"""Mesh serving tests: EP-sharded scheduler parity + lane evacuation.

The multi-device claims (token-bit parity on a mesh, packed-MoE EP routing,
token-exact evacuation after a simulated host loss) run in subprocesses
with 8 forced host devices, like `test_distributed.py`. The supervisor's
control-plane logic (heartbeats, lane bookkeeping, restart budget) is
mesh-independent and also runs fast in-process against the null context
with a simulated host count.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.scheduler import Request, make_scheduler
from repro.models.model import build_model
from repro.parallel.ctx import ParallelContext
from repro.runtime.supervisor import FailureInjection, ServeSupervisor

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run8(body: str, timeout=600) -> str:
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              + textwrap.dedent(body))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# fast in-process: supervisor control plane on the null mesh
# ---------------------------------------------------------------------------

def _smoke_engine():
    cfg = configs.get_smoke("tinyllama-1.1b")
    model = build_model(cfg, ParallelContext(mesh=None))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=4, plen=8, gen=6):
    rng = np.random.default_rng(0)
    return [Request(rid=i, max_new_tokens=gen,
                    prompt=rng.integers(0, cfg.vocab, size=(plen,))
                    .astype(np.int32)) for i in range(n)]


def test_failure_injection_validates():
    with pytest.raises(ValueError):
        FailureInjection(host=0, at_step=1, kind="meteor")
    with pytest.raises(ValueError):
        FailureInjection(host=-1, at_step=1)


def test_null_mesh_evacuation_token_exact():
    """A vanished simulated host mid-decode: its lanes re-admit and the
    stitched streams equal the uninterrupted run's, with one restart."""
    cfg, model, params = _smoke_engine()

    def make_sched(ctx, pool):
        return make_scheduler("continuous", model, params, cfg, n_slots=4,
                              max_len=24, sampling="greedy", seed=0, ctx=ctx)

    reqs = _requests(cfg, gen=10)
    ref = make_sched(ParallelContext(mesh=None), None).run(_requests(cfg,
                                                                     gen=10))
    sup = ServeSupervisor(make_sched, ParallelContext(mesh=None), hosts=2,
                          deadline_steps=2,
                          injection=FailureInjection(host=1, at_step=3))
    out = sup.serve(reqs)
    assert sup.restarts == 1
    assert sup.evacuated_rids, "host 1 owned lanes; some must evacuate"
    for a, b in zip(ref, out):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert b.prompt_len == a.prompt_len


def test_evacuation_exhausts_restart_budget():
    """Losing the only host has nowhere to evacuate to: TrainingAborted."""
    from repro.runtime.fault_tolerance import TrainingAborted
    cfg, model, params = _smoke_engine()

    def make_sched(ctx, pool):
        return make_scheduler("continuous", model, params, cfg, n_slots=2,
                              max_len=24, sampling="greedy", seed=0, ctx=ctx)

    sup = ServeSupervisor(make_sched, ParallelContext(mesh=None), hosts=1,
                          deadline_steps=2,
                          injection=FailureInjection(host=0, at_step=1))
    with pytest.raises(TrainingAborted):
        sup.serve(_requests(cfg, n=2, gen=10))


def test_host_of_lane_partitions_evenly():
    cfg, model, params = _smoke_engine()

    def make_sched(ctx, pool):
        return make_scheduler("continuous", model, params, cfg, n_slots=4,
                              max_len=16, sampling="greedy", seed=0, ctx=ctx)

    sup = ServeSupervisor(make_sched, ParallelContext(mesh=None), hosts=2)
    assert [sup.host_of_lane(i) for i in range(4)] == [0, 0, 1, 1]


# ---------------------------------------------------------------------------
# subprocess, 8 virtual devices: the mesh-execution claims
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mesh_serve_bit_parity():
    """Continuous + slo greedy streams on a 4x2 mesh are byte-equal to
    single-device, with the same dispatch count and the fleet floor equal
    to n_hosts x per-host floor."""
    run8("""
        import numpy as np
        from repro.launch.serve import run

        base = ["--arch", "tinyllama-1.1b", "--smoke", "--batch", "4",
                "--prompt-len", "12", "--gen", "8", "--sampling", "greedy"]
        for schedule in ("continuous", "slo"):
            argv = base + ["--schedule", schedule]
            single = run(argv)
            mesh = run(argv + ["--mesh-shape", "4x2"])
            assert np.array_equal(single["tokens"], mesh["tokens"]), schedule
            assert single["n_dispatches"] == mesh["n_dispatches"], schedule
            assert mesh["n_hosts"] == 4
            assert abs(mesh["fleet_floor_s"]
                       - 4 * mesh["per_host_floor_s"]) < 1e-12
        print("parity OK")
    """)


@pytest.mark.slow
def test_packed_moe_routes_through_ep():
    """A packed (int4_palette) dbrx serve on a 2x4 mesh traces the
    shard_map EP path, and a direct prefill of the same packed params on
    and off the mesh agrees to float tolerance (the EP combine reorders
    the expert reduction, so bitwise equality is not the contract here)."""
    run8("""
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.core import hal
        from repro.core.dispatch import KernelDispatcher
        from repro.launch.serve import parse_mesh, run
        from repro.models import moe
        from repro.models.model import build_model
        from repro.optim.compression import compress_model_params
        from repro.parallel.ctx import ParallelContext

        moe.ROUTE_COUNTS["ep"] = 0
        out = run(["--arch", "dbrx-132b", "--smoke", "--batch", "8",
                   "--prompt-len", "8", "--gen", "4", "--sampling", "greedy",
                   "--weight-form", "int4_palette", "--mesh-shape", "2x4"])
        assert moe.ROUTE_COUNTS["ep"] >= 1, "serve never traced the EP path"

        cfg = configs.get_smoke("dbrx-132b")
        disp = KernelDispatcher(hal.get_target("tpu-v5e"))
        ref = build_model(cfg, ParallelContext(mesh=None), dispatcher=disp)
        meshed = build_model(cfg, parse_mesh("2x4"), dispatcher=disp)
        params = compress_model_params(ref.init(jax.random.PRNGKey(0)),
                                       "int4_palette")
        toks = {"tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, size=(8, 8)), jnp.int32)}
        _, lg_mesh = meshed.prefill(params, toks)
        _, lg_ref = ref.prefill(params, toks)
        err = float(jnp.max(jnp.abs(lg_mesh - lg_ref)))
        assert err < 1e-4, f"EP prefill logits off by {err}"
        print("EP OK", err)
    """)


@pytest.mark.slow
def test_mesh_evacuation_token_exact():
    """A host vanishing mid-decode on the 4x2 mesh: the mesh shrinks to
    3x2 over the survivors, the lost lanes re-admit, and the streams are
    byte-equal to the uninterrupted single-device run."""
    run8("""
        import numpy as np
        from repro.launch.serve import run

        base = ["--arch", "tinyllama-1.1b", "--smoke", "--batch", "4",
                "--prompt-len", "12", "--gen", "8", "--sampling", "greedy"]
        ref = run(base)
        out = run(base + ["--mesh-shape", "4x2", "--fail-host", "1",
                          "--fail-at-step", "3"])
        assert np.array_equal(ref["tokens"], out["tokens"])
        assert out["restarts"] == 1
        assert [r["new_mesh_shape"] for r in out["rescales"]] == [[3, 2]] \\
            or [tuple(r["new_mesh_shape"]) for r in out["rescales"]] \\
            == [(3, 2)]
        assert out["n_hosts"] == 3
        print("evacuation OK")
    """)
