"""Model-stack dispatch parity: dense reference vs the routed serving path.

The harness the tentpole ships behind: for every registered weight form
(dense / int4_palette / sparse) and several model configs, the model stack
routed through `core.dispatch.KernelDispatcher` — every projection, MLP,
MoE expert, attention and logits matmul resolved against the kernel
registry — must match the dense reference within the registry's per-dtype
tolerances, across all three serving-relevant entry points:

    prefill   (batched prompt -> caches + last logits)
    decode    (token-by-token against the resident KV cache)
    loss      (the train-step forward; checks the routed stack end to end)

For packed forms the reference is the *fold* path: the same quantized
values decoded to dense and multiplied with plain XLA matmuls — so the
comparison isolates the routing/kernels, not the quantizer.

A second battery pins the oracle-fallback behavior: a capability-limited
HAL (palette stream gated off; an M1 with no `gather`) must silently
reroute the affected kernels to their oracles and still match.
"""

import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import hal
from repro.core.dispatch import KernelDispatcher
from repro.kernels import registry
from repro.launch.serve import _merge_prefill
from repro.models.model import build_model
from repro.optim.compression import (compress_model_params,
                                     decompress_model_params,
                                     weight_form_census)

FORMS = ("dense", "int4_palette", "sparse")
FAST_ARCHS = ("tinyllama-1.1b",)
# large-config sweeps: MoE (dbrx), biased GQA (granite), MLA+MoE+MTP
# (deepseek), encoder-decoder (whisper)
SLOW_ARCHS = ("dbrx-132b", "granite-8b", "deepseek-v3-671b", "whisper-small")
DECODE_STEPS = 3


def _tolerance(form: str) -> tuple[float, float]:
    """The registry's fp32 tolerance for the kernel that streams `form`,
    widened by a small depth factor (the smoke stacks chain a few routed
    matmuls per layer)."""
    kernel = {"dense": "anemm", "int4_palette": "palette",
              "sparse": "sparse"}[form]
    rtol, atol = registry.get(kernel).tol(jnp.float32)
    return 4 * rtol, 4 * atol


def _batch_for(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)}
    batch["targets"] = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b,) + cfg.frame_shape), jnp.float32)
    return batch


_CASE_CACHE: dict = {}


def _run_case(arch: str, form: str, target: hal.Target = hal.TPU_V5E):
    """Run prefill / decode / loss through the dense reference and the
    dispatched path once per (arch, form, target); memoized."""
    key = (arch, form, target.name)
    if key in _CASE_CACHE:
        return _CASE_CACHE[key]
    cfg = configs.get_smoke(arch)
    ref = build_model(cfg)
    params = ref.init(jax.random.PRNGKey(0))
    if form == "dense":
        cparams, rparams = params, params
    else:
        cparams = compress_model_params(params, form)
        assert weight_form_census(cparams), f"{arch}: nothing packed"
        rparams = decompress_model_params(cparams)
    dispatcher = KernelDispatcher(target)
    routed = build_model(cfg, dispatcher=dispatcher)

    batch = _batch_for(cfg)
    b, s = batch["tokens"].shape
    out = {"dispatcher": dispatcher, "cfg": cfg}

    # prefill
    caches_r, lg_r = jax.jit(ref.prefill)(rparams, batch)
    caches_d, lg_d = jax.jit(routed.prefill)(cparams, batch)
    out["prefill"] = (np.asarray(lg_r), np.asarray(lg_d))

    # decode: identical greedy token stream (from the reference) into both
    max_len = s + DECODE_STEPS + 1
    caches_r = _merge_prefill(ref, ref.init_cache(b, max_len), caches_r, s)
    caches_d = _merge_prefill(routed, routed.init_cache(b, max_len),
                              caches_d, s)
    decode_r = jax.jit(ref.decode_step)
    decode_d = jax.jit(routed.decode_step)
    tok = jnp.argmax(lg_r[:, -1, : cfg.vocab], axis=-1).astype(jnp.int32)[:, None]
    steps = []
    for i in range(DECODE_STEPS):
        pos = jnp.full((b,), s + i, jnp.int32)
        caches_r, dlg_r = decode_r(rparams, caches_r, tok, pos)
        caches_d, dlg_d = decode_d(cparams, caches_d, tok, pos)
        steps.append((np.asarray(dlg_r), np.asarray(dlg_d)))
        tok = jnp.argmax(dlg_r[:, -1, : cfg.vocab], axis=-1
                         ).astype(jnp.int32)[:, None]
    out["decode"] = steps

    # loss (train-step forward; fp32 anchor at the head)
    loss_r, _ = jax.jit(ref.loss)(rparams, batch)
    loss_d, _ = jax.jit(routed.loss)(cparams, batch)
    out["loss"] = (float(loss_r), float(loss_d))

    _CASE_CACHE[key] = out
    return out


def _sweep(archs):
    return [pytest.param(arch, form, id=f"{arch}-{form}")
            for arch in archs for form in FORMS]


class _ParitySweep:
    ARCHS: tuple = ()

    def test_prefill_parity(self, arch, form):
        case = _run_case(arch, form)
        rtol, atol = _tolerance(form)
        lg_r, lg_d = case["prefill"]
        np.testing.assert_allclose(lg_d, lg_r, rtol=rtol, atol=atol)

    def test_decode_parity(self, arch, form):
        case = _run_case(arch, form)
        rtol, atol = _tolerance(form)
        for i, (dlg_r, dlg_d) in enumerate(case["decode"]):
            np.testing.assert_allclose(
                dlg_d, dlg_r, rtol=rtol, atol=atol,
                err_msg=f"decode step {i} diverged")

    def test_loss_parity(self, arch, form):
        case = _run_case(arch, form)
        rtol, _ = _tolerance(form)
        loss_r, loss_d = case["loss"]
        assert loss_d == pytest.approx(loss_r, rel=rtol)

    def test_routes_are_native_on_tpu(self, arch, form):
        # on the full-capability TPU target nothing may fall back: the
        # sweep must exercise the Pallas rows, not silently oracle them
        case = _run_case(arch, form)
        backends = {r.backend for r in case["dispatcher"].routes}
        assert backends == {"pallas"}, Counter(
            (r.kernel, r.reason) for r in case["dispatcher"].routes
            if r.backend == "oracle")
        if form != "dense":
            kernels = {r.kernel for r in case["dispatcher"].routes}
            expected = {"int4_palette": "palette", "sparse": "sparse"}[form]
            assert expected in kernels, kernels


@pytest.mark.parametrize("arch,form", _sweep(FAST_ARCHS))
class TestParityFast(_ParitySweep):
    """Fast lane: one representative arch x every weight form."""


@pytest.mark.slow
@pytest.mark.parametrize("arch,form", _sweep(SLOW_ARCHS))
class TestParityFull(_ParitySweep):
    """Full lane: MoE / biased / MLA+MTP / encdec configs x every form."""


# ---------------------------------------------------------------------------
# Oracle fallback under capability-limited HALs
# ---------------------------------------------------------------------------


def _limited_v5e_no_palette() -> hal.Target:
    return dataclasses.replace(
        hal.TPU_V5E, name="tpu-v5e-nopalette",
        weight_streams={**hal.TPU_V5E.weight_streams,
                        hal.WeightForm.INT4_PALETTE: False})


class TestOracleFallback:
    def test_palette_falls_back_when_stream_gated(self):
        """A HAL whose palette stream folds must route the packed-weight
        matmuls to the oracle — and still match the dense reference."""
        case = _run_case("tinyllama-1.1b", "int4_palette",
                         target=_limited_v5e_no_palette())
        rtol, atol = _tolerance("int4_palette")
        lg_r, lg_d = case["prefill"]
        np.testing.assert_allclose(lg_d, lg_r, rtol=rtol, atol=atol)
        for dlg_r, dlg_d in case["decode"]:
            np.testing.assert_allclose(dlg_d, dlg_r, rtol=rtol, atol=atol)
        palette_routes = [r for r in case["dispatcher"].routes
                          if r.kernel == "palette"]
        assert palette_routes
        assert all(r.backend == "oracle" for r in palette_routes)
        assert all("folds" in r.reason for r in palette_routes)

    def test_decode_attention_oracles_on_gatherless_m1(self):
        """H13/M1 has no native gather: decode attention must take the
        oracle cell of the op-by-device matrix while anemm/flash stay
        native — and decode still matches the dense reference."""
        case = _run_case("tinyllama-1.1b", "dense", target=hal.ANE_M1)
        rtol, atol = _tolerance("dense")
        for dlg_r, dlg_d in case["decode"]:
            np.testing.assert_allclose(dlg_d, dlg_r, rtol=rtol, atol=atol)
        by_kernel = {}
        for r in case["dispatcher"].routes:
            by_kernel.setdefault(r.kernel, set()).add(r.backend)
        assert by_kernel["decode_attention"] == {"oracle"}
        assert by_kernel["anemm"] == {"pallas"}
        assert by_kernel["flash"] == {"pallas"}
