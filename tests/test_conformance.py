"""Oracle-backed conformance sweep over the kernel registry.

Every case here is *generated* from `repro.kernels.registry` — there is no
hard-coded kernel list. A kernel family added to the registry is swept
against its ref oracle for every declared dtype and shape class (including
the padding/alignment edge cases), has its VJP checked when it declares one,
gets a sane cost-model entry, and is routed by the capability-gated
dispatcher — for free.

Tiering: the full kernel x dtype x shape sweep is `slow` (it runs Pallas in
interpret mode); a one-case-per-kernel smoke subset stays in the fast lane.
"""

import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, hal
from repro.kernels import compat, registry


def _seed(*parts) -> np.random.Generator:
    # deterministic per-case seeding (stable hash: str hash() is salted) so
    # sweep cases are order-independent and reproducible across runs
    digest = hashlib.sha256("|".join(str(p) for p in parts).encode()).digest()
    return np.random.default_rng(int.from_bytes(digest[:4], "little"))


def _check_case(spec, case, dtype):
    inputs = spec.make_inputs(case, dtype, _seed(spec.name, case.name, dtype))
    got = np.asarray(spec.run_kernel(inputs), np.float32)
    ref = np.asarray(spec.run_oracle(inputs), np.float32)
    rtol, atol = spec.tol(dtype)
    np.testing.assert_allclose(
        got, ref, rtol=rtol, atol=atol,
        err_msg=f"{spec.name}/{case.name} diverged from its oracle")


def _sweep_params(edge_only=None):
    for spec, case, dtype in registry.iter_conformance_cases():
        if edge_only is not None and case.edge != edge_only:
            continue
        yield pytest.param(spec, case, dtype,
                           id=f"{spec.name}-{case.name}-{jnp.dtype(dtype).name}")


class TestRegistrySurface:
    def test_registry_is_populated(self):
        # every kernel family the tree ships must be registered — count only,
        # no name list, so new families extend rather than break this
        assert len(registry.names()) >= 6
        assert len(set(registry.names())) == len(registry.names())

    def test_every_spec_declares_edge_cases(self):
        for spec in registry.all_specs():
            assert spec.edge_cases, f"{spec.name} has no padding/alignment case"
            assert spec.dtypes, f"{spec.name} declares no dtypes"

    def test_cost_entries_are_roofline_usable(self):
        for spec in registry.all_specs():
            for case in spec.cases:
                c = spec.cost(case, spec.dtypes[0])
                assert c.flops > 0 and c.bytes > 0, (spec.name, case.name)
                # a cost entry prices on both roofline axes
                t = hal.TPU_V5E
                assert max(c.flops / t.peak_flops,
                           c.bytes / t.hbm_bandwidth) > 0

    def test_capability_ops_exist_in_hal(self):
        # the gate key must be a real row of the op floor on the TPU target,
        # otherwise the dispatcher would silently oracle everything
        for spec in registry.all_specs():
            assert hal.TPU_V5E.attests(spec.capability_op), spec.name

    def test_no_direct_compiler_params_outside_compat(self):
        # the acceptance grep, as a test: kernels reach Pallas compiler params
        # only through the version-adaptive surface
        import pathlib
        root = pathlib.Path(__file__).resolve().parents[1] / "src"
        offenders = []
        for p in root.rglob("*.py"):
            if p.name == "compat.py":
                continue
            if "pltpu.CompilerParams" in p.read_text() \
                    or "pltpu.TPUCompilerParams" in p.read_text():
                offenders.append(str(p))
        assert not offenders, offenders


class TestConformanceSmoke:
    """Fast lane: first (non-edge) case x first dtype per registered kernel."""

    @pytest.mark.parametrize(
        "spec", registry.all_specs(), ids=registry.names())
    def test_kernel_matches_oracle(self, spec):
        case = next(c for c in spec.cases if not c.edge)
        _check_case(spec, case, spec.dtypes[0])


@pytest.mark.slow
class TestConformanceSweep:
    """The full generated sweep: kernel x dtype x shape class vs oracle."""

    @pytest.mark.parametrize("spec,case,dtype", _sweep_params(edge_only=False))
    def test_kernel_matches_oracle(self, spec, case, dtype):
        _check_case(spec, case, dtype)


class TestPaddingAlignment:
    """Edge cases (ragged/tiny/off-block shapes) stay in the fast lane at the
    widest dtype — padding bugs are shape bugs, not dtype bugs."""

    @pytest.mark.parametrize("spec,case,dtype", [
        p for p in _sweep_params(edge_only=True)
        if p.values[2] == jnp.float32])
    def test_kernel_matches_oracle(self, spec, case, dtype):
        _check_case(spec, case, dtype)

    @pytest.mark.slow
    @pytest.mark.parametrize("spec,case,dtype", [
        p for p in _sweep_params(edge_only=True)
        if p.values[2] != jnp.float32])
    def test_kernel_matches_oracle_narrow(self, spec, case, dtype):
        _check_case(spec, case, dtype)


class TestVJP:
    """Gradient conformance for every kernel that declares a VJP."""

    @pytest.mark.parametrize(
        "spec",
        [s for s in registry.all_specs() if s.make_vjp is not None],
        ids=[s.name for s in registry.all_specs() if s.make_vjp is not None])
    def test_vjp_matches_oracle(self, spec):
        case = next(c for c in spec.cases if not c.edge)
        inputs = spec.make_inputs(case, jnp.float32, _seed(spec.name, "vjp"))
        kernel_fn, ref_fn, args = spec.make_vjp(inputs)
        argnums = tuple(range(len(args)))
        g_kernel = jax.grad(kernel_fn, argnums)(*args)
        g_ref = jax.grad(ref_fn, argnums)(*args)
        for gk, gr in zip(g_kernel, g_ref):
            np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                       rtol=5e-3, atol=5e-3,
                                       err_msg=f"{spec.name} VJP diverged")


class TestDispatcher:
    """Capability-gated routing: the op-by-device matrix, live."""

    def test_tpu_routes_all_native(self):
        for route in dispatch.KernelDispatcher(hal.TPU_V5E).matrix():
            assert route.native, route

    def test_m1_gates_decode_attention_on_gather(self):
        # H13 attests gather but cannot lower it — the paper's attested-vs-
        # reachable split decides a kernel route here
        d = dispatch.KernelDispatcher(hal.ANE_M1)
        by_name = {r.kernel: r for r in d.matrix()}
        assert not by_name["decode_attention"].native
        assert "gather" in by_name["decode_attention"].reason
        # ...and the gate lifts on the generation that ships gather (H15)
        d3 = dispatch.KernelDispatcher(hal.ANE_M3)
        assert {r.kernel: r for r in d3.matrix()}["decode_attention"].native

    def test_bf16_falls_back_on_ane(self):
        d = dispatch.KernelDispatcher(hal.ANE_M1)
        for route in d.matrix(jnp.bfloat16):
            assert not route.native, route

    def test_oracle_fallback_executes_and_matches(self):
        # a gated route still computes — through the oracle — and agrees with
        # the native path on the same inputs
        spec = registry.get("decode_attention")
        case = spec.cases[0]
        inputs = spec.make_inputs(case, jnp.float32, _seed("fallback"))
        native = dispatch.KernelDispatcher(hal.TPU_V5E)("decode_attention",
                                                        inputs)
        fallback = dispatch.KernelDispatcher(hal.ANE_M1)("decode_attention",
                                                         inputs)
        rtol, atol = spec.tol(jnp.float32)
        np.testing.assert_allclose(np.asarray(native), np.asarray(fallback),
                                   rtol=rtol, atol=atol)

    def test_routes_are_recorded(self):
        d = dispatch.KernelDispatcher(hal.TPU_V5E)
        spec = registry.get("act_lut")
        d("act_lut", spec.make_inputs(spec.cases[0], jnp.float32, _seed("r")))
        assert len(d.routes) == 1 and d.routes[0].kernel == "act_lut"

    def test_full_matrix_covers_all_targets(self):
        rows = dispatch.kernel_matrix()
        assert len(rows) == len(hal.TARGETS) * len(registry.names())


class TestCompatLayer:
    def test_compiler_params_class_resolved(self):
        # whichever name this jax ships, the surface must produce an object
        # pallas_call accepts (or {} on interpret-only builds)
        kw = compat.pallas_call_params(
            dimension_semantics=("parallel", "arbitrary"))
        assert isinstance(kw, dict)
        if kw:
            assert "compiler_params" in kw

    def test_unknown_fields_are_dropped(self):
        # a field from another jax era must not raise
        compat.compiler_params(dimension_semantics=("parallel",),
                               field_from_the_future=1)

    def test_tree_flatten_with_path(self):
        leaves, _ = compat.tree_flatten_with_path({"a": {"b": jnp.ones(2)}})
        (path, leaf), = leaves
        assert compat.tree_path_str(path) == "a/b"
        assert leaf.shape == (2,)

    def test_jax_version_parses(self):
        v = compat.jax_version()
        assert len(v) == 3 and v >= (0, 4, 0)
