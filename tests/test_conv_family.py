"""Conv/pooling family: fused-epilogue bit-identity and capability gating.

The fused `epilogue=` contract is exact: a kernel that fuses the LUT
activation at its output port must produce bit-identical results to the
two-dispatch pipeline (kernel, store, then the act_lut kernel). These tests
pin that, plus the op-by-device story: a HAL target whose feature bytes deny
`conv2d` must route the conv to the jnp oracle — silently, with a recorded
reason — and still agree numerically.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hal
from repro.core.dispatch import KernelDispatcher
from repro.kernels import registry
from repro.models import dispatched as dsp

# ---------------------------------------------------------------------------
# Fused-epilogue bit-identity (the tentpole invariant)
# ---------------------------------------------------------------------------


def _conv_operands(dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 9, 11, 6)), dtype)
    w = jnp.asarray(rng.normal(size=(3, 3, 6, 24)) * 0.2, dtype)
    b = jnp.asarray(rng.normal(size=(24,)), dtype)
    return x, w, b


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["gelu", "sigmoid"])
def test_conv_fused_epilogue_bit_identical(dtype, act):
    from repro.kernels.act_lut.ops import lut_activation
    from repro.kernels.conv import ops as conv_ops

    x, w, b = _conv_operands(dtype)
    fused = conv_ops.conv2d(x, w, b, stride=(1, 2), padding="SAME",
                            epilogue=act)
    separate = lut_activation(act)(
        conv_ops.conv2d(x, w, b, stride=(1, 2), padding="SAME"))
    assert fused.dtype == separate.dtype
    assert np.array_equal(np.asarray(fused, np.float32),
                          np.asarray(separate, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["gelu", "swish"])
def test_anemm_fused_epilogue_bit_identical(dtype, act):
    from repro.kernels.act_lut.ops import lut_activation
    from repro.kernels.anemm.anemm import anemm

    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(48, 160)) * 0.3, dtype)
    b = jnp.asarray(rng.normal(size=(160, 72)) * 0.3, dtype)
    fused = anemm(a, b, epilogue=act)
    separate = lut_activation(act)(anemm(a, b))
    assert np.array_equal(np.asarray(fused, np.float32),
                          np.asarray(separate, np.float32))


def test_fused_matches_separate_reference():
    """The oracle side holds the same contract: conv2d_ref(epilogue=) is
    exactly lut_apply_ref over the epilogue-free conv."""
    from repro.kernels.act_lut.ops import lut_apply_ref
    from repro.kernels.conv.ref import conv2d_ref

    x, w, b = _conv_operands(jnp.float32)
    fused = conv2d_ref(x, w, b, stride=(2, 2), padding="VALID",
                       epilogue="gelu")
    separate = lut_apply_ref(
        conv2d_ref(x, w, b, stride=(2, 2), padding="VALID"), "gelu")
    assert np.array_equal(np.asarray(fused), np.asarray(separate))


# ---------------------------------------------------------------------------
# Dispatched entry point: fusion scope and dispatch counts
# ---------------------------------------------------------------------------


def test_dispatched_conv_fused_vs_unfused_same_bits_fewer_routes():
    x, w, b = _conv_operands(jnp.float32)

    d_fused = KernelDispatcher()
    with dsp.use_dispatcher(d_fused), dsp.fuse_epilogues(True):
        out_fused = dsp.conv2d(x, w, b, stride=(1, 2), act="gelu")

    d_unfused = KernelDispatcher()
    with dsp.use_dispatcher(d_unfused), dsp.fuse_epilogues(False):
        out_unfused = dsp.conv2d(x, w, b, stride=(1, 2), act="gelu")

    assert np.array_equal(np.asarray(out_fused), np.asarray(out_unfused))
    assert [r.kernel for r in d_fused.routes] == ["conv2d"]
    assert [r.kernel for r in d_unfused.routes] == ["conv2d", "act_lut"]
    assert all(r.native for r in d_fused.routes)
    assert all(r.native for r in d_unfused.routes)


def test_undispatched_conv_matches_routed_oracle():
    """No dispatcher in scope -> the differentiable reference with the same
    LUT numerics, so model code can call dsp.conv2d unconditionally."""
    from repro.kernels.conv.ref import conv2d_ref

    x, w, b = _conv_operands(jnp.float32, seed=3)
    got = dsp.conv2d(x, w, b, stride=(1, 1), act="gelu")
    want = conv2d_ref(x, w, b, stride=(1, 1), padding="SAME",
                      epilogue="gelu")
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Capability gating: feature-byte denial falls back to the oracle
# ---------------------------------------------------------------------------


def _denied(op: str) -> hal.Target:
    return dataclasses.replace(
        hal.TPU_V5E, name=f"tpu-no-{op}",
        op_floor={**hal.TPU_V5E.op_floor, op: False})


@pytest.mark.parametrize("name,op", [("conv2d", "conv2d"),
                                     ("avg_pool", "avg_pool"),
                                     ("max_pool", "max_pool")])
def test_denied_op_routes_to_oracle(name, op):
    disp = KernelDispatcher(_denied(op))
    route = disp.resolve(name, jnp.float32)
    assert not route.native
    assert op in route.reason

    native = KernelDispatcher()
    assert native.resolve(name, jnp.float32).native


def test_conv2d_denied_target_still_serves_the_stem():
    """The regression the satellite pins: with `conv2d` struck from the
    feature bytes, dispatched conv calls run the oracle leg and the numbers
    still match the native path at registry tolerance."""
    x, w, b = _conv_operands(jnp.float32, seed=5)

    with dsp.use_dispatcher(KernelDispatcher()):
        native = dsp.conv2d(x, w, b, stride=(1, 2), act="gelu")

    gated = KernelDispatcher(_denied("conv2d"))
    with dsp.use_dispatcher(gated):
        fallback = dsp.conv2d(x, w, b, stride=(1, 2), act="gelu")

    assert [r.backend for r in gated.routes] == ["oracle"]
    assert gated.routes[0].reason
    rtol, atol = registry.get("conv2d").tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(fallback), np.asarray(native),
                               rtol=rtol, atol=atol)


def test_pool_routes_through_dispatcher():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 10, 12, 5)), jnp.float32)
    disp = KernelDispatcher()
    with dsp.use_dispatcher(disp):
        a = dsp.avg_pool(x, window=(2, 2))
        m = dsp.max_pool(x, window=(3, 3), stride=(2, 2), padding="SAME")
    assert [r.kernel for r in disp.routes] == ["avg_pool", "max_pool"]
    assert all(r.native for r in disp.routes)

    from repro.kernels.conv.ref import avg_pool_ref, max_pool_ref
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(avg_pool_ref(x, window=(2, 2))),
        rtol=1e-5, atol=1e-5)
    assert np.array_equal(
        np.asarray(m),
        np.asarray(max_pool_ref(x, window=(3, 3), stride=(2, 2),
                                padding="SAME")))
