"""Core technique modules: capability, segmenter, compression, dispatch,
roofline — each validated against the paper's corresponding claim."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import (capability, compression as cp, costmodel, dispatch,
                        hal, roofline, segmenter as sg)
from repro.core.hal import WeightForm


class TestCapability:
    def test_conv3d_attested_but_unreachable(self):
        # paper:§4.4 — the case that fixes the rule
        t = hal.ANE_M1
        assert t.attests("conv3d") and not t.reaches("conv3d")
        v = capability.confirm_op("conv3d", t)
        assert not v.reachable and v.layer == "lowering"

    def test_family_gates(self):
        # paper:T4.1 — sin/cos native only from A15 (H15)
        assert not hal.ANE_M1.reaches("sin")
        assert hal.ANE_M3.reaches("sin")
        # texture engine arrives at A14
        assert not hal.ANE_M1.reaches("resize_texture")
        assert hal.ANE_M2.reaches("resize_texture")

    def test_no_path_on_any_family(self):
        # paper:§4.2 — reduce_prod / scatter / recurrent cells never lower
        for op in ("reduce_prod", "scatter", "gru", "lstm"):
            for t in (hal.ANE_M1, hal.ANE_M5):
                assert not t.reaches(op), op

    def test_confirm_op_on_real_backend(self):
        # compile-and-run on the actual XLA target: NATIVE for standard ops
        for op in ("matmul", "softmax", "conv2d", "reduce_prod"):
            v = capability.confirm_op(op, hal.TPU_V5E)
            assert v.reachable, v

    def test_census_gap_exists(self):
        rows = capability.attested_vs_reachable(hal.ANE_M1)
        gap = [op for op, att, reach in rows if att and not reach]
        assert "conv3d" in gap

    def test_pooling_and_argmax_probe_rows(self):
        # the op-by-device matrix covers the pooling rows (paper's conv/
        # pooling families, registry-bound next) and the argmax port the
        # specdec verify/accept kernel gates on (0x4f2_argmax_hw)
        for op in ("avg_pool", "max_pool", "argmax"):
            v = capability.confirm_op(op, hal.TPU_V5E)
            assert v.reachable, v
            assert hal.ANE_M1.reaches(op), op
        assert {"avg_pool", "max_pool", "argmax"} \
            <= set(capability._probe_ops())


class TestSegmenter:
    def _ops(self, arch="tinyllama-1.1b", shape="decode_32k", n=7):
        cfg = configs.get_config(arch)
        return costmodel.op_graph(cfg, configs.SHAPES[shape])[:n]

    def test_matches_brute_force(self):
        ops = self._ops()
        d = sg.place(ops, sg.ANE_BACKENDS)
        b = sg.brute_force(ops, sg.ANE_BACKENDS)
        assert abs(d.cost - b.cost) < 1e-12

    def test_transfer_penalty_favors_long_segments(self):
        # paper:§5.3 — the transfer cost is why minimum-cost solutions favor
        # long single-backend runs
        ops = self._ops(n=8)
        cheap = sg.place(ops, sg.ANE_BACKENDS, transfer_bytes_per_s=1e15)
        costly = sg.place(ops, sg.ANE_BACKENDS, transfer_bytes_per_s=1e6)
        assert len(costly.segments) <= len(cheap.segments)

    def test_ineligible_op_routes_around(self):
        # an op the engine cannot accept has no engine node -> fallback
        backends = (
            sg.Backend("ane", 12e12, 51e9, rejects=frozenset({"attn"})),
            sg.Backend("gpu", 2.6e12, 230e9),
        )
        ops = self._ops("tinyllama-1.1b", "train_4k", 6)
        p = sg.place(ops, backends)
        for name, b in zip(p.ops, p.backend):
            if "attn" in name:
                assert b == "gpu"

    def test_cost_equation_form(self):
        # cost = max(flops/P, bytes/B): a compute-heavy op is flops-priced,
        # a byte-heavy op bandwidth-priced
        b = sg.ANE_BACKENDS[0]
        heavy = costmodel.OpCost("x", 1e12, 1e3)
        wide = costmodel.OpCost("y", 1e3, 1e9)
        assert b.op_cost(heavy) == pytest.approx(1e12 / b.flops_per_s)
        assert b.op_cost(wide) == pytest.approx(1e9 / b.bytes_per_s)


class TestCompression:
    rng = np.random.default_rng(0)
    w = rng.normal(size=(256, 128)).astype(np.float32)

    @pytest.mark.parametrize("form,max_err", [
        (WeightForm.INT8, 0.02), (WeightForm.BLOCKWISE, 0.02),
        (WeightForm.INT4_PALETTE, 0.25), (WeightForm.SPARSE, 0.6),
    ])
    def test_round_trip_error_bounds(self, form, max_err):
        assert cp.accuracy_error(form, self.w) <= max_err

    def test_stream_vs_fold_gates_match_paper(self):
        # paper:T7.1 — M1 streams int4+sparse, folds int8+blockwise;
        # A14/M2 adds int8; A15/M3 adds blockwise; M5 streams all four
        m1, m2, m3, m5 = hal.ANE_M1, hal.ANE_M2, hal.ANE_M3, hal.ANE_M5
        assert m1.streams(WeightForm.INT4_PALETTE) and m1.streams(WeightForm.SPARSE)
        assert not m1.streams(WeightForm.INT8) and not m1.streams(WeightForm.BLOCKWISE)
        assert m2.streams(WeightForm.INT8) and not m2.streams(WeightForm.BLOCKWISE)
        assert m3.streams(WeightForm.BLOCKWISE)
        assert all(m5.streams(f) for f in WeightForm)

    def test_fold_moves_dense_bytes(self):
        # paper:§7.3 — the int8 fold on M1 is a stored-size saving only
        p = cp.encode(WeightForm.INT8, self.w)
        assert p.stored_bytes < p.dense_bytes            # stored: halved
        assert cp.dram_bytes(p, hal.ANE_M1) == p.dense_bytes   # moved: dense
        assert cp.dram_bytes(p, hal.ANE_M2) == p.stored_bytes  # A14+: streams

    def test_int4_stream_byte_ratio(self):
        # 4-bit indices -> ~4x fewer weight bytes (the raw ratio behind the
        # measured 2.37x of paper:T7.4, which includes activation traffic)
        p = cp.encode(WeightForm.INT4_PALETTE, self.w)
        assert 3.5 <= p.dense_bytes / p.stored_bytes <= 4.5
        # with activation bytes included, the predicted speedup drops toward
        # the paper's measured 2.37x
        sp = cp.stream_speedup(p, hal.ANE_M1, act_bytes=p.dense_bytes * 0.25)
        assert 2.0 <= sp <= 3.2

    def test_chooser_follows_paper_procedure(self):
        # compute-bound -> fp16 (a stream cannot help)
        f = cp.choose_weight_form(self.w, hal.ANE_M1, flops=1e12, act_bytes=10.0)
        assert f == WeightForm.FP16
        # bandwidth-bound + palettizable weight -> int4 on M1
        clustered = self.rng.choice(
            np.linspace(-1, 1, 16), size=(256, 128)).astype(np.float32)
        f = cp.choose_weight_form(clustered, hal.ANE_M1,
                                  flops=2 * 256 * 128 * 4, act_bytes=1e3)
        assert f == WeightForm.INT4_PALETTE
        # mostly-zero weight -> sparse beats when int4 misses tolerance
        sparse_w = self.w.copy()
        sparse_w[self.rng.random(self.w.shape) < 0.6] = 0.0
        f = cp.choose_weight_form(sparse_w, hal.ANE_M1,
                                  flops=2 * 256 * 128 * 4, act_bytes=1e3,
                                  tolerance=0.35)
        assert f in (WeightForm.INT4_PALETTE, WeightForm.SPARSE)

    def test_palette_packing_worked_example(self):
        # paper:§7.2 — [1,0,0,1] with lut[0]=0.0, lut[1]=1.0 packs to two
        # bytes 0x01, 0x10 (low nibble first)
        w = np.array([[1.0], [0.0], [0.0], [1.0]], np.float32)
        from repro.kernels.palette.palette_matmul import pack_kn
        packed, lut = pack_kn(w, iters=2)
        dec = [lut[packed[0, 0] & 0xF], lut[packed[0, 0] >> 4],
               lut[packed[1, 0] & 0xF], lut[packed[1, 0] >> 4]]
        np.testing.assert_allclose(dec, [1.0, 0.0, 0.0, 1.0], atol=1e-6)


class TestDispatch:
    def test_content_hash_cache_semantics(self):
        # paper:§5.6 — identical structure hits; changing shape/option misses
        cache = dispatch.ProgramCache()
        f = lambda x: x * 2  # noqa: E731
        x8 = jnp.ones((8,))
        x16 = jnp.ones((16,))
        cache.compile(f, x8)
        cache.compile(f, x8)
        assert cache.stats.hits == 1 and cache.stats.misses == 1
        cache.compile(f, x16)                 # shape change -> new key
        assert cache.stats.misses == 2
        cache.compile(f, x8, options="opt-level=3")
        assert cache.stats.misses == 3
        cache.compile(f, x8, force_recompilation=True)
        assert cache.stats.misses == 4        # defeats the warm start

    def test_execution_stream_serializes(self):
        cache = dispatch.ProgramCache()
        compiled, key = cache.compile(lambda x: x + 1, jnp.zeros((4,)))
        stream = dispatch.ExecutionStream(cache)
        stream.encode_operation(compiled, (jnp.zeros((4,)),), key)
        stream.encode_operation(compiled, (jnp.ones((4,)),), key)
        outs = stream.execute_sync()
        assert len(outs) == 2 and float(outs[1][0]) == 2.0
        assert len(stream.records) == 2
        # type-stable: a single encoded op still comes back as a list, and
        # each record charges the target's costmodel dispatch floor
        stream.encode_operation(compiled, (jnp.zeros((4,)),), key)
        outs = stream.execute_sync()
        assert isinstance(outs, list) and len(outs) == 1
        rec = stream.records[-1]
        assert rec.floor_s == stream.floor_s > 0.0
        assert rec.work_s == max(0.0, rec.wall_s - rec.floor_s)

    def test_resident_state_never_recrosses_host(self):
        # paper:§2.6 — output buffer aliases the next input buffer: the
        # donated argument's buffer is reused (XLA donation)
        step = dispatch.resident(lambda s, x: (s + x, s.sum()), 0)
        s = jnp.zeros((4,))
        for i in range(4):
            s, total = step(s, jnp.ones((4,)))
        # resident accumulator returns 1,2,3,4-like progression (paper §2.6)
        assert float(total) == 3 * 4  # sum before last add


class TestRoofline:
    def test_parse_post_optimization_format(self):
        hlo = """
  %ar = f32[256,1024]{1,0} all-reduce(%x), replica_groups=[16,32]<=[512], to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(%y), replica_groups=[32,16]<=[512], dimensions={0}
  %rs = bf16[32,128]{1,0} reduce-scatter(%z), replica_groups=[32,16]<=[512], dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""
        st = roofline.parse_collectives(hlo)
        assert st.bytes_by_kind["all-reduce"] == 256 * 1024 * 4
        # all-gather operand = result / group_size
        assert st.bytes_by_kind["all-gather"] == 64 * 128 * 4 / 16
        # reduce-scatter operand = result * group_size
        assert st.bytes_by_kind["reduce-scatter"] == 32 * 128 * 2 * 16
        assert st.bytes_by_kind["collective-permute"] == 8 * 8 * 2

    def test_parse_real_compiled_module(self):
        # a psum under 2 fake... single device: no collectives, parse = 0
        f = jax.jit(lambda x: x @ x.T)
        hlo = f.lower(jnp.ones((8, 8))).compile().as_text()
        st = roofline.parse_collectives(hlo)
        assert st.total_bytes == 0.0

    def test_ridge_point(self):
        # paper:T9.2 — I* = P/B ~ 141 FLOP/byte on the M1
        assert hal.ANE_M1.ridge_flop_per_byte == pytest.approx(141.2, abs=1.0)
        # v5e: 197e12/819e9 ~ 241
        assert hal.TPU_V5E.ridge_flop_per_byte == pytest.approx(240.5, abs=1.0)

    def test_attainable_rate_two_regimes(self):
        t = hal.ANE_M1
        assert roofline.attainable_rate(1000.0, t) == t.peak_flops
        assert roofline.attainable_rate(10.0, t) == 10.0 * t.hbm_bandwidth

    def test_dispatch_floor_dominates_small_ops(self):
        # paper:§9.3 — below the floor, neither the op nor its size matters
        t = hal.ANE_M1
        t_small, _ = roofline.dispatch_time(1e6, 1e4, t)
        assert t_small == pytest.approx(t.dispatch_floor_s, rel=0.01)


class TestCostModel:
    @pytest.mark.parametrize("arch,expected_b", [
        ("tinyllama-1.1b", 1.1), ("granite-8b", 8.0), ("phi4-mini-3.8b", 3.8),
        ("dbrx-132b", 132.0), ("deepseek-v3-671b", 671.0),
        ("chameleon-34b", 34.0),
    ])
    def test_param_counts_match_published(self, arch, expected_b):
        got = costmodel.param_count(configs.get_config(arch)) / 1e9
        assert got == pytest.approx(expected_b, rel=0.15), got

    def test_moe_active_far_below_total(self):
        cfg = configs.get_config("deepseek-v3-671b")
        total = costmodel.param_count(cfg)
        active = costmodel.active_param_count(cfg)
        assert active / total < 0.08   # ~37B / 671B

    def test_model_flops_6nd(self):
        cfg = configs.get_config("tinyllama-1.1b")
        sh = configs.SHAPES["train_4k"]
        mf = costmodel.model_flops(cfg, sh)
        n = costmodel.active_param_count(cfg)
        assert mf == pytest.approx(6.0 * n * sh.global_batch * sh.seq_len)
