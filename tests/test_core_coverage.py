"""Direct coverage for core/compression.py round-trips and core/segmenter.py
partition invariants — the two modules the technique tests exercised only
sideways (through error bounds and cost comparisons) before."""

import numpy as np
import pytest

from repro import configs
from repro.core import compression as cp
from repro.core import costmodel, hal
from repro.core import segmenter as sg
from repro.core.hal import WeightForm

rng = np.random.default_rng(7)


class TestCompressionRoundTrip:
    """palettize -> pack -> unpack -> dequantize comes back shape- and
    value-faithful for every form, including the non-obvious layouts."""

    @pytest.mark.parametrize("form", [WeightForm.FP16, WeightForm.INT8,
                                      WeightForm.INT4_PALETTE,
                                      WeightForm.SPARSE, WeightForm.BLOCKWISE])
    def test_decode_restores_shape_and_dtype(self, form):
        w = rng.normal(size=(64, 48)).astype(np.float32)
        p = cp.encode(form, w)
        out = np.asarray(cp.decode(p), np.float32)
        assert out.shape == w.shape
        assert np.all(np.isfinite(out))

    def test_int8_round_trip_is_quantization_exact(self):
        # values already on the int8 grid survive the trip bit-exactly
        scale = 0.5
        q = rng.integers(-127, 128, size=(32, 16)).astype(np.float32)
        q[0, :] = 127      # pin per-channel max so the encoder recovers scale
        w = q * scale
        out = np.asarray(cp.decode(cp.encode(WeightForm.INT8, w)), np.float32)
        np.testing.assert_allclose(out, w)    # bit-exact: on-grid, fp16-safe

    def test_palette_round_trip_on_palettized_weight(self):
        # a weight drawn FROM a 16-entry codebook round-trips to codebook
        # values exactly (up to fp16 storage of the lut)
        code = np.linspace(-1.0, 1.0, 16).astype(np.float32)
        w = rng.choice(code, size=(40, 24)).astype(np.float32)
        p = cp.encode(WeightForm.INT4_PALETTE, w)
        out = np.asarray(cp.decode(p), np.float32)
        np.testing.assert_allclose(out, w, atol=2e-3)
        # packed payload is half a byte per element (+ codebook)
        assert p.payload["packed"].size == (w.size + 1) // 2
        assert p.payload["lut"].size == 16

    def test_palette_low_nibble_first_layout(self):
        # the worked-example layout (paper §7.2): index[0] in the low nibble
        w = np.array([1.0, 0.0, 0.0, 1.0], np.float32).reshape(4, 1)
        p = cp.encode(WeightForm.INT4_PALETTE, w)
        packed = p.payload["packed"]
        lut = np.asarray(p.payload["lut"], np.float32)
        assert lut[packed[0] & 0xF] == pytest.approx(1.0, abs=1e-3)
        assert lut[packed[0] >> 4] == pytest.approx(0.0, abs=1e-3)

    def test_sparse_round_trip_keeps_survivors_zeroes_rest(self):
        w = rng.normal(size=(32, 8)).astype(np.float32)
        p = cp.encode(WeightForm.SPARSE, w)
        out = np.asarray(cp.decode(p), np.float32)
        pairs_in = w.reshape(-1, 2, 8)
        pairs_out = out.reshape(-1, 2, 8)
        keep_hi = np.abs(pairs_in[:, 1]) > np.abs(pairs_in[:, 0])
        survivor_in = np.where(keep_hi, pairs_in[:, 1], pairs_in[:, 0])
        survivor_out = np.where(keep_hi, pairs_out[:, 1], pairs_out[:, 0])
        dropped_out = np.where(keep_hi, pairs_out[:, 0], pairs_out[:, 1])
        np.testing.assert_allclose(survivor_out, survivor_in, atol=2e-2)
        assert np.all(dropped_out == 0.0)
        # exactly one survivor per pair -> exactly 50% density
        assert cp.fraction_zero(out) == pytest.approx(0.5)

    def test_blockwise_round_trip_block_structure(self):
        # per-block scales: a block with tiny values keeps fine resolution
        # even when another block holds a huge outlier
        w = rng.normal(size=(64, 8)).astype(np.float32) * 0.01
        w[40, 3] = 100.0                      # outlier in block 1 of column 3
        p = cp.encode(WeightForm.BLOCKWISE, w)
        out = np.asarray(cp.decode(p), np.float32)
        np.testing.assert_allclose(out[:32], w[:32], atol=1e-3)   # clean block
        assert out[40, 3] == pytest.approx(100.0, rel=0.02)

    def test_stored_bytes_ordering_matches_hal_table(self):
        # int4 < int8 ~ blockwise < fp16 stored footprint
        w = rng.normal(size=(256, 128)).astype(np.float32)
        stored = {f: cp.encode(f, w).stored_bytes
                  for f in (WeightForm.INT4_PALETTE, WeightForm.INT8,
                            WeightForm.BLOCKWISE, WeightForm.FP16)}
        assert stored[WeightForm.INT4_PALETTE] < stored[WeightForm.INT8]
        assert stored[WeightForm.INT8] <= stored[WeightForm.BLOCKWISE]
        assert stored[WeightForm.BLOCKWISE] < stored[WeightForm.FP16]


class TestSegmenterInvariants:
    """Partition invariants of the Dijkstra placement (paper §5.3)."""

    def _ops(self, n=8):
        cfg = configs.get_config("tinyllama-1.1b")
        return costmodel.op_graph(cfg, configs.SHAPES["decode_32k"])[:n]

    def test_placement_covers_every_op_in_order(self):
        ops = self._ops()
        p = sg.place(ops, sg.ANE_BACKENDS)
        assert p.ops == [o.name for o in ops]
        assert len(p.backend) == len(ops)
        valid = {b.name for b in sg.ANE_BACKENDS}
        assert set(p.backend) <= valid

    def test_segments_partition_the_op_list(self):
        # segments are a partition: counts sum to n, runs are maximal
        ops = self._ops()
        p = sg.place(ops, sg.ANE_BACKENDS)
        segs = p.segments
        assert sum(c for _, c in segs) == len(ops)
        for (b1, _), (b2, _) in zip(segs, segs[1:]):
            assert b1 != b2, "adjacent segments must differ (maximal runs)"

    def test_cost_is_sum_of_op_costs_plus_boundaries(self):
        ops = self._ops(6)
        launch, xfer = 0.23e-3, 24e9
        p = sg.place(ops, sg.ANE_BACKENDS, launch_penalty=launch,
                     transfer_bytes_per_s=xfer)
        by_name = {b.name: b for b in sg.ANE_BACKENDS}
        expect = launch + by_name[p.backend[0]].op_cost(ops[0])
        for i in range(1, len(ops)):
            expect += by_name[p.backend[i]].op_cost(ops[i])
            if p.backend[i] != p.backend[i - 1]:
                expect += launch + ops[i - 1].bytes / xfer
        assert p.cost == pytest.approx(expect, rel=1e-9)

    def test_rejected_op_never_assigned(self):
        backends = (
            sg.Backend("ane", 12e12, 51e9, rejects=frozenset({"mlp"})),
            sg.Backend("gpu", 2.6e12, 230e9),
        )
        p = sg.place(self._ops(), backends)
        for name, b in zip(p.ops, p.backend):
            if "mlp" in name:
                assert b == "gpu"

    def test_all_ops_rejected_raises(self):
        only = (sg.Backend("ane", 12e12, 51e9, rejects=frozenset({"embed"})),)
        with pytest.raises(ValueError, match="no feasible placement"):
            sg.place(self._ops(2), only)

    def test_single_op_graph(self):
        ops = self._ops(1)
        p = sg.place(ops, sg.ANE_BACKENDS)
        assert len(p.backend) == 1 and p.segments == [(p.backend[0], 1)]

    def test_empty_graph(self):
        p = sg.place([], sg.ANE_BACKENDS)
        assert p.ops == [] and p.cost == 0.0

    def test_zero_transfer_cost_matches_greedy_per_op_optimum(self):
        # with free boundaries (and no launch penalty), the shortest path is
        # exactly per-op argmin — the partition degenerates as theory says
        ops = self._ops(6)
        p = sg.place(ops, sg.ANE_BACKENDS, launch_penalty=0.0,
                     transfer_bytes_per_s=float("inf"))
        for op, b_name in zip(ops, p.backend):
            best = min(sg.ANE_BACKENDS, key=lambda b: b.op_cost(op))
            assert b_name == best.name

    def test_matches_brute_force_on_tpu_backends(self):
        ops = self._ops(6)
        d = sg.place(ops, sg.TPU_BACKENDS)
        b = sg.brute_force(ops, sg.TPU_BACKENDS)
        assert d.cost == pytest.approx(b.cost, rel=1e-12)
