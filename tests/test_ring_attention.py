"""Ring-attention serving-path suite (tier: chunked prefill/long context).

`parallel.ring_attention` is the context-parallel prefill route: the
sequence axis shards over the mesh's "model" axis, KV blocks rotate around
the ring (`ppermute`) while every rank accumulates its local queries'
online softmax. These tests pin the serve-facing wrapper `ring_prefill`:

  * numerical parity against the monolithic flash path
    (`chunked_attention`) at serve shapes — ring multiples, chunk
    boundaries, ragged tails that need padding, GQA head groups;
  * the degenerate ring (null context / 1-rank model axis) falls back to
    the flash path *exactly* (bit-identical, no padding round trip);
  * routed end to end: a mesh scheduler with `ring_prefill_min` set emits
    greedy token streams identical to the single-device run.

Multi-device cases force 8 CPU devices via XLA_FLAGS in a subprocess-free
way only when the session already has them; otherwise they skip loudly.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import hal
from repro.core.dispatch import ExecutionStream, ProgramCache
from repro.launch.scheduler import Request, ServeConfig, build_scheduler
from repro.models.attention import chunked_attention
from repro.models.model import build_model
from repro.parallel.ctx import ParallelContext
from repro.parallel.ring_attention import ring_prefill

V5E = hal.get_target("tpu-v5e")

_multi = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@functools.lru_cache(maxsize=None)
def _ring_ctx(min_tokens=1):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    return dataclasses.replace(ParallelContext(mesh=mesh),
                               ring_prefill_min=min_tokens)


def _qkv(s, *, b=2, h=8, kvh=4, dh=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# Parity at serve shapes
# ---------------------------------------------------------------------------

@_multi
@pytest.mark.parametrize("s", [64,    # ring multiple
                               96,    # prefill-chunk boundary (12 x 8)
                               17,    # ragged: pads 17 -> 20 on a 4-ring
                               23,    # prime, maximal padding
                               4])    # one token per rank
def test_ring_prefill_matches_flash(s):
    q, k, v = _qkv(s)
    ref = chunked_attention(q, k, v, causal=True)
    out = ring_prefill(q, k, v, _ring_ctx(), causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@_multi
def test_ring_prefill_scale_override():
    q, k, v = _qkv(32)
    ref = chunked_attention(q, k, v, causal=True, scale=0.5)
    out = ring_prefill(q, k, v, _ring_ctx(), causal=True, scale=0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_degenerate_ring_is_exact_fallback():
    """Null context and 1-rank rings take the flash path bit-identically:
    no padding, no shard_map, no ulp drift."""
    q, k, v = _qkv(23)
    ref = np.asarray(chunked_attention(q, k, v, causal=True))
    np.testing.assert_array_equal(
        np.asarray(ring_prefill(q, k, v, None, causal=True)), ref)
    np.testing.assert_array_equal(
        np.asarray(ring_prefill(q, k, v, ParallelContext(mesh=None),
                                causal=True)), ref)


# ---------------------------------------------------------------------------
# Routed end to end through the scheduler
# ---------------------------------------------------------------------------

def _serve_tokens(ctx, lens=(24, 33, 17), gen=5):
    cfg = configs.get_smoke("tinyllama-1.1b")
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=(L,)).astype(np.int32),
                    max_new_tokens=gen) for i, L in enumerate(lens)]
    config = ServeConfig(schedule="continuous", max_len=max(lens) + gen,
                         n_slots=len(lens),
                         stream=ExecutionStream(ProgramCache(), target=V5E),
                         ctx=ctx)
    sched = build_scheduler(config, model, params, cfg)
    return {r.rid: r.tokens for r in sched.run(reqs)}


@_multi
def test_ring_routed_serve_matches_single_device():
    """`ring_prefill_min` on a live mesh: every monolithic prefill of >=
    min tokens routes through the ring, and greedy streams stay identical
    to the single-device scheduler (argmax survives the ulp drift at smoke
    scale; this is the same parity bar every serve schedule meets)."""
    single = _serve_tokens(ParallelContext(mesh=None))
    ringed = _serve_tokens(_ring_ctx(min_tokens=8))
    for rid in single:
        np.testing.assert_array_equal(single[rid], ringed[rid])


@_multi
def test_ring_off_by_default_on_mesh():
    """Without opting in, a mesh context keeps ring routing OFF —
    `ring_prefill_min` defaults to None, protecting the bit-parity
    guarantee mesh serving CI gates."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelContext(mesh=mesh)
    assert ctx.ring_prefill_min is None
    single = _serve_tokens(ParallelContext(mesh=None))
    meshed = _serve_tokens(ctx)
    for rid in single:
        np.testing.assert_array_equal(single[rid], meshed[rid])
