"""Multi-device tests: run in subprocesses with 8 forced host devices.

Covers: EP MoE vs dense-reference parity, sharded train step, GPipe pipeline
parity, compressed all-reduce, elastic restore onto a different mesh."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run8(body: str, timeout=600) -> str:
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
              + textwrap.dedent(body))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_moe_ep_matches_dense_reference():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro import configs
        from repro.models import moe as moe_lib
        from repro.parallel.ctx import ParallelContext
        import dataclasses

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ParallelContext(mesh=mesh)
        cfg = dataclasses.replace(configs.get_smoke("dbrx-132b"),
                                  moe_capacity_factor=8.0)   # no drops
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 16, cfg.d_model)), jnp.float32)

        dense, aux_d = moe_lib.moe_dense(cfg, p, x)
        ep_fn = jax.jit(lambda p, x: moe_lib.moe_ep(cfg, p, x, ctx))
        ep, aux_e = ep_fn(p, x)
        np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                                   rtol=2e-4, atol=2e-4)
        # aux: EP averages the per-rank balance loss over token slices, the
        # dense path computes it globally — same signal, small relative gap
        assert abs(float(aux_d) - float(aux_e)) / max(float(aux_d), 1e-6) < 0.3
        print("EP==DENSE OK")
    """)
    assert "EP==DENSE OK" in out


@pytest.mark.slow
def test_moe_ep_capacity_drops_are_bounded():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro import configs
        from repro.models import moe as moe_lib
        from repro.parallel.ctx import ParallelContext
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ParallelContext(mesh=mesh)
        cfg = dataclasses.replace(configs.get_smoke("dbrx-132b"),
                                  moe_capacity_factor=1.0)
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 16, cfg.d_model)), jnp.float32)
        dense, _ = moe_lib.moe_dense(cfg, p, x)
        ep, _ = jax.jit(lambda p, x: moe_lib.moe_ep(cfg, p, x, ctx))(p, x)
        # with capacity 1.0 some copies drop; outputs stay close in norm
        rel = float(jnp.linalg.norm(ep - dense) / jnp.linalg.norm(dense))
        assert rel < 0.5, rel
        print("EP-drops OK", rel)
    """)
    assert "EP-drops OK" in out


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models.model import build_model
        from repro.optim import adamw
        from repro.parallel.ctx import ParallelContext
        from repro.parallel import sharding as shard_lib
        from repro.launch.train import make_train_step

        cfg = configs.get_smoke("tinyllama-1.1b")
        batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                 "targets": jnp.ones((8, 32), jnp.int32)}
        opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)

        def run(ctx):
            m = build_model(cfg, ctx)
            params = m.init(jax.random.PRNGKey(0))
            opt = adamw.init_state(opt_cfg, params)
            step = make_train_step(m, opt_cfg)
            if ctx.active:
                ps = shard_lib.param_specs(params, ctx)
                os_ = shard_lib.opt_state_specs(opt, ps, ctx)
                bs = shard_lib.batch_specs(batch, ctx)
                fn = jax.jit(step, in_shardings=(
                    jax.tree.map(lambda s: jax.sharding.NamedSharding(ctx.mesh, s), ps),
                    jax.tree.map(lambda s: jax.sharding.NamedSharding(ctx.mesh, s), os_),
                    jax.tree.map(lambda s: jax.sharding.NamedSharding(ctx.mesh, s), bs)))
            else:
                fn = jax.jit(step)
            p2, o2, metrics = fn(params, opt, batch)
            return float(metrics["loss"]), p2

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        loss_sharded, p_sh = run(ParallelContext(mesh=mesh))
        loss_single, p_si = run(ParallelContext(mesh=None))
        assert abs(loss_sharded - loss_single) < 2e-2, (loss_sharded, loss_single)
        # updated params agree across the two executions
        for a, b in zip(jax.tree.leaves(p_sh), jax.tree.leaves(p_si)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-2)
        print("SHARDED==SINGLE OK", loss_sharded)
    """)
    assert "SHARDED==SINGLE OK" in out


def test_gpipe_matches_sequential():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import gpipe, split_stages, bubble_fraction
        mesh = jax.make_mesh((4,), ("stage",))
        L, S, M, mb, d = 8, 4, 6, 2, 16
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (L, d, d)) * 0.3

        def layer(w, x):
            return jnp.tanh(x @ w)

        def stage_fn(slab, x):            # slab: (L/S, d, d)
            def body(x, w):
                return layer(w, x), None
            x, _ = jax.lax.scan(body, x, slab)
            return x

        xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        pp = gpipe(stage_fn, mesh)
        got = jax.jit(pp)(split_stages(Ws, S), xs)

        ref = xs
        for i in range(L):
            ref = jax.vmap(lambda x: layer(Ws[i], x))(ref)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        assert abs(bubble_fraction(S, M) - 3/9) < 1e-9
        print("GPIPE OK")
    """)
    assert "GPIPE OK" in out


def test_compressed_psum_close_to_exact():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 1024)),
                        jnp.float32)
        exact = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                          in_specs=P("data"), out_specs=P("data"))(x)
        comp = shard_map(lambda v: compressed_psum(v, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P("data"))(x)
        rel = float(jnp.linalg.norm(comp - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, rel
        print("COMPRESSED_PSUM OK", rel)
    """)
    assert "COMPRESSED_PSUM OK" in out


def test_elastic_restore_onto_new_mesh():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.runtime.elastic import plan_rescale, build_mesh, make_placer
        from jax.sharding import PartitionSpec as P

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            tree = {"w": jnp.arange(64.0).reshape(8, 8)}
            mgr.save(1, tree)
            plan = plan_rescale(8, 4, model_parallel=2)
            assert plan.new_mesh_shape == (2, 2)
            mesh = jax.make_mesh((2, 2), ("data", "model"),
                                 devices=jax.devices()[:4])
            placer = make_placer(mesh, lambda path, shape: P(None, "model"))
            restored, step = mgr.restore(tree, placer=placer)
            assert step == 1
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.arange(64.0).reshape(8, 8))
            assert len(restored["w"].sharding.device_set) == 4
            print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


def test_ring_attention_matches_reference():
    out = run8("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.ring_attention import ring_attention
        from repro.models.attention import chunked_attention
        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        b, s, h, kvh, d = 2, 64, 4, 2, 32
        q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
        got = jax.jit(lambda q, k, v: ring_attention(q, k, v, mesh))(q, k, v)
        ref = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
        # non-causal too
        got2 = jax.jit(lambda q, k, v: ring_attention(q, k, v, mesh,
                                                      causal=False))(q, k, v)
        ref2 = chunked_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2),
                                   rtol=2e-3, atol=2e-3)
        print("RING OK")
    """)
    assert "RING OK" in out
