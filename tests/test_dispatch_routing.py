"""KernelDispatcher routing decisions + serving-residency regressions.

Three batteries:

  * a property-based sweep (hypothesis): for ANY (registered kernel, HAL
    target, activation dtype) the resolved route must be *legal* — the
    chosen kernel is registered, a native route passes every capability
    gate, and the oracle fires exactly when one gate fails (including the
    unknown-dtype and op-floor edge cases);
  * decode-step residency: the KV cache stays donated/resident across N
    dispatches — shapes and dtypes unchanged, and the decode program's
    content hash is stable, so no step forces a recompile or a host
    round-trip through a new buffer;
  * weight-form tags survive the checkpoint boundary.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch, hal
from repro.kernels import registry
from repro.launch.serve import _merge_prefill
from repro.models.model import build_model
from repro.optim.compression import (compress_model_params,
                                     weight_form_census)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # the exhaustive sweep below still runs
    HAVE_HYPOTHESIS = False

DTYPES = ("float32", "bfloat16", "float16", "int8", "float64", "int32")


def _dtype_surface(spec) -> set[str]:
    return {jnp.dtype(d).name for d in spec.dtypes}


def _check_route_legal(name: str, target_name: str, dtype: str) -> None:
    """Any (kernel, target, dtype) cell resolves to a registered kernel
    whose native leg is capability-legal, with oracle fallback exactly when
    a gate fails."""
    target = hal.get_target(target_name)
    route = dispatch.KernelDispatcher(target).resolve(name, dtype)
    spec = registry.get(route.kernel)              # registered, or KeyError
    assert route.kernel == name
    assert route.target == target.name
    assert route.backend in ("pallas", "oracle")

    dtype_ok = dtype in _dtype_surface(spec)
    op_ok = target.attests(spec.capability_op) and \
        target.reaches(spec.capability_op)
    stream_ok = spec.weight_form is None or target.streams(spec.weight_form)
    datapath_ok = target.supports_dtype(dtype)
    all_gates = dtype_ok and op_ok and stream_ok and datapath_ok

    if route.native:
        assert all_gates, (route, dtype_ok, op_ok, stream_ok, datapath_ok)
        assert route.reason == ""
    else:
        # fallback fires exactly when gated, and says why
        assert not all_gates, route
        assert route.reason


class TestRoutingExhaustive:
    """The full (kernel x target x dtype) cube, deterministically — the
    matrix is small enough to enumerate, so no cell ever goes unchecked."""

    @pytest.mark.parametrize("target_name", sorted(hal.TARGETS))
    def test_every_cell_is_legal(self, target_name):
        for name in registry.names():
            for dtype in DTYPES:
                _check_route_legal(name, target_name, dtype)

    def test_matrix_rows_agree_with_resolve(self):
        """The census (`matrix()`) and point resolution never disagree."""
        for target_name in sorted(hal.TARGETS):
            d = dispatch.KernelDispatcher(hal.get_target(target_name))
            for dtype in DTYPES[:3]:
                by_name = {r.kernel: r for r in d.matrix(dtype)}
                for name in registry.names():
                    assert by_name[name] == d.resolve(name, dtype)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestRoutingProperty:
    """Property form of the same invariant (hypothesis shrinks failures to
    a minimal cell); extends past the pinned dtype list via dtype names
    drawn from jnp itself."""

    if HAVE_HYPOTHESIS:
        @settings(max_examples=200, deadline=None)
        @given(name=st.sampled_from(registry.names()),
               target_name=st.sampled_from(sorted(hal.TARGETS)),
               dtype=st.sampled_from(DTYPES + ("uint8", "int16", "float64")))
        def test_route_is_legal(self, name, target_name, dtype):
            _check_route_legal(name, target_name, dtype)


class TestRoutingEdges:
    # -- pinned edge cells of the op-by-device matrix -----------------------
    def test_unknown_dtype_routes_to_oracle(self):
        route = dispatch.KernelDispatcher(hal.TPU_V5E).resolve(
            "anemm", jnp.int8)
        assert route.backend == "oracle"
        assert "dtype" in route.reason

    def test_op_floor_gates_decode_attention_on_m1(self):
        # gather is absent from the H13 op table (hal.T4.1)
        route = dispatch.KernelDispatcher(hal.ANE_M1).resolve(
            "decode_attention", jnp.float32)
        assert route.backend == "oracle"
        assert "gather" in route.reason

    def test_non_native_dtype_gates_on_ane(self):
        # the ANE datapath is fp16-only: bf16 activations must fold back
        route = dispatch.KernelDispatcher(hal.ANE_M1).resolve(
            "anemm", jnp.bfloat16)
        assert route.backend == "oracle"

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError):
            dispatch.KernelDispatcher(hal.TPU_V5E).resolve("nope")


# ---------------------------------------------------------------------------
# Decode residency: donated KV caches across N dispatches
# ---------------------------------------------------------------------------


def _tree_spec(tree):
    return jax.tree.map(lambda a: (a.shape, str(a.dtype)), tree)


class TestDecodeResidency:
    def test_kv_cache_resident_across_dispatches(self):
        """N decode steps against a donated cache: the cache pytree keeps
        its exact shapes/dtypes (the buffer is rebound, never reshaped or
        host-copied) and the decode program's content hash is stable — no
        step requires a new compile."""
        cfg = configs.get_smoke("tinyllama-1.1b")
        disp = dispatch.KernelDispatcher(hal.TPU_V5E)
        model = build_model(cfg, dispatcher=disp)
        params = model.init(jax.random.PRNGKey(0))
        b, s, n_steps = 2, 16, 6
        batch = {"tokens": jnp.ones((b, s), jnp.int32)}
        pf_caches, lg = jax.jit(model.prefill)(params, batch)
        caches = _merge_prefill(model, model.init_cache(b, s + n_steps + 1),
                                pf_caches, s)

        spec0 = _tree_spec(caches)
        tok = jnp.ones((b, 1), jnp.int32)
        pos0 = jnp.full((b,), s, jnp.int32)
        key0 = dispatch.content_hash(model.decode_step,
                                     (params, caches, tok, pos0))

        cache_mgr = dispatch.ProgramCache()
        decode, _ = cache_mgr.compile(model.decode_step, params, caches, tok,
                                      pos0, jit_kwargs={"donate_argnums": (1,)})
        for i in range(n_steps):
            pos = jnp.full((b,), s + i, jnp.int32)
            caches, lg = decode(params, caches, tok, pos)
            tok = jnp.argmax(lg[:, -1, : cfg.vocab], -1).astype(
                jnp.int32)[:, None]
            # resident-state invariant: the updated cache is bit-compatible
            # with the donated slot — same structure, shapes, dtypes
            assert _tree_spec(caches) == spec0
        # content-hash stability: the program for step N is the program for
        # step 0 — nothing about the evolved cache forces a recompile
        assert dispatch.content_hash(
            model.decode_step, (params, caches, tok, pos)) == key0
        assert not cache_mgr.is_new_compile_required(
            model.decode_step, params, caches, tok, pos)
        assert cache_mgr.stats.misses == 1
        # and the cache really advanced (the steps were not no-ops)
        pos_rows = np.asarray(caches[0]["sub0"]["pos"])
        assert (pos_rows >= s).any()

    def test_content_hash_distinguishes_shapes(self):
        cfg = configs.get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        b1 = {"tokens": jnp.ones((2, 16), jnp.int32)}
        b2 = {"tokens": jnp.ones((2, 24), jnp.int32)}
        k1 = dispatch.content_hash(model.prefill, (params, b1))
        k2 = dispatch.content_hash(model.prefill, (params, b2))
        assert k1 != k2

    def test_content_hash_stable_across_traces(self):
        """Regression: custom_vjp closures print object addresses into the
        jaxpr; the hash must scrub them or every retrace is a cache miss."""
        cfg = configs.get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
        keys = {dispatch.content_hash(model.prefill, (params, batch))
                for _ in range(3)}
        assert len(keys) == 1


# ---------------------------------------------------------------------------
# Weight-form tags across the checkpoint boundary
# ---------------------------------------------------------------------------


class TestWeightFormPersistence:
    def test_checkpoint_round_trips_packed_params(self, tmp_path):
        from repro.checkpoint.checkpoint import CheckpointManager

        cfg = configs.get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cparams = compress_model_params(params, "sparse")
        census = weight_form_census(cparams)
        assert census and set(census.values()) == {"sparse"}

        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, cparams)
        restored, step = mgr.restore(cparams)
        assert step == 7
        rcensus = weight_form_census(restored)
        assert rcensus == census
        for a, b in zip(jax.tree.leaves(cparams), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_form_mismatch_is_rejected(self, tmp_path):
        from repro.checkpoint.checkpoint import CheckpointManager

        cfg = configs.get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, compress_model_params(params, "sparse"))
        with pytest.raises(ValueError, match="weight form"):
            mgr.restore(compress_model_params(params, "int4_palette"))
        # a dense-saved checkpoint into a packed template is also a form
        # mismatch, not a bare missing-key crash
        mgr.save(2, params)
        with pytest.raises(ValueError, match="weight form"):
            mgr.restore(compress_model_params(params, "sparse"), step=2)

    def test_restore_placer_never_sees_form_markers(self, tmp_path):
        """Elastic restore device_puts every array through a placer; the
        weight-form marker is a host-side string and must bypass it."""
        from repro.checkpoint.checkpoint import CheckpointManager

        cfg = configs.get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cparams = compress_model_params(params, "int4_palette")
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, cparams)

        seen = []

        def placer(path, arr):
            seen.append(path)
            assert arr.dtype.kind != "U", f"string marker reached placer: {path}"
            return jnp.asarray(arr)

        restored, _ = mgr.restore(cparams, placer=placer)
        assert seen
        assert weight_form_census(restored) == weight_form_census(cparams)

    def test_planner_spares_non_matmul_leaves(self):
        cfg = configs.get_smoke("dbrx-132b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cparams = compress_model_params(params, "int4_palette")
        packed = weight_form_census(cparams)
        assert packed, "MoE config must pack expert banks"
        # routing tables, norms and the embedding gather table stay dense
        for path in packed:
            assert "router" not in path
            assert "scale" not in path and "ln" not in path.split("/")[-1]
            assert not path.endswith("table")
