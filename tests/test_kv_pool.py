"""Paged KV pool suite (tier: prefix cache).

Three layers, cheapest first:

  * **pool invariants** — the host-side metadata machine
    (`launch/kv_pool.PagedKVPool`): prefix-trie matching on chained
    token-block hashes, refcounts == live page-table references, LRU
    eviction touches only refcount-0 blocks, copy-on-write never aliases a
    shared block, and releasing a lane frees exactly its exclusively-owned
    blocks. A seeded random-op interpreter drives the same checks two ways:
    deterministic numpy fuzz (always runs) and hypothesis `@given` (CI
    shrinks counterexamples; skipped cleanly when hypothesis is absent).
  * **paged-attention parity** — `paged_decode_attention` against its jnp
    oracle AND bit-identical to the monolithic-slab `decode_attention` over
    ragged page tables (partial last block, permuted arena rows, K=0 empty
    lane, sliding-window masks from the recurrentgemma regression).
  * **device assembly** — pool-inserted prefill blocks gather back
    *bitwise* equal to the prefill cache they came from (the property the
    serve-scheduler prefix parity rides on), and malformed prefill trees
    fail loudly with the tree path before any arena write.

Every invariant check goes through `PagedKVPool.audit()`; to add a pool
invariant, extend `audit` and the op interpreter below picks it up for
free on every fuzzed sequence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import compat
from repro.kernels.flash.decode_attention import (decode_attention,
                                                  decode_attention_ref,
                                                  gather_pages,
                                                  paged_decode_attention,
                                                  paged_decode_attention_ref)
from repro.launch.kv_pool import TIME_MERGE_LEAVES, PagedKVPool
from repro.models.model import build_model

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # optional dep: numpy fuzz still runs
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Pool invariants: deterministic unit coverage
# ---------------------------------------------------------------------------


def toks(*xs):
    return np.asarray(xs, np.int32)


def test_pool_rejects_bad_geometry():
    with pytest.raises(ValueError, match="n_blocks"):
        PagedKVPool(0, 4)
    with pytest.raises(ValueError, match="block_size"):
        PagedKVPool(4, 0)


def test_chain_hash_identifies_whole_prefix():
    """Block k's key must depend on every token before it, not just its
    own: two prompts sharing block-1 *content* but not block-0 must not
    share block 1 (KV at position p is a function of tokens[0..p])."""
    pool = PagedKVPool(8, 2)
    k1, _, _ = pool.reserve(toks(1, 2, 5, 6))
    k2, _, _ = pool.reserve(toks(3, 4, 5, 6))
    assert k1[0] != k2[0] and k1[1] != k2[1]
    pool.audit()
    # same prefix -> same chain, nothing new allocated
    again, new, _ = pool.reserve(toks(1, 2, 5, 6))
    assert again == k1 and new == []
    assert pool.free_blocks() == 8 - 4


def test_match_walks_longest_resident_prefix():
    pool = PagedKVPool(8, 2)
    keys, _, _ = pool.reserve(toks(1, 2, 3, 4, 5, 6))
    assert pool.match(toks(1, 2, 3, 4, 9, 9)) == keys[:2]
    assert pool.match(toks(1, 2, 3, 4, 5, 6, 7)) == keys   # partial tail block
    assert pool.match(toks(9, 9)) == []
    # anchored_match only lands where a prefill boundary snapshot exists
    assert pool.anchored_match(toks(1, 2, 3, 4)) == []
    pool.set_anchor(keys[1], {"h": np.ones(2)})
    assert pool.anchored_match(toks(1, 2, 3, 4, 5, 6)) == keys[:2]
    assert pool.anchored_match(toks(1, 2, 3, 4, 5, 6), limit=3) == keys[:1] \
        or pool.anchored_match(toks(1, 2, 3, 4, 5, 6), limit=3) == []
    assert pool.anchored_match(toks(1, 2, 3, 4, 5, 6), limit=5) == keys[:2]
    pool.audit()


def test_release_frees_exactly_exclusive_blocks():
    """The satellite invariant: freeing a lane returns exactly the blocks
    nobody else references — shared prefix blocks stay resident and
    referenced by the other owner."""
    pool = PagedKVPool(8, 2)
    a, _, _ = pool.reserve(toks(1, 2, 3, 4))
    pool.acquire("r0", a)
    b, _, _ = pool.reserve(toks(1, 2, 9, 9))      # shares block 0 with r0
    pool.acquire("r1", b)
    assert a[0] == b[0] and a[1] != b[1]
    assert pool.refcount(a[0]) == 2 + 2           # two lanes + two children
    pool.audit()
    freed = pool.release("r1")
    assert freed == [b[1]], "release must free exactly the exclusive block"
    assert pool.refcount(a[0]) >= 1               # r0 still holds the prefix
    assert b[1] in pool.resident()                # freed != evicted: cached
    pool.audit()
    freed = pool.release("r0")
    assert set(freed) == {a[1]}                   # a[0] still has children
    pool.audit()
    # double acquire by the same owner is a bug upstream: loud
    pool.acquire("r2", a)
    with pytest.raises(ValueError, match="already holds"):
        pool.acquire("r2", a[:1])
    with pytest.raises(KeyError):
        pool.acquire("r3", ["deadbeef"])


def test_referenced_blocks_never_evicted():
    """Allocation pressure evicts LRU refcount-0 blocks only; when every
    block is referenced the pool reports exhaustion instead of stealing."""
    pool = PagedKVPool(4, 2)
    a, _, _ = pool.reserve(toks(1, 2, 3, 4))
    pool.acquire("r0", a)
    b, _, _ = pool.reserve(toks(5, 6, 7, 8))      # fills the pool
    keys, new, first = pool.reserve(toks(9, 9, 8, 8))   # must evict b's chain
    assert len(keys) == 2 and len(new) == 2
    assert pool.stats["evictions"] == 2
    assert all(k in pool.resident() for k in a), \
        "a referenced block was evicted"
    pool.audit()
    # now everything is referenced: reserve comes back empty-handed
    pool.acquire("r1", keys)
    full, none_new, _ = pool.reserve(toks(4, 4, 4, 4))
    assert full == [] and none_new == []
    pool.audit()


def test_lru_eviction_cascades_to_parents():
    """A parent stays pinned by resident children (refcount counts them);
    evicting the leaf re-enters the parent into the LRU list."""
    pool = PagedKVPool(2, 2)
    a, _, _ = pool.reserve(toks(1, 2, 3, 4))
    assert pool.refcount(a[0]) == 1 and pool.refcount(a[1]) == 0
    b, new, _ = pool.reserve(toks(7, 7, 8, 8))    # evicts leaf, then parent
    assert len(b) == len(new) == 2
    assert pool.stats["evictions"] == 2
    assert pool.resident() == set(b)
    pool.audit()


def test_cow_write_never_aliases_shared_blocks():
    """Divergence at a shared block lands on a fresh arena row; the shared
    row is untouched and still referenced by the other lane."""
    pool = PagedKVPool(8, 2)
    a, _, _ = pool.reserve(toks(1, 2, 3, 4))
    pool.acquire("r0", a)
    pool.fork("r0", "r1")
    assert pool.refcount(a[1]) == 2
    pool.audit()
    shared_bid = pool.bids_of(a[1:])[0]
    new_key = pool.write("r1", 1, toks(8, 9))
    assert new_key is not None and new_key != a[1]
    assert pool.bids_of([new_key])[0] != shared_bid, "CoW aliased the row"
    assert pool.table("r0") == a                  # r0's chain is untouched
    assert pool.table("r1") == [a[0], new_key]
    assert pool.refcount(a[1]) == 1               # r0's reference remains
    pool.audit()
    # content-identical write is a no-op on the chain
    assert pool.write("r0", 1, toks(3, 4)) == a[1]
    assert pool.table("r0") == a
    pool.audit()
    # write truncates the owner's suffix past the divergence point
    pool.release("r1")
    c, _, _ = pool.reserve(toks(1, 2, 3, 4, 5, 6))
    pool.acquire("r2", c)
    k = pool.write("r2", 0, toks(7, 7))
    assert pool.table("r2") == [k]
    pool.audit()
    with pytest.raises(IndexError, match="cannot write"):
        pool.write("r2", 5, toks(1, 2))
    with pytest.raises(ValueError, match="one block"):
        pool.write("r2", 0, toks(1, 2, 3))


def test_cost_aware_eviction_prefers_cheapest_chain():
    """With `evict_cost_fn` set, allocation pressure evicts the refcount-0
    block whose chain is cheapest to re-prefill — not the oldest. A leaf's
    chain cost is depth x block_size tokens, so shallow chains (cheap to
    recreate) go first and deep resident prefixes stay hot."""
    # plain LRU control: the oldest refcount-0 leaf goes, even though its
    # chain is the expensive one to rebuild
    pool = PagedKVPool(3, 2)
    a, _, _ = pool.reserve(toks(1, 2, 3, 4))      # depth-2 chain, oldest
    b, _, _ = pool.reserve(toks(5, 6))            # depth-1 chain, newest
    pool.reserve(toks(7, 7))
    assert a[1] not in pool.resident() and b[0] in pool.resident()
    pool.audit()

    # cost-aware: same pressure evicts the shallow (cheap) chain instead
    pool = PagedKVPool(3, 2, evict_cost_fn=lambda n_tokens: float(n_tokens))
    a, _, _ = pool.reserve(toks(1, 2, 3, 4))
    b, _, _ = pool.reserve(toks(5, 6))
    pool.reserve(toks(7, 7))
    assert b[0] not in pool.resident(), "cheapest chain must evict first"
    assert all(k in pool.resident() for k in a), \
        "the deep (expensive) chain must stay resident"
    assert pool.stats["evictions"] == 1
    pool.audit()


def test_cost_aware_eviction_skips_referenced_blocks():
    pool = PagedKVPool(2, 2, evict_cost_fn=lambda n: float(n))
    a, _, _ = pool.reserve(toks(1, 2))
    pool.acquire("r0", a)
    b, _, _ = pool.reserve(toks(3, 4))
    keys, new, _ = pool.reserve(toks(5, 5))       # b is the only candidate
    assert a[0] in pool.resident() and b[0] not in pool.resident()
    pool.audit()


def test_block_depth_tracks_chain_length():
    """`audit` enforces depth = parent.depth + 1 along every chain — both
    the `reserve` and the `write` allocation paths."""
    pool = PagedKVPool(8, 2)
    keys, _, _ = pool.reserve(toks(1, 2, 3, 4, 5, 6))
    depths = [pool._nodes[k].depth for k in keys]
    assert depths == [1, 2, 3]
    pool.audit()
    pool.acquire("r0", keys)
    k = pool.write("r0", 1, toks(8, 9))           # CoW divergence at idx 1
    assert pool._nodes[k].depth == 2
    pool.audit()


def test_scheduler_re_prefill_cost_feeds_pool():
    """ContinuousSchedule wires its costmodel re-prefill estimate into the
    pool: deeper chains cost more, and every cost includes the dispatch
    floor (evicting anything costs at least one prefill dispatch)."""
    from repro.launch.scheduler import make_scheduler
    from repro.parallel.ctx import ParallelContext

    cfg = configs.get_smoke("tinyllama-1.1b")
    model = build_model(cfg, ParallelContext(mesh=None))
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    sched = make_scheduler("continuous", model, params, cfg, n_slots=2,
                           max_len=32, sampling="greedy", seed=0,
                           prefix_cache=True, prefix_blocks=8,
                           prefix_block_size=4)
    assert sched.pool.evict_cost_fn is not None
    c8, c64 = sched._re_prefill_cost(8), sched._re_prefill_cost(64)
    assert 0 < sched.stream.floor_s < c8 <= c64
    # short chains are weight-streaming-bound (equal cost is fine); by a
    # million tokens the flops term must dominate and the cost must grow
    assert sched._re_prefill_cost(1 << 20) > c64


# ---------------------------------------------------------------------------
# Pool invariants: seeded random-op interpreter (numpy fuzz + hypothesis)
# ---------------------------------------------------------------------------

#: tiny geometry + tiny alphabet on purpose: collisions, shared prefixes and
#: eviction pressure on every run
N_BLOCKS, BLOCK, ALPHABET = 6, 2, 3


def run_ops(ops: list[tuple]) -> None:
    """Interpret (op, *args) tuples against a fresh pool, auditing every
    structural invariant after each op plus the release-exactness and
    CoW-no-alias model checks the audit cannot see."""
    pool = PagedKVPool(N_BLOCKS, BLOCK)
    owners: dict[int, list[str]] = {}
    next_owner = 0
    for op in ops:
        kind = op[0]
        if kind == "insert":
            tokens = np.asarray(op[1], np.int32)
            keys, new, first = pool.reserve(tokens)
            assert keys == pool.match(tokens)[: len(keys)]
            if keys and op[2]:                      # sometimes anchor + own
                pool.set_anchor(keys[-1], None)
                pool.acquire(("o", next_owner), keys)
                owners[next_owner] = keys
                next_owner += 1
        elif kind == "release" and owners:
            oid = sorted(owners)[op[1] % len(owners)]
            keys = owners.pop(oid)
            before = {k: pool.refcount(k) for k in keys}
            freed = pool.release(("o", oid))
            for k in keys:
                assert (k in freed) == (before[k] == 1), \
                    "release freed a shared block or kept an exclusive one"
                assert k in pool.resident()          # freed is not evicted
        elif kind == "fork" and owners:
            oid = sorted(owners)[op[1] % len(owners)]
            pool.fork(("o", oid), ("o", next_owner))
            owners[next_owner] = list(owners[oid])
            next_owner += 1
        elif kind == "write" and owners:
            oid = sorted(owners)[op[1] % len(owners)]
            table = pool.table(("o", oid))
            if table:
                idx = op[2] % len(table)
                old = table[idx]
                shared = pool.refcount(old) > 1
                old_bid = pool.bids_of([old])[0]
                new_key = pool.write(("o", oid), idx,
                                     np.asarray(op[3], np.int32))
                if new_key is not None and new_key != old and shared:
                    assert pool.bids_of([new_key])[0] != old_bid, \
                        "copy-on-write aliased a shared block"
                owners[oid] = pool.table(("o", oid))
        pool.audit()
    # teardown: releasing every owner leaves zero lane references
    for oid in sorted(owners):
        pool.release(("o", oid))
        pool.audit()
    assert all(pool.refcount(k) == sum(
        1 for n in pool.resident() if pool._nodes[n].parent == k)
        for k in pool.resident())


def _ops_from_rng(rng: np.random.Generator, n: int) -> list[tuple]:
    ops = []
    for _ in range(n):
        r = rng.integers(0, 4)
        if r == 0:
            L = int(rng.integers(1, 5)) * BLOCK
            ops.append(("insert",
                        rng.integers(0, ALPHABET, size=(L,)).tolist(),
                        bool(rng.integers(0, 2))))
        elif r == 1:
            ops.append(("release", int(rng.integers(0, 8))))
        elif r == 2:
            ops.append(("fork", int(rng.integers(0, 8))))
        else:
            ops.append(("write", int(rng.integers(0, 8)),
                        int(rng.integers(0, 4)),
                        rng.integers(0, ALPHABET, size=(BLOCK,)).tolist()))
    return ops


@pytest.mark.parametrize("seed", range(20))
def test_pool_random_ops_numpy_fuzz(seed):
    rng = np.random.default_rng(seed)
    run_ops(_ops_from_rng(rng, 40))


if HAVE_HYPOTHESIS:
    block_tokens = st.lists(st.integers(0, ALPHABET - 1),
                            min_size=BLOCK, max_size=BLOCK)
    op_strategy = st.one_of(
        st.tuples(st.just("insert"),
                  st.lists(st.integers(0, ALPHABET - 1), min_size=BLOCK,
                           max_size=4 * BLOCK).map(
                      lambda t: t[: len(t) - len(t) % BLOCK] or t * BLOCK),
                  st.booleans()),
        st.tuples(st.just("release"), st.integers(0, 7)),
        st.tuples(st.just("fork"), st.integers(0, 7)),
        st.tuples(st.just("write"), st.integers(0, 7), st.integers(0, 3),
                  block_tokens),
    )

    @settings(max_examples=60, deadline=None)
    @given(st.lists(op_strategy, max_size=40))
    def test_pool_random_ops_hypothesis(ops):
        run_ops(list(ops))
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                      "(pip install -r requirements-test.txt)")
    def test_pool_random_ops_hypothesis():
        pass


# ---------------------------------------------------------------------------
# Paged attention parity: oracle + monolithic-slab bit-exactness
# ---------------------------------------------------------------------------


def _paged_case(rng, *, b=3, h=4, kvh=2, n=24, bs=8, nb=4, d=16,
                lens=(25, 8, 0)):
    """Ragged paged-decode operands: per-lane lengths cover a partial last
    block, a block-exact lane and an empty (K=0) lane; arena rows are
    permuted so block ids never equal block indices."""
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    k = rng.standard_normal((n, bs, kvh, d)).astype(np.float32)
    v = rng.standard_normal((n, bs, kvh, d)).astype(np.float32)
    perm = rng.permutation(n)
    bt = np.full((b, nb), -1, np.int32)
    pos = np.full((n, bs), -1, np.int32)
    for i, L in enumerate(lens):
        for j in range((L + bs - 1) // bs):
            bid = int(perm[i * nb + j])
            bt[i, j] = bid
            valid = min(bs, L - j * bs)
            pos[bid, :valid] = np.arange(j * bs, j * bs + valid)
    cur = np.maximum(np.asarray(lens, np.int32) - 1, 0)
    return (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(pos), jnp.asarray(bt), jnp.asarray(cur))


@pytest.mark.parametrize("window", [None, 9])
def test_paged_decode_matches_oracle_ragged(window):
    rng = np.random.default_rng(0)
    q, k, v, pos, bt, cur = _paged_case(rng)
    got = paged_decode_attention(q, k, v, pos, bt, cur, window=window)
    want = paged_decode_attention_ref(q, k, v, pos, bt, cur, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 9])
def test_paged_decode_bit_identical_to_monolithic(window):
    """The page-table gather must be a pure relayout: against the
    monolithic slab holding the same KV in the same order, with the chunk
    size pinned to the block size (same accumulation order), the paged
    path is bit-identical — not merely close."""
    rng = np.random.default_rng(1)
    q, k, v, pos, bt, cur = _paged_case(rng)
    bs = k.shape[1]
    k_slab = gather_pages(k, bt)
    v_slab = gather_pages(v, bt)
    pos_slab = jnp.where(jnp.repeat(bt >= 0, bs, axis=1),
                         gather_pages(pos, bt), -1)
    paged = paged_decode_attention(q, k, v, pos, bt, cur, window=window)
    mono = decode_attention(q, k_slab, v_slab, pos_slab, cur,
                            window=window, bk=bs)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(mono))


def test_paged_decode_empty_lane_matches_full_table_absence():
    """A K=0 lane (all pages unmapped) attends over nothing: identical to
    the monolithic path with an all-invalid positions row, and finite."""
    rng = np.random.default_rng(2)
    q, k, v, pos, bt, cur = _paged_case(rng, lens=(16, 0, 0))
    out = np.asarray(paged_decode_attention(q, k, v, pos, bt, cur))
    assert np.all(np.isfinite(out))
    ref = np.asarray(paged_decode_attention_ref(q, k, v, pos, bt, cur))
    np.testing.assert_allclose(out[1:], ref[1:], rtol=2e-5, atol=2e-5)


def test_paged_decode_ring_window_wrap_parity():
    """The recurrentgemma regression shape: a sliding window smaller than
    the resident history. The window mask must measure distance in
    absolute positions straight from the pos arena — block order and row
    permutation must not matter."""
    rng = np.random.default_rng(3)
    q, k, v, pos, bt, cur = _paged_case(rng, lens=(30, 21, 5))
    for window in (4, 8, 32):
        got = paged_decode_attention(q, k, v, pos, bt, cur, window=window)
        want = paged_decode_attention_ref(q, k, v, pos, bt, cur,
                                          window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=f"w={window}")
    # the decisive check: shrinking the window really changes the output
    full = paged_decode_attention(q, k, v, pos, bt, cur)
    tight = paged_decode_attention(q, k, v, pos, bt, cur, window=4)
    assert not np.allclose(np.asarray(full)[0], np.asarray(tight)[0])


# ---------------------------------------------------------------------------
# Device assembly: insert -> gather is bitwise, malformed trees fail loud
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-9b",
                                  "mamba2-1.3b"])
def test_pool_roundtrip_is_bitwise(arch):
    """Prefill state routed through the arena and gathered back must be
    bitwise identical to the prefill cache it came from — paged leaves
    through `insert_blocks`/`assemble_prefix`, everything else (SSM conv /
    recurrent state) verbatim through the anchor. The three archs cover
    the classification matrix: attention (paged KV only), hybrid
    (paged + recurrent anchor), pure SSM (anchor only — the pool
    degenerates to boundary snapshots and must still round-trip)."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    s, max_len, bs = 16, 32, 8
    prompt = rng.integers(0, cfg.vocab, size=(1, s)).astype(np.int32)
    pf_caches, _ = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt)})
    dec = model.init_cache(2, max_len)

    pool = PagedKVPool(8, bs)
    pool.bind(dec, max_len=max_len)
    if arch == "mamba2-1.3b":
        assert not pool._paged_paths, "pure SSM has no KV time axis to page"
    else:
        assert pool._paged_paths, f"{arch}: nothing paged"
    pool.validate_prefill(pf_caches, s)
    keys, new_bids, first = pool.reserve(prompt[0])
    assert len(keys) == s // bs and first == 0
    pool.arenas = pool.insert_blocks(pool.arenas, pf_caches,
                                     jnp.asarray(new_bids, jnp.int32), first)
    pool.set_anchor(keys[-1], pool.anchor_leaves(pf_caches))

    assembled = pool.assemble_prefix(dec, pool.arenas,
                                     jnp.asarray(pool.bids_of(keys),
                                                 jnp.int32),
                                     pool.anchor_of(keys[-1]))
    pf = {compat.tree_path_str(p): v
          for p, v in compat.tree_flatten_with_path(pf_caches)[0]}
    n_paged = n_anchor = 0
    for path, leaf in compat.tree_flatten_with_path(assembled)[0]:
        loc = compat.tree_path_str(path)
        np.testing.assert_array_equal(
            np.asarray(leaf), np.asarray(pf[loc]),
            err_msg=f"{arch} {loc}: pool round-trip is not bitwise")
        if loc in pool._paged_paths:
            n_paged += 1
        else:
            n_anchor += 1
    if arch != "mamba2-1.3b":
        assert n_paged > 0
    if arch != "tinyllama-1.1b":
        assert n_anchor > 0, "recurrent state must ride the anchor"


def test_pool_validate_prefill_fails_loud_with_path():
    """The merge loud-failure regression, pool flavor: a page-table/arena
    rank or off-axis mismatch raises with the tree path instead of
    silently caching truncated or misshapen state."""
    bs = 4
    dec = {"g0": {"sub0": {"k": jnp.zeros((2, 3, 16, 2, 8)),
                           "pos": jnp.zeros((2, 3, 16), jnp.int32),
                           "h": jnp.zeros((2, 3, 5))}}}
    pool = PagedKVPool(4, bs)
    pool.bind(dec, max_len=16)
    assert set(pool._paged_paths) == {"g0/sub0/k", "g0/sub0/pos"}

    ok = {"g0": {"sub0": {"k": jnp.zeros((2, 1, 8, 2, 8)),
                          "pos": jnp.zeros((2, 1, 8), jnp.int32),
                          "h": jnp.ones((2, 1, 5))}}}
    pool.validate_prefill(ok, 8)

    bad_rank = jax.tree_util.tree_map(lambda x: x, ok)
    bad_rank["g0"]["sub0"]["k"] = jnp.zeros((2, 1, 8, 2))
    with pytest.raises(ValueError, match=r"g0/sub0/k.*rank"):
        pool.validate_prefill(bad_rank, 8)

    bad_time = jax.tree_util.tree_map(lambda x: x, ok)
    bad_time["g0"]["sub0"]["k"] = jnp.zeros((2, 1, 6, 2, 8))
    with pytest.raises(ValueError, match=r"g0/sub0/k.*time extent"):
        pool.validate_prefill(bad_time, 8)

    bad_axis = jax.tree_util.tree_map(lambda x: x, ok)
    bad_axis["g0"]["sub0"]["k"] = jnp.zeros((2, 1, 8, 3, 8))
    with pytest.raises(ValueError, match=r"g0/sub0/k.*arena row"):
        pool.validate_prefill(bad_axis, 8)

    bad_batch = jax.tree_util.tree_map(lambda x: x, ok)
    bad_batch["g0"]["sub0"]["k"] = jnp.zeros((2, 2, 8, 2, 8))
    with pytest.raises(ValueError, match=r"g0/sub0/k.*batch"):
        pool.validate_prefill(bad_batch, 8)

    bad_tree = {"g0": {"sub0": {"k": ok["g0"]["sub0"]["k"],
                                "pos": ok["g0"]["sub0"]["pos"]}}}
    with pytest.raises(ValueError, match=r"structure diverges.*g0/sub0/h"):
        pool.validate_prefill(bad_tree, 8)

    # a missing anchor leaf at assembly is state loss: loud, with the path
    with pytest.raises(ValueError, match=r"g0/sub0/h.*anchor"):
        pool.assemble_prefix(dec, pool.arenas, jnp.zeros((1,), jnp.int32),
                             {})


def test_pool_bind_classifies_ring_leaves_as_anchor():
    """A sliding-window KV leaf (time extent = window < max_len) is a ring
    buffer — paging it by absolute position would be wrong, so it must
    ride the anchor; named KV leaves at full extent must page."""
    dec = {"attn": {"k": jnp.zeros((1, 2, 32, 2, 4)),
                    "v": jnp.zeros((1, 2, 32, 2, 4)),
                    "pos": jnp.zeros((1, 2, 32), jnp.int32)},
           "win": {"k": jnp.zeros((1, 2, 8, 2, 4)),
                   "pos": jnp.zeros((1, 2, 8), jnp.int32)},
           "ssm": {"state": jnp.zeros((1, 2, 16, 4))}}
    pool = PagedKVPool(4, 4)
    pool.bind(dec, max_len=32)
    assert pool._paged_paths == {"attn/k", "attn/v", "attn/pos"}
    assert pool._anchor_paths == {"win/k", "win/pos", "ssm/state"}
    for loc, arena in pool.arenas.items():
        assert arena.shape[:3] == (4, 1, 4), loc
    assert sorted(TIME_MERGE_LEAVES) == ["c_kv", "k", "k_rope", "pos", "v"]
