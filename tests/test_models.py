"""Per-architecture smoke + decode-parity tests.

The decode-parity test is the load-bearing one: greedy logits from
prefill-then-decode must match a single full forward over the same tokens —
this catches KV-cache indexing, rolling-window, MLA-absorption, SSM-state
and conv-state bugs in one assertion per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model

ARCHS = configs.ARCH_NAMES

# The per-arch sweeps are the bulk of suite wall time. The fast lane
# (-m "not slow") keeps one representative per family; tier-1 runs them all.
_FAST_ARCHS = {"tinyllama-1.1b", "mamba2-1.3b"}
ARCH_SWEEP = [pytest.param(a, marks=() if a in _FAST_ARCHS
                           else (pytest.mark.slow,)) for a in ARCHS]


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b,) + cfg.frame_shape), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_SWEEP)
class TestSmoke:
    def test_train_step_finite_shapes(self, arch):
        cfg = configs.get_smoke(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        loss, metrics = jax.jit(m.loss)(params, _batch(cfg))
        assert np.isfinite(float(loss))
        assert float(metrics["ce"]) > 0

    def test_gradients_flow_everywhere(self, arch):
        cfg = configs.get_smoke(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        grads = jax.grad(lambda p: m.loss(p, _batch(cfg))[0])(params)
        flat = jax.tree.leaves(grads)
        assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
        # no dead parameters: a majority of leaves get nonzero gradient
        nz = sum(float(jnp.any(g != 0)) for g in flat)
        assert nz / len(flat) > 0.9

    def test_prefill_shapes_and_finite(self, arch):
        cfg = configs.get_smoke(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        caches, lg = jax.jit(m.prefill)(params, _batch(cfg))
        assert lg.shape == (2, 1, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(lg)))


@pytest.mark.parametrize("arch", ARCH_SWEEP)
def test_decode_matches_full_forward(arch):
    """Prefill p tokens, decode the rest one by one; per-step logits must
    match the teacher-forced full forward (same tokens) to fp tolerance."""
    cfg = configs.get_smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    b, s, p = 2, 24, 16
    batch = _batch(cfg, b, s, seed=3)
    tokens = batch["tokens"]

    # teacher-forced full forward: logits at every position
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h, _, _ = m.forward(params, tokens, positions, mode="train",
                        frames=batch.get("frames"))
    from repro.models.layers import logits as logits_fn
    full_lg = logits_fn(cfg, params["embed"], h)          # (b, s, V)

    # prefill on the first p tokens, then decode positions p..s-1
    pre = {"tokens": tokens[:, :p]}
    if "frames" in batch:
        pre["frames"] = batch["frames"]
    caches = m.init_cache(b, s)
    pf_caches, lg_p = jax.jit(m.prefill)(params, pre)
    from repro.launch.serve import _merge_prefill
    caches = _merge_prefill(m, caches, pf_caches, p)
    np.testing.assert_allclose(np.asarray(lg_p[:, -1]),
                               np.asarray(full_lg[:, p - 1]),
                               rtol=2e-2, atol=2e-2)

    decode = jax.jit(m.decode_step)
    for i in range(p, s):
        tok = tokens[:, i][:, None]
        pos = jnp.full((b,), i, jnp.int32)
        caches, lg = decode(params, caches, tok, pos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_lg[:, i]),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{arch}: decode step {i} diverged from full forward")


def test_sliding_window_cache_is_bounded():
    """The hybrid's rolling cache never exceeds the window — the property
    that makes long_500k a running cell (DESIGN §Arch-applicability)."""
    cfg = configs.get_smoke("recurrentgemma-9b")
    m = build_model(cfg)
    caches = m.init_cache(batch=1, max_len=10_000)
    leaves = jax.tree_util.tree_leaves(caches)
    assert all(l.size < 1_000_000 for l in leaves)
    # attention cache time axis == window, not max_len
    for path, leaf in m.named_leaves(caches):
        name = path.rsplit("/", 1)[-1]
        if name in ("k", "v"):
            assert leaf.shape[-3] == cfg.attn_window


@pytest.mark.slow
def test_mtp_loss_present_for_deepseek():
    cfg = configs.get_smoke("deepseek-v3-671b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert "mtp" in params
    _, metrics = m.loss(params, _batch(cfg))
    assert np.isfinite(float(metrics["mtp"]))


def test_moe_dense_routes_topk():
    """Router respects k: zeroing an expert's weights changes outputs only
    for tokens routed to it."""
    from repro.models import moe as moe_lib
    from repro.parallel.ctx import CPU_CTX
    cfg = configs.get_smoke("dbrx-132b")
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, cfg.d_model)),
                    jnp.float32)
    out1, aux = moe_lib.moe_dense(cfg, p, x)
    assert np.isfinite(float(aux))
    # aux loss near 1.0 for near-uniform routing (Switch normalization)
    assert 0.5 < float(aux) < 4.0


@pytest.mark.parametrize("arch", ARCH_SWEEP)
def test_causality(arch):
    """Logits at position i must not depend on tokens at positions > i.

    Perturb the last quarter of the sequence; every logit before the
    perturbation point must be bit-unchanged (catches mask bugs, window
    off-by-ones, SSD chunk-boundary leaks, RG-LRU scan direction)."""
    cfg = configs.get_smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    b, s = 2, 32
    cut = 24
    batch = _batch(cfg, b, s, seed=5)
    toks = batch["tokens"]
    rng = np.random.default_rng(9)
    perturbed = toks.at[:, cut:].set(
        jnp.asarray(rng.integers(0, cfg.vocab, (b, s - cut)), jnp.int32))

    def run(tk):
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, _, _ = m.forward(params, tk, pos, mode="train",
                            frames=batch.get("frames"))
        from repro.models.layers import logits as logits_fn
        return logits_fn(cfg, params["embed"], h)

    la = np.asarray(jax.jit(run)(toks))
    lb = np.asarray(jax.jit(run)(perturbed))
    np.testing.assert_array_equal(
        la[:, :cut], lb[:, :cut],
        err_msg=f"{arch}: future tokens leaked into past logits")
    # sanity: the perturbation does change the late logits
    assert not np.array_equal(la[:, cut:], lb[:, cut:])


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_batch_element_independence(arch):
    """Paper §3.8: a row computed alone is identical to the same row inside
    a batch — batch elements never interact."""
    cfg = configs.get_smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    batch = _batch(cfg, 4, 24, seed=11)

    def run(tk):
        pos = jnp.broadcast_to(jnp.arange(tk.shape[1])[None], tk.shape)
        h, _, _ = m.forward(params, tk, pos, mode="train")
        return h

    full = np.asarray(jax.jit(run)(batch["tokens"]), np.float32)
    solo = np.asarray(jax.jit(run)(batch["tokens"][:1]), np.float32)
    np.testing.assert_allclose(full[:1], solo, rtol=2e-5, atol=2e-5)
