"""Chunked prefill + ServeConfig suite (tier: chunked prefill/long context).

Load-bearing properties of the chunked-prefill admission path
(`--prefill-chunk`) and the typed `ServeConfig` construction API:

  * **token-exact parity** — admitting a long prompt as fixed-size chunk
    programs (decode-mode forwards written incrementally into a batch-1
    staging cache, admitted via the donated `_admit_into_slot` path) emits
    exactly the unchunked continuous/SLO/sequential token stream, per
    request, across attention, SSM, RG-LRU, windowed and MLA families.
  * **bounded compile set** — every chunk of a given size shares ONE
    ProgramCache entry (the staging cache is decode-shaped whatever the
    prompt), so heterogeneous prompts compile {1 chunk + 1 decode} instead
    of one prefill program per bucket.
  * **floor-charged chunks** — each chunk is a `DispatchRecord` on the
    scheduler's stream carrying its token `span`; the spans of one prompt
    tile [0, target) exactly.
  * **pool interop** — chunked cold admissions insert whole blocks from the
    staging cache (chunk-boundary anchors), and later identical prompts
    admit from residency, token-exact.
  * **loud configuration** — `ServeConfig` sections reject schedules they
    cannot apply to; the legacy `make_scheduler(**kw)` shim raises on
    unknown keywords, warns before dropping inapplicable ones, and emits a
    DeprecationWarning on every call.
"""

import functools
import warnings

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import hal
from repro.core.dispatch import (AsyncExecutionStream, ExecutionStream,
                                 ProgramCache)
from repro.launch.scheduler import (ChunkConfig, PrefixConfig, Request,
                                    ServeConfig, SLOConfig, SpecConfig,
                                    build_scheduler, make_scheduler)
from repro.launch.speculative import SpeculativeSchedule
from repro.models.model import build_model

V5E = hal.get_target("tpu-v5e")

# heterogeneous on purpose: below one chunk (reset admission), chunk-exact,
# ragged last chunk, and a multi-chunk prompt
CHUNK_LENS = [24, 6, 17, 16, 33]


@functools.lru_cache(maxsize=None)
def _served_model(arch: str):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, lens, gen, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=(L,)).astype(np.int32),
                    max_new_tokens=gen)
            for i, L in enumerate(lens)]


def _serve(arch, schedule, lens, gen, *, chunk=None, prefix=None,
           n_slots=3, rounds=1, slo=None):
    cfg, model, params = _served_model(arch)
    cache = ProgramCache()
    stream = (AsyncExecutionStream(cache, target=V5E) if schedule == "slo"
              else ExecutionStream(cache, target=V5E))
    config = ServeConfig(
        schedule=schedule, max_len=max(lens) + gen, n_slots=n_slots,
        stream=stream, slo=slo,
        prefix=PrefixConfig(**prefix) if prefix is not None else None,
        chunk=ChunkConfig(prefill_chunk=chunk) if chunk is not None else None)
    sched = build_scheduler(config, model, params, cfg)
    outs = [{r.rid: r for r in sched.run(_requests(cfg, lens, gen))}
            for _ in range(rounds)]
    return (outs[0] if rounds == 1 else outs), sched


# ---------------------------------------------------------------------------
# Token-exact parity: chunked vs unchunked vs sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_matches_unchunked_continuous(chunk):
    base, _ = _serve("tinyllama-1.1b", "continuous", CHUNK_LENS, gen=6)
    out, sched = _serve("tinyllama-1.1b", "continuous", CHUNK_LENS, gen=6,
                        chunk=chunk)
    for rid in base:
        np.testing.assert_array_equal(base[rid].tokens, out[rid].tokens)
    st = sched.stats(len(CHUNK_LENS))
    assert st["chunked_prefill"]["n_chunks"] > 0


def test_chunked_matches_sequential_under_slo():
    seq, _ = _serve("tinyllama-1.1b", "sequential", CHUNK_LENS, gen=6)
    slo, _ = _serve("tinyllama-1.1b", "slo", CHUNK_LENS, gen=6, chunk=8,
                    slo=SLOConfig(slo_ms=1e6))
    for rid in seq:
        np.testing.assert_array_equal(seq[rid].tokens, slo[rid].tokens)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b",
                                  "deepseek-v3-671b", "phi4-mini-3.8b",
                                  "command-r-35b"])
def test_chunked_parity_across_families(arch):
    """SSM state carry, RG-LRU hidden carry, MLA absorbed decode and
    sliding-window wrap all survive chunk-at-a-time prefill bit-exactly
    (greedy): the chunk branch is decode mode generalized from s=1 to
    s=C, resumed from the carried cache."""
    base, _ = _serve(arch, "continuous", CHUNK_LENS, gen=5)
    out, _ = _serve(arch, "continuous", CHUNK_LENS, gen=5, chunk=8)
    for rid in base:
        np.testing.assert_array_equal(base[rid].tokens, out[rid].tokens)


def test_categorical_streams_schedule_invariant_chunked():
    cfg, model, params = _served_model("tinyllama-1.1b")
    outs = {}
    for chunk in (None, 8):
        stream = ExecutionStream(ProgramCache(), target=V5E)
        config = ServeConfig(
            schedule="continuous", max_len=40, n_slots=2, stream=stream,
            sampling="categorical", seed=7,
            chunk=ChunkConfig(prefill_chunk=chunk) if chunk else None)
        sched = build_scheduler(config, model, params, cfg)
        outs[chunk] = {r.rid: r.tokens
                       for r in sched.run(_requests(cfg, [26, 9], 5))}
    for rid in outs[None]:
        np.testing.assert_array_equal(outs[None][rid], outs[8][rid])


# ---------------------------------------------------------------------------
# Compile economics + floor accounting
# ---------------------------------------------------------------------------

def test_one_program_per_chunk_size():
    """Heterogeneous prompts compile exactly one chunk program + one decode
    program: the staging cache is decode-shaped for every prompt, so the
    content hash collapses across buckets."""
    _, sched = _serve("tinyllama-1.1b", "continuous", CHUNK_LENS, gen=4,
                      chunk=8)
    assert len(sched._chunk_keys) == 1
    # unchunked compiles one prefill program per bucket touched instead
    _, base = _serve("tinyllama-1.1b", "continuous", CHUNK_LENS, gen=4)
    chunked_misses = sched.stream.cache.stats.misses
    assert chunked_misses <= base.stream.cache.stats.misses


def test_chunk_spans_tile_the_prefix_and_pay_floors():
    lens = [33]
    _, sched = _serve("tinyllama-1.1b", "continuous", lens, gen=3, chunk=8)
    spans = sorted(r.span for r in sched.stream.records
                   if r.span is not None)
    target = 8 * ((33 - 1) // 8)
    assert spans[0][0] == 0 and spans[-1][1] == target
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 == b0, "chunk spans must tile without gap or overlap"
    # every chunk dispatch is floor-charged on the scheduler's own stream
    chunk_recs = [r for r in sched.stream.records if r.span is not None]
    assert len(chunk_recs) == len(spans)
    assert all(r.floor_s == V5E.dispatch_floor_s for r in chunk_recs)


def test_decode_windows_run_between_chunks():
    """A long prompt arriving while another lane decodes must not stall it:
    decode dispatches interleave between the chunk dispatches."""
    cfg, model, params = _served_model("tinyllama-1.1b")
    stream = ExecutionStream(ProgramCache(), target=V5E)
    config = ServeConfig(schedule="continuous", max_len=72, n_slots=2,
                         stream=stream, chunk=ChunkConfig(prefill_chunk=8))
    sched = build_scheduler(config, model, params, cfg)
    reqs = _requests(cfg, [6, 64], gen=8)
    reqs[1] = Request(rid=1, prompt=reqs[1].prompt, max_new_tokens=8,
                      arrival=2)
    sched.run(reqs)
    seqs = [r.seq for r in stream.records if r.span is not None]
    decode_seqs = [r.seq for r in stream.records
                   if r.span is None and r.batch >= 1 and r.key
                   in {k for _, k in sched._decode_memo.values()}]
    interleaved = [s for s in decode_seqs if seqs[0] < s < seqs[-1]]
    assert interleaved, ("no decode dispatch ran between the first and "
                        "last chunk: chunking failed to break "
                        "head-of-line blocking")


# ---------------------------------------------------------------------------
# Prefix-pool interop
# ---------------------------------------------------------------------------

def test_chunked_cold_insert_then_prefix_hits():
    rounds, sched = _serve("tinyllama-1.1b", "continuous", [26, 26, 26],
                           gen=4, chunk=8, n_slots=1, rounds=2,
                           prefix=dict(blocks=64, block_size=4))
    base_rounds, _ = _serve("tinyllama-1.1b", "continuous", [26, 26, 26],
                            gen=4, n_slots=1, rounds=2)
    for rnd, brnd in zip(rounds, base_rounds):
        for rid in rnd:
            np.testing.assert_array_equal(rnd[rid].tokens, brnd[rid].tokens)
    # chunk target 24 = 6 whole blocks: the chain anchors at the chunk
    # boundary, so rounds after the first admit from residency
    assert sched.pool.stats["hits"] >= 3
    assert sched.pool.stats["misses"] >= 1


# ---------------------------------------------------------------------------
# ServeConfig: loud sections, loud shim
# ---------------------------------------------------------------------------

def test_serve_config_rejects_inapplicable_sections():
    with pytest.raises(ValueError, match="does not apply"):
        ServeConfig(schedule="sequential", max_len=16,
                    chunk=ChunkConfig(prefill_chunk=4)).validate()
    with pytest.raises(ValueError, match="does not apply"):
        ServeConfig(schedule="continuous", max_len=16,
                    slo=SLOConfig(slo_ms=5.0)).validate()
    with pytest.raises(ValueError, match="does not apply"):
        ServeConfig(schedule="slo", max_len=16,
                    spec=SpecConfig(draft_depth=2)).validate()
    with pytest.raises(ValueError, match="block_size"):
        ServeConfig(schedule="continuous", max_len=16,
                    chunk=ChunkConfig(prefill_chunk=6),
                    prefix=PrefixConfig(block_size=4)).validate()
    with pytest.raises(ValueError, match="prefill_chunk"):
        ChunkConfig(prefill_chunk=0)
    with pytest.raises(ValueError, match="empty"):
        ChunkConfig()


def test_make_scheduler_shim_is_loud():
    cfg, model, params = _served_model("tinyllama-1.1b")
    # unknown keyword: TypeError, not a silent drop (the regression this
    # API redesign exists to fix)
    with pytest.raises(TypeError, match="unknown keyword"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            make_scheduler("continuous", model, params, cfg, n_slots=1,
                           max_len=16, slo_mss=5.0)
    # schedule-inapplicable knob: warned before being dropped
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sched = make_scheduler("continuous", model, params, cfg, n_slots=1,
                               max_len=16, slo_ms=5.0)
    cats = {x.category for x in w}
    assert DeprecationWarning in cats and UserWarning in cats
    assert not hasattr(sched, "slo_s")
    # legacy behavior preserved: sequential strips the prefix knobs
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        seq = make_scheduler("sequential", model, params, cfg, max_len=16,
                             n_slots=1, prefix_cache=True)
    assert not hasattr(seq, "pool")
    assert any(x.category is UserWarning for x in w)


def test_chunking_rejected_where_it_cannot_apply():
    cfg, model, params = _served_model("tinyllama-1.1b")
    with pytest.raises(ValueError, match="chunk"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            prefill_chunk=4)
    ecfg, emodel, eparams = _served_model("whisper-small")
    with pytest.raises(ValueError, match="encdec"):
        build_scheduler(
            ServeConfig(schedule="continuous", max_len=16,
                        chunk=ChunkConfig(prefill_chunk=4)),
            emodel, eparams, ecfg)
