"""Sharding rule-table tests: pure spec computation, no multi-device mesh.

The rules in `parallel/sharding.py` are path-pattern tables consumed by both
training (`param_specs`/`cache_specs`, TP+FSDP) and serving
(`serve_param_specs`/`serve_cache_specs`, EP-only + lane sharding). These
tests drive them with a duck-typed context whose axis sizes are arbitrary,
so the divisibility fallbacks, the FSDP/embed size gates, the stacked-layer
offset and the `DispatchedWeight` payload handling are all checked without
forcing virtual devices (the mesh-execution side lives in
`test_distributed.py` / `test_mesh_serve.py`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models.dispatched import DispatchedWeight, WeightForm
from repro.models.model import build_model
from repro.parallel import sharding
from repro.parallel.ctx import ParallelContext


class FakeCtx:
    """Duck-typed ParallelContext with arbitrary axis sizes and no mesh —
    the rule tables only consume axis_size/spec/batch_axes."""

    def __init__(self, **sizes):
        self._sizes = sizes

    active = True

    @property
    def axis_names(self):
        return tuple(self._sizes)

    @property
    def batch_axes(self):
        return tuple(a for a in ("pod", "data") if a in self._sizes)

    @property
    def model_axis(self):
        return "model" if "model" in self._sizes else None

    def axis_size(self, name):
        return self._sizes.get(name, 1)

    def spec(self, *axes):
        cleaned = []
        for a in axes:
            if a is None:
                cleaned.append(None)
            elif isinstance(a, tuple):
                present = tuple(x for x in a if x in self._sizes)
                cleaned.append(present if present else None)
            else:
                cleaned.append(a if a in self._sizes else None)
        return P(*cleaned)

    def divisible(self, n, axis):
        s = self.axis_size(axis)
        return s > 1 and n % s == 0


CTX = FakeCtx(data=4, model=2)


def _axes_of(spec):
    flat = []
    for a in spec:
        if isinstance(a, tuple):
            flat.extend(a)
        elif a is not None:
            flat.append(a)
    return flat


# ---------------------------------------------------------------------------
# param_specs across every architecture family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_param_specs_cover_every_arch(arch):
    """Every arch's param tree maps to a same-structure spec tree whose
    ranks match and whose axes all exist on the context."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, ParallelContext(mesh=None))
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = sharding.param_specs(params, CTX)
    p_leaves = jax.tree_util.tree_leaves_with_path(params)
    s_leaves = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(p_leaves) == len(s_leaves), f"{arch}: structure mismatch"
    for (pp, leaf), (sp, spec) in zip(p_leaves, s_leaves):
        assert pp == sp
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, f"{arch}: {pp} over-ranked"
        for ax in _axes_of(spec):
            assert ax in CTX.axis_names


def test_cache_specs_cover_every_arch():
    for arch in configs.ARCH_NAMES:
        cfg = configs.get_smoke(arch)
        model = build_model(cfg, ParallelContext(mesh=None))
        caches = jax.eval_shape(lambda: model.init_cache(4, 32))
        specs = sharding.cache_specs(caches, CTX)
        pairs = zip(
            jax.tree_util.tree_leaves(caches),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)))
        for leaf, spec in pairs:
            assert len(spec) <= leaf.ndim, f"{arch}: cache over-ranked"


# ---------------------------------------------------------------------------
# divisibility fallback + size gates (_spec_for directly)
# ---------------------------------------------------------------------------

def test_divisibility_fallback_replicates():
    # 7 heads on a 2-way model axis: the head dim must replicate
    spec = sharding._spec_for("blk/mix/wq", (64, 7, 16), CTX,
                              sharding._RULES, stacked_offset=True)
    assert spec == P(None, None, None)
    # 8 heads divide: TP applies (FSDP stays off — weight under the gate)
    spec = sharding._spec_for("blk/mix/wq", (64, 8, 16), CTX,
                              sharding._RULES, stacked_offset=True)
    assert spec == P(None, "model", None)


def test_fsdp_min_elements_gate():
    small = (1024, 8, 64)                    # 0.5M elements: no FSDP
    spec = sharding._spec_for("blk/mix/wq", small, CTX,
                              sharding._RULES, stacked_offset=True)
    assert "data" not in _axes_of(spec)
    big = (8192, 64, 64)                     # 33.5M >= FSDP_MIN_ELEMENTS
    assert np.prod(big) >= sharding.FSDP_MIN_ELEMENTS
    spec = sharding._spec_for("blk/mix/wq", big, CTX,
                              sharding._RULES, stacked_offset=True)
    assert spec == P("data", "model", None)


def test_embed_shard_min_elements_gate():
    small = (512, 64)
    spec = sharding._spec_for("embed/table", small, CTX,
                              sharding._RULES, stacked_offset=True)
    assert spec == P(None, None)             # replicate small tables
    big = (32768, 8192)                      # 268M >= EMBED_SHARD_MIN
    assert np.prod(big) >= sharding.EMBED_SHARD_MIN_ELEMENTS
    spec = sharding._spec_for("embed/table", big, CTX,
                              sharding._RULES, stacked_offset=True)
    assert spec == P("model", None)


def test_stacked_offset_shifts_rule_dims():
    # stacked layer params carry a leading L dim: "layers/..." shifts +1
    stacked = sharding._spec_for("layers/mix/wq", (3, 64, 8, 16), CTX,
                                 sharding._RULES, stacked_offset=True)
    assert stacked == P(None, None, "model", None)
    flat = sharding._spec_for("encdec/enc/attn/wq", (3, 64, 8, 16), CTX,
                              sharding._RULES, stacked_offset=True)
    assert flat == P(None, None, "model", None)
    unstacked = sharding._spec_for("blk/mix/wq", (64, 8, 16), CTX,
                                   sharding._RULES, stacked_offset=True)
    assert unstacked == P(None, "model", None)


def test_cache_rule_tries_stacked_then_flat():
    # stacked (L,B,S,KV,dh): batch at 1, heads at 3
    spec = sharding.cache_specs({"self": {"k": jax.ShapeDtypeStruct(
        (3, 8, 32, 4, 16), jnp.float32)}}, CTX)
    assert spec["self"]["k"] == P(None, ("data",), None, "model", None)
    # rank-1 /pos: the stacked offset runs off the rank, falls back to 0
    spec = sharding.cache_specs({"self": {"pos": jax.ShapeDtypeStruct(
        (8,), jnp.int32)}}, CTX)
    assert spec["self"]["pos"] == P(("data",))


# ---------------------------------------------------------------------------
# DispatchedWeight payloads
# ---------------------------------------------------------------------------

def _packed_bank(*stack, d=8, f=16):
    """A hand-rolled INT4_PALETTE bank: payload leaves share the leading
    `stack` dims (layer-scan and/or expert), trailing dims are the packed
    matmul view."""
    return DispatchedWeight(
        form=WeightForm.INT4_PALETTE,
        contract_shape=(d,), out_shape=(f,), dtype_name="float32",
        payload={"packed": jnp.zeros((*stack, d, f // 2), jnp.uint8),
                 "lut": jnp.zeros((*stack, 16), jnp.float32)})


def test_stack_specs_rejects_matmul_dims():
    bank = _packed_bank(4)
    assert bank.n_stack == 1
    with pytest.raises(ValueError, match="packed matmul dims"):
        bank.stack_specs("model", "data")


def test_param_specs_handles_dispatched_weight():
    # unstacked (E,...) bank: rule dim 0 lands on the expert dim
    specs = sharding.param_specs({"blk": {"moe": {"wg": _packed_bank(4)}}},
                                 CTX)
    bank_specs = specs["blk"]["moe"]["wg"]
    assert isinstance(bank_specs, DispatchedWeight)
    for leaf in jax.tree_util.tree_leaves(
            bank_specs, is_leaf=lambda x: isinstance(x, P)):
        assert leaf == P("model")
    # layer-stacked (L,E,...) bank under "layers/": the offset shifts the
    # expert rule to dim 1; the FSDP dim falls past the stack and drops
    specs = sharding.param_specs(
        {"layers": {"moe": {"wg": _packed_bank(3, 4)}}}, CTX)
    for leaf in jax.tree_util.tree_leaves(
            specs["layers"]["moe"]["wg"],
            is_leaf=lambda x: isinstance(x, P)):
        assert leaf == P(None, "model")


def test_dispatched_divisibility_guard():
    # 5 experts on a 2-way model axis: the bank replicates
    specs = sharding.param_specs({"blk": {"moe": {"wg": _packed_bank(5)}}},
                                 CTX)
    for leaf in jax.tree_util.tree_leaves(
            specs["blk"]["moe"]["wg"],
            is_leaf=lambda x: isinstance(x, P)):
        assert leaf == P(None)


# ---------------------------------------------------------------------------
# serving placement rules
# ---------------------------------------------------------------------------

def test_serve_param_specs_replicate_all_but_expert_banks():
    cfg = configs.get_smoke("dbrx-132b")
    model = build_model(cfg, ParallelContext(mesh=None))
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = sharding.serve_param_specs(params, CTX)
    leaves = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    sharded = [jax.tree_util.keystr(kp) for kp, s in leaves if _axes_of(s)]
    assert sharded, "expert banks must shard over the EP axis"
    for kp, spec in leaves:
        path = jax.tree_util.keystr(kp)
        if "moe" in path and any(w in path for w in ("wg", "wu", "wd")):
            # layer-scanned params carry a leading L dim: the EP cut lands
            # on the expert dim right after it
            assert _axes_of(spec) == ["model"], path
            assert spec[1] == "model", path
        else:
            assert not _axes_of(spec), f"{path} must replicate for serving"


def test_serve_cache_specs_strip_model_axis():
    caches = {"self": {"k": jax.ShapeDtypeStruct((3, 8, 32, 4, 16),
                                                 jnp.float32),
                       "state": jax.ShapeDtypeStruct((3, 8, 4, 2, 8),
                                                     jnp.float32)}}
    specs = sharding.serve_cache_specs(caches, CTX)
    for spec in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert "model" not in _axes_of(spec)
    # the lane/batch sharding survives the strip
    assert "data" in _axes_of(specs["self"]["k"])


def test_serve_arena_specs_replicate():
    arenas = {"k": jnp.zeros((4, 2, 8)), "v": jnp.zeros((4, 2, 8))}
    specs = sharding.serve_arena_specs(arenas, CTX)
    assert all(s == P() for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))


def test_batch_specs_divisibility():
    ctx = FakeCtx(pod=2, data=2, model=2)
    x = jnp.zeros((8, 16))
    assert sharding.batch_specs(x, ctx) == P(("pod", "data"), None)
    assert sharding.batch_specs(jnp.zeros((6, 16)), ctx) == P(None, None)
