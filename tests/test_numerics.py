"""Paper ch. 3 reproductions: the fp16 datapath + wide accumulator oracle.

Every test here validates a *specific measured claim of the paper* (marked
with its table/section). Where the paper itself leaves the tie mode
unresolved (§3.6), the test pins the structure (threshold location, hard
floor) rather than the tie-dependent values.
"""

import numpy as np
import pytest

from repro.core import hal, numerics as nu


class TestWideAccumulator:
    def test_survivor_floor_is_exactly_four(self):
        # paper:T3.1 — hard floor of exactly 4 survivors at and above 4096
        for tie in ("even", "away"):
            got = nu.survivor_sweep([4096, 8000, 16000, 30000], tie=tie)
            assert got == [4, 4, 4, 4], (tie, got)

    def test_survivor_threshold_at_4096(self):
        # paper:T3.1 — 16 at 1024 (exact regime); the drop to the floor
        # happens exactly where fp16 spacing reaches 4
        assert nu.survivor_sweep([1024])[0] == 16
        assert nu.survivor_sweep([4090], tie="away")[0] > 4
        assert nu.survivor_sweep([4096], tie="away")[0] == 4

    def test_16000_ones_bit_exact(self):
        # paper:§3.2 — a reduction of sixteen thousand ones is bit exact
        assert nu.wide_reduce(np.ones(16000)) == 16000.0

    def test_naive_fp16_stalls_near_2048(self):
        # the contrast case the paper gives: a narrow running sum stalls
        acc = np.float16(0)
        for _ in range(4000):
            acc = np.float16(acc + np.float16(1.0))
        assert acc == 2048.0

    def test_worked_sum_between_naive_and_exact(self):
        # paper:§3.2 — [4096] + [1]*1024: engine 5116, naive 4096, exact 5120.
        # Our model lands within one in-tile rounding step of the decoded
        # value; the structural claim (strictly between) must hold.
        got = nu.wide_reduce(np.array([4096.0] + [1.0] * 1024))
        assert 4096.0 < got < 5120.0
        assert abs(got - 5116.0) <= 4.0

    def test_cancellation_triple_survives_below_threshold(self):
        # paper:§3.2 — big, -big, one near 4000: the ones survive
        v = np.array([3000.0, -3000.0, 1.0] * 16)
        assert nu.wide_reduce(v, tie="away") >= 16.0


class TestSaturation:
    def test_mac_output_port_ceiling_pinned_to_the_bit(self):
        # paper:§3.7 — 32752 passes through a linear; 32768 returns inf
        one = np.array([[1.0]])
        assert nu.ane_matmul(np.array([[32752.0]]), one)[0, 0] == 32752.0
        assert nu.ane_matmul(np.array([[32768.0]]), one)[0, 0] == np.inf
        assert nu.ane_matmul(np.array([[-32768.0]]), one)[0, 0] == -np.inf

    def test_interior_partial_overflows_despite_cancellation(self):
        # paper:§3.7 — an interior partial above 2^15 overflows even when a
        # later cancellation would bring the result back into range.
        # (The oracle models the port on the final value; the kernel-level
        # behavior is covered in the kernel ANE-mode tests.)
        a = np.array([[30000.0, 30000.0, -30000.0]])
        b = np.ones((3, 1))
        assert nu.ane_matmul(a, b)[0, 0] == np.inf

    def test_width_slice_gain(self):
        # paper:§3.7 — 4094 passes (4094*16 == 65504), 4096 -> inf
        x = np.full((1, 8), hal.WIDTH_SLICE_FINITE_FILL)
        assert nu.width_slice(x, 1, 4)[0, 0] == hal.WIDTH_SLICE_FINITE_FILL
        x = np.full((1, 8), hal.WIDTH_SLICE_OVERFLOW_FILL)
        assert nu.width_slice(x, 1, 4)[0, 0] == np.inf
        # control: zero begin offset is free of the saturation
        assert nu.width_slice(x, 0, 4)[0, 0] == hal.WIDTH_SLICE_OVERFLOW_FILL


class TestEdgeSemantics:
    def test_nan_coerces_to_inf_never_emitted(self):
        # paper:§3.6
        assert nu.ane_relu(np.nan) == np.inf
        assert nu.ane_max(np.nan, 1.0) == np.inf
        assert float(nu.build_lut("sigmoid")(np.array([np.nan]))[0]) == 1.0
        assert float(nu.build_lut("tanh")(np.array([np.nan]))[0]) == 1.0

    def test_indeterminates_flush_to_positive_zero(self):
        assert nu.ane_add(np.inf, -np.inf) == 0.0
        assert nu.ane_mul(0.0, np.inf) == 0.0
        assert nu.ane_sqrt(-1.0) == 0.0
        assert nu.ane_log(-1.0) == 0.0

    def test_log_zero_sentinel(self):
        assert nu.ane_log(0.0) == nu.LOG_ZERO_SENTINEL  # -45440

    def test_signed_zero_reciprocal(self):
        assert nu.ane_reciprocal(-0.0) == np.inf
        assert nu.ane_rsqrt(-0.0) == np.inf

    def test_softmax_max_subtract_never_overflows(self):
        got = nu.ane_softmax(np.array([1000.0, 1.0, 2.0, 3.0]))
        np.testing.assert_array_equal(got, [1.0, 0.0, 0.0, 0.0])
        got = nu.ane_softmax(np.array([5.0, 5.0, 5.0, 5.0]))
        np.testing.assert_array_equal(got, [0.25] * 4)

    def test_softmax_nan_lane_takes_all_mass(self):
        got = nu.ane_softmax(np.array([np.nan, 1.0, 2.0, 3.0]))
        assert got[0] == 1.0 and got[1:].sum() == 0.0

    def test_bare_exp_overflows_at_11_094(self):
        assert nu.ane_exp(hal.EXP_OVERFLOW_INPUT) == np.inf
        assert np.isfinite(nu.ane_exp(11.0))


class TestActivationTables:
    @pytest.mark.parametrize("name,bound", [
        ("sigmoid", 0.0034), ("tanh", 0.0017), ("gelu", 0.0059),
    ])
    def test_worst_error_meets_paper_bound(self, name, bound):
        # paper:T3.3 per-function worst absolute errors
        t = nu.build_lut(name)
        assert nu.lut_worst_error(t) <= bound

    def test_knot_count_is_33(self):
        assert nu.build_lut("sigmoid").xs.shape == (hal.LUT_KNOTS,)

    def test_origin_biases(self):
        # paper:T3.3 — gelu -0.000543, swish -0.001259 at x=0
        assert abs(float(nu.build_lut("gelu")(np.zeros(1))[0]) - (-0.000543)) < 1e-6
        assert abs(float(nu.build_lut("swish")(np.zeros(1))[0]) - (-0.001259)) < 1e-6

    def test_softplus_collapses_at_infinity(self):
        # paper:§3.6 — softplus(+inf) returns +0 (a table collapse)
        assert float(nu.build_lut("softplus")(np.array([np.inf]))[0]) == 0.0

    def test_trig_seam_error_within_paper_range(self):
        # paper:T3.3 — sin/cos up to 0.04..0.12 near argument-reduction seams
        for name in ("sin", "cos"):
            assert nu.lut_worst_error(nu.build_lut(name)) <= 0.12

    def test_clamp_past_domain(self):
        t = nu.build_lut("sigmoid")
        assert float(t(np.array([50.0]))[0]) == t.hi_clamp
        assert float(t(np.array([-50.0]))[0]) == t.lo_clamp


class TestDeterminism:
    def test_rerun_bit_identical(self):
        # paper:§3.8 — fixed graph + fixed input -> identical fp16 bytes
        rng = np.random.default_rng(0)
        a = rng.normal(size=(16, 64)).astype(np.float32)
        b = rng.normal(size=(64, 8)).astype(np.float32)
        outs = [nu.ane_matmul(a, b) for _ in range(5)]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)

    def test_association_order_changes_bits(self):
        # paper:§3.8 — (a+b)+c vs a+(b+c) differ by fp16 rounding on a
        # sizeable fraction of elements (the paper measures ~31%); each
        # ordering is itself perfectly reproducible
        rng = np.random.default_rng(7)
        a, b, c = rng.normal(size=(3, 1000))
        left = nu.round_fp16(nu.round_fp16(a + b) + c)
        right = nu.round_fp16(a + nu.round_fp16(b + c))
        frac = np.mean(left != right)
        assert 0.05 < frac < 0.6, frac
