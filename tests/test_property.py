"""Property-based tests (hypothesis) on the system's invariants.

Degrades to a module-level skip when hypothesis is absent (it is an optional
test dependency — see requirements-test.txt); CI installs it so these run."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression as cp, hal, numerics as nu, segmenter as sg
from repro.core.costmodel import OpCost
from repro.optim.compression import dequantize_int8, quantize_int8

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

finite_f = st.floats(min_value=-60000, max_value=60000,
                     allow_nan=False, allow_infinity=False)


class TestNumericsProperties:
    @given(st.lists(finite_f, min_size=1, max_size=64))
    def test_round_fp16_idempotent(self, xs):
        x = np.array(xs)
        once = nu.round_fp16(x)
        assert np.array_equal(nu.round_fp16(once), once)

    @given(st.lists(st.integers(min_value=0, max_value=2048),
                    min_size=1, max_size=256))
    def test_wide_reduce_exact_for_small_integers(self, xs):
        # representable sums come back near exact (paper §3.2): integer
        # inputs with partials < 2^24 reduce exactly in the wide register
        # as long as in-tile fp16 partials stay on-grid (<= 2048 each, tile
        # of 4 -> partial <= 8192, grid spacing 4 ... so use <= 511 values)
        xs = [min(x, 511) for x in xs]
        v = np.array(xs, dtype=np.float64)
        got = nu.wide_reduce(v)
        # in-tile partials <= 4*511 < 2048: every partial is fp16-exact
        assert got == float(np.sum(v))

    @given(finite_f)
    def test_engine_never_emits_nan(self, x):
        for fn in (nu.ane_relu, nu.ane_sqrt, nu.ane_log, nu.ane_reciprocal,
                   nu.ane_exp):
            out = np.asarray(fn(x))
            assert not np.any(np.isnan(out)), fn.__name__

    @given(st.floats(min_value=-9.0, max_value=8.0, allow_nan=False))
    def test_lut_sigmoid_monotone_and_bounded(self, x):
        t = nu.build_lut("sigmoid")
        y = float(t(np.array([x]))[0])
        y2 = float(t(np.array([x + 0.25]))[0])
        assert 0.0 <= y <= 1.0
        assert y2 >= y - 1e-3   # monotone up to fp16 grid jitter

    @given(st.lists(finite_f, min_size=2, max_size=32),
           st.lists(finite_f, min_size=2, max_size=32))
    def test_matmul_saturation_monotone(self, a_vals, b_vals):
        # if the exact |result| of a 1x1 contraction exceeds 2^15, the
        # oracle yields inf; below 2^15 - margin it stays finite
        n = min(len(a_vals), len(b_vals))
        a = np.array(a_vals[:n])[None, :] / 100.0
        b = np.array(b_vals[:n])[:, None] / 100.0
        out = nu.ane_matmul(a, b)[0, 0]
        partials = np.cumsum(nu.coerce_input(a)[0] * nu.coerce_input(b)[:, 0])
        if np.all(np.abs(partials) < 32000):
            assert np.isfinite(out)


class TestCompressionProperties:
    @given(st.integers(min_value=1, max_value=7))
    def test_int8_roundtrip_relative_error(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(64, 32)).astype(np.float32)
        err = cp.accuracy_error(hal.WeightForm.INT8, w)
        assert err < 0.02   # paper: ~1% relative vs fp32 reference

    @given(st.integers(min_value=1, max_value=7))
    def test_stored_bytes_ordering(self, seed):
        # int4 < blockwise ~ int8 < sparse-ish < fp16 (dense)
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(64, 32)).astype(np.float32)
        sizes = {f: cp.encode(f, w).stored_bytes
                 for f in (hal.WeightForm.INT4_PALETTE, hal.WeightForm.INT8,
                           hal.WeightForm.SPARSE)}
        dense = cp.encode(hal.WeightForm.FP16, w).stored_bytes
        assert sizes[hal.WeightForm.INT4_PALETTE] < sizes[hal.WeightForm.INT8]
        assert all(s < dense for s in sizes.values())

    @given(st.integers(min_value=0, max_value=9))
    def test_grad_quantize_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        g = rng.normal(size=(777,)).astype(np.float32) * 10.0 ** float(rng.integers(-3, 3))
        import jax.numpy as jnp
        q, s = quantize_int8(jnp.asarray(g))
        back = np.asarray(dequantize_int8(q, s, g.shape))
        denom = np.linalg.norm(g) + 1e-12
        assert np.linalg.norm(back - g) / denom < 0.01

    @given(st.integers(min_value=1, max_value=5))
    def test_streaming_never_moves_more_than_dense(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(64, 32)).astype(np.float32)
        for form in (hal.WeightForm.INT4_PALETTE, hal.WeightForm.SPARSE,
                     hal.WeightForm.INT8, hal.WeightForm.BLOCKWISE):
            p = cp.encode(form, w)
            for target in (hal.ANE_M1, hal.ANE_M5, hal.TPU_V5E):
                assert cp.dram_bytes(p, target) <= p.dense_bytes + 64


class TestSegmenterProperties:
    @given(st.integers(min_value=1, max_value=200),
           st.integers(min_value=2, max_value=6))
    def test_dijkstra_optimal_vs_bruteforce(self, seed, n_ops):
        rng = np.random.default_rng(seed)
        ops = [OpCost(f"op{i}", float(rng.uniform(1e6, 1e12)),
                      float(rng.uniform(1e3, 1e9))) for i in range(n_ops)]
        d = sg.place(ops, sg.ANE_BACKENDS)
        b = sg.brute_force(ops, sg.ANE_BACKENDS)
        assert d.cost <= b.cost * (1 + 1e-12)

    @given(st.integers(min_value=1, max_value=50))
    def test_placement_covers_every_op(self, seed):
        rng = np.random.default_rng(seed)
        ops = [OpCost(f"op{i}", float(rng.uniform(1e6, 1e12)),
                      float(rng.uniform(1e3, 1e9))) for i in range(5)]
        p = sg.place(ops, sg.ANE_BACKENDS)
        assert len(p.backend) == len(ops)
        assert all(b in {"ane", "gpu", "cpu"} for b in p.backend)
