"""End-to-end training: convergence, checkpoint/restart determinism, fault
tolerance, serving driver."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.models.model import build_model
from repro.optim import adamw
from repro.runtime.fault_tolerance import (RestartPolicy, StragglerDetector,
                                           Heartbeat, run_with_restarts)


@pytest.mark.slow
def test_loss_decreases_tinyllama_smoke():
    out = train_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                         "--steps", "60", "--batch", "8", "--seq", "64",
                         "--lr", "3e-3", "--log-every", "10",
                         "--mesh", "none"])
    hist = out["loss_history"]
    assert hist[-1] < hist[0] - 0.5, hist
    assert np.isfinite(hist[-1])


@pytest.mark.slow
def test_grad_compression_trains():
    out = train_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                         "--steps", "40", "--batch", "8", "--seq", "64",
                         "--lr", "3e-3", "--grad-compression", "int8",
                         "--log-every", "10", "--mesh", "none"])
    assert out["loss_history"][-1] < out["loss_history"][0] - 0.3


@pytest.mark.slow
def test_checkpoint_restart_resumes_deterministically():
    """Train 30 steps straight vs 15 + crash + resume 15: identical params
    (the data pipeline is a pure function of (seed, step))."""
    cfg = configs.get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=30)
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step_fn = jax.jit(train_mod.make_train_step(model, opt_cfg))

    def train(n_start, n_end, params, opt):
        for t in range(n_start, n_end):
            batch = {k: jnp.asarray(v) for k, v in src.batch(t).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    p0 = model.init(jax.random.PRNGKey(0))
    o0 = adamw.init_state(opt_cfg, p0)
    p_straight, _ = train(0, 30, p0, o0)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        p1 = model.init(jax.random.PRNGKey(0))
        o1 = adamw.init_state(opt_cfg, p1)
        p1, o1 = train(0, 15, p1, o1)
        mgr.save(15, (p1, o1))
        # simulate crash: fresh process state, restore, continue
        pr = model.init(jax.random.PRNGKey(0))
        orr = adamw.init_state(opt_cfg, pr)
        (pr, orr), step = mgr.restore((pr, orr))
        assert step == 15
        pr = jax.tree.map(jnp.asarray, pr)
        orr = jax.tree.map(jnp.asarray, orr)
        p_resumed, _ = train(15, 30, pr, orr)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_supervisor_restarts_after_injected_crash():
    crashes = {"n": 0}
    progress = []

    def run_fn(start_step):
        step = 10 if start_step == -1 else 0   # "restored from checkpoint"
        while step < 30:
            step += 1
            progress.append(step)
            if step == 12 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("injected node failure")
        return step

    final = run_with_restarts(run_fn, policy=RestartPolicy(max_restarts=2))
    assert final == 30 and crashes["n"] == 1


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(window=8, threshold=1.5, patience=2)
    import time
    for step in range(8):
        for host in range(4):
            wall = 1.0 if host != 2 else 3.0     # host 2 is slow
            det.record(Heartbeat(host, step, wall, time.time()))
        flagged = det.evaluate()
    assert flagged == [2]


def test_checkpoint_atomicity_ignores_torn_write():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"w": jnp.ones((4,))}
        mgr.save(1, tree)
        # simulate a torn write: step dir without COMMIT
        torn = os.path.join(d, "step_000000002")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.json"), "w") as f:
            f.write("{}")
        assert mgr.latest_step() == 1
        restored, step = mgr.restore(tree)
        assert step == 1


def test_serve_driver_generates():
    out = serve_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                         "--batch", "2", "--prompt-len", "16", "--gen", "8"])
    assert out["tokens"].shape == (2, 8)
    assert out["tok_per_s"] > 0


def test_serve_greedy_matches_decode_parity_source():
    """Serving greedy decode equals argmax over teacher-forced logits when
    the prompt continuation is fed back (self-consistency of the driver)."""
    cfg = configs.get_smoke("mamba2-1.3b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
    caches, lg = jax.jit(m.prefill)(params, {"tokens": tokens})
    caches_d = m.init_cache(1, 20)
    caches_d = serve_mod._merge_prefill(m, caches_d, caches, 12)
    tok = jnp.argmax(lg[:, -1, :cfg.vocab], -1)[:, None].astype(jnp.int32)
    caches_d, lg2 = jax.jit(m.decode_step)(params, caches_d, tok,
                                           jnp.array([12], jnp.int32))
    # teacher-forced check: full forward over prompt+tok gives same logits
    full = jnp.concatenate([tokens, tok], axis=1)
    pos = jnp.arange(13)[None]
    h, _, _ = m.forward(params, full, pos, mode="train")
    from repro.models.layers import logits as logits_fn
    lg_full = logits_fn(cfg, params["embed"], h)[:, -1]
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(lg_full),
                               rtol=2e-2, atol=2e-2)
