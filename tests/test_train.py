"""End-to-end training: convergence, checkpoint/restart determinism, fault
tolerance, serving driver."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.models.model import build_model
from repro.optim import adamw
from repro.runtime.fault_tolerance import (RestartPolicy, StragglerDetector,
                                           Heartbeat, run_with_restarts)


@pytest.mark.slow
def test_loss_decreases_tinyllama_smoke():
    out = train_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                         "--steps", "60", "--batch", "8", "--seq", "64",
                         "--lr", "3e-3", "--log-every", "10",
                         "--mesh", "none"])
    hist = out["loss_history"]
    assert hist[-1] < hist[0] - 0.5, hist
    assert np.isfinite(hist[-1])


@pytest.mark.slow
def test_grad_compression_trains():
    out = train_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                         "--steps", "40", "--batch", "8", "--seq", "64",
                         "--lr", "3e-3", "--grad-compression", "int8",
                         "--log-every", "10", "--mesh", "none"])
    assert out["loss_history"][-1] < out["loss_history"][0] - 0.3


@pytest.mark.slow
def test_checkpoint_restart_resumes_deterministically():
    """Train 30 steps straight vs 15 + crash + resume 15: identical params
    (the data pipeline is a pure function of (seed, step))."""
    cfg = configs.get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=30)
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step_fn = jax.jit(train_mod.make_train_step(model, opt_cfg))

    def train(n_start, n_end, params, opt):
        for t in range(n_start, n_end):
            batch = {k: jnp.asarray(v) for k, v in src.batch(t).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    p0 = model.init(jax.random.PRNGKey(0))
    o0 = adamw.init_state(opt_cfg, p0)
    p_straight, _ = train(0, 30, p0, o0)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        p1 = model.init(jax.random.PRNGKey(0))
        o1 = adamw.init_state(opt_cfg, p1)
        p1, o1 = train(0, 15, p1, o1)
        mgr.save(15, (p1, o1))
        # simulate crash: fresh process state, restore, continue
        pr = model.init(jax.random.PRNGKey(0))
        orr = adamw.init_state(opt_cfg, pr)
        (pr, orr), step = mgr.restore((pr, orr))
        assert step == 15
        pr = jax.tree.map(jnp.asarray, pr)
        orr = jax.tree.map(jnp.asarray, orr)
        p_resumed, _ = train(15, 30, pr, orr)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_supervisor_restarts_after_injected_crash():
    crashes = {"n": 0}
    progress = []

    def run_fn(start_step):
        step = 10 if start_step == -1 else 0   # "restored from checkpoint"
        while step < 30:
            step += 1
            progress.append(step)
            if step == 12 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("injected node failure")
        return step

    final = run_with_restarts(run_fn, policy=RestartPolicy(max_restarts=2))
    assert final == 30 and crashes["n"] == 1


def test_supervisor_default_policy_is_fresh_per_call():
    """Regression: `policy` used to default to a module-level
    `RestartPolicy()` instance — one caller mutating it would change every
    other caller's retry budget. The default must be None, constructing a
    fresh policy inside each call."""
    import inspect
    assert inspect.signature(run_with_restarts) \
        .parameters["policy"].default is None

    calls = {"n": 0}

    def flaky(start_step):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return 42

    assert run_with_restarts(flaky) == 42      # default budget covers 2
    assert calls["n"] == 3


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(window=8, threshold=1.5, patience=2)
    import time
    for step in range(8):
        for host in range(4):
            wall = 1.0 if host != 2 else 3.0     # host 2 is slow
            det.record(Heartbeat(host, step, wall, time.time()))
        flagged = det.evaluate()
    assert flagged == [2]


def test_checkpoint_atomicity_ignores_torn_write():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"w": jnp.ones((4,))}
        mgr.save(1, tree)
        # simulate a torn write: step dir without COMMIT
        torn = os.path.join(d, "step_000000002")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.json"), "w") as f:
            f.write("{}")
        assert mgr.latest_step() == 1
        restored, step = mgr.restore(tree)
        assert step == 1


def test_serve_driver_generates():
    out = serve_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                         "--batch", "2", "--prompt-len", "16", "--gen", "8"])
    assert out["tokens"].shape == (2, 8)
    assert out["tok_per_s"] > 0


def test_serve_greedy_matches_decode_parity_source():
    """Serving greedy decode equals argmax over teacher-forced logits when
    the prompt continuation is fed back (self-consistency of the driver)."""
    cfg = configs.get_smoke("mamba2-1.3b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
    caches, lg = jax.jit(m.prefill)(params, {"tokens": tokens})
    caches_d = m.init_cache(1, 20)
    caches_d = serve_mod._merge_prefill(m, caches_d, caches, 12)
    tok = jnp.argmax(lg[:, -1, :cfg.vocab], -1)[:, None].astype(jnp.int32)
    caches_d, lg2 = jax.jit(m.decode_step)(params, caches_d, tok,
                                           jnp.array([12], jnp.int32))
    # teacher-forced check: full forward over prompt+tok gives same logits
    full = jnp.concatenate([tokens, tok], axis=1)
    pos = jnp.arange(13)[None]
    h, _, _ = m.forward(params, full, pos, mode="train")
    from repro.models.layers import logits as logits_fn
    lg_full = logits_fn(cfg, params["embed"], h)[:, -1]
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(lg_full),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Distillation tier: the drafter that makes speculation win (launch.distill)
# ---------------------------------------------------------------------------

import functools  # noqa: E402


@functools.lru_cache(maxsize=1)
def _distill_bundle():
    """One small teacher-train + distill run shared by the tier (same knobs
    as the bench's --fast inline pipeline)."""
    from repro.launch import distill as distill_mod
    cfg = configs.get_smoke("tinyllama-1.1b")
    out = distill_mod.distill_pipeline(
        cfg, teacher_steps=60, steps=80, batch=8, seq=48, lr=3e-3,
        kl_weight=0.75, temperature=1.0, seed=0, eval_steps=8, log_every=40)
    return cfg, out


def _collect_weight_forms(node, acc):
    from repro.models.dispatched import DispatchedWeight
    if isinstance(node, DispatchedWeight):
        acc.append(node.form.value)
    elif isinstance(node, dict):
        for v in node.values():
            _collect_weight_forms(v, acc)
    elif isinstance(node, (list, tuple)):
        for v in node:
            _collect_weight_forms(v, acc)
    return acc


@pytest.mark.slow
def test_distill_loss_decreases_and_tracks_teacher():
    """The KL+CE distillation loss strictly decreases through the shared
    train-step machinery, and the student's held-out teacher-rollout
    agreement lands far above chance (= the quantity speculative
    acceptance tracks)."""
    cfg, out = _distill_bundle()
    hist = out["history"]
    assert len(hist) >= 2
    assert hist[-1] < hist[0], hist
    assert np.isfinite(hist[-1])
    assert out["agreement"] >= 0.6, out["agreement"]


@pytest.mark.slow
def test_distilled_drafter_beats_random_acceptance():
    """Through the REAL SpeculativeSchedule on held-out motif prompts: the
    distilled student clears the bench's acceptance bar, the random-init
    placebo does not (the regression this tier exists to pin)."""
    from repro.core import hal
    from repro.core.dispatch import (AsyncExecutionStream, KernelDispatcher,
                                     ProgramCache)
    from repro.launch.scheduler import Request
    from repro.launch.speculative import Drafter, SpeculativeSchedule

    cfg, out = _distill_bundle()
    target = hal.get_target("tpu-v5e")
    model = build_model(cfg, dispatcher=KernelDispatcher(target))
    tparams = out["teacher_params"]
    n, plen, gen = 6, 24, 8
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=plen,
                                 global_batch=n, seed=21))
    toks = src.prompt_batch(0, n, plen)

    def acceptance(drafter):
        sched = SpeculativeSchedule(
            model, tparams, cfg, n_slots=n, max_len=plen + gen,
            sampling="greedy", seed=0, draft_depth=2, drafter=drafter,
            stream=AsyncExecutionStream(ProgramCache(), target=target))
        sched.run([Request(rid=i, prompt=np.asarray(toks[i], np.int32),
                           max_new_tokens=gen) for i in range(n)])
        assert sched.proposed > 0
        return sched.acceptance_rate

    trained = acceptance(Drafter.shrink(cfg, dispatcher=model.dispatcher,
                                        params=out["student_params"]))
    random = acceptance(Drafter.shrink(cfg, dispatcher=model.dispatcher))
    assert trained >= 0.4, (trained, random)
    assert trained > random, (trained, random)


@pytest.mark.slow
def test_distill_cli_checkpoint_roundtrip(tmp_path):
    """The CLI writes teacher/ and student/ checkpoints with metadata
    sidecars; `Drafter.shrink(ckpt=...)` restores the student and rejects
    a mismatched target config loudly."""
    from repro.launch import distill as distill_mod
    from repro.launch.speculative import Drafter

    d = str(tmp_path / "distill")
    out = distill_mod.run(["--arch", "tinyllama-1.1b", "--smoke",
                           "--teacher-steps", "40", "--steps", "50",
                           "--seq", "32", "--log-every", "25",
                           "--ckpt-dir", d])
    assert out["loss_history"][-1] < out["loss_history"][0]

    cfg = configs.get_smoke("tinyllama-1.1b")
    meta = CheckpointManager(os.path.join(d, "student")).metadata()
    assert meta["role"] == "draft-student"
    assert meta["vocab"] == cfg.vocab
    assert meta["target_arch"] == cfg.name
    assert 0.0 <= meta["agreement_top1"] <= 1.0
    drafter = Drafter.shrink(cfg, ckpt=os.path.join(d, "student"))
    assert drafter.trained
    assert drafter.cfg.vocab == cfg.vocab

    # the full (non-smoke) config serves a different vocab: rejected before
    # any array loads
    with pytest.raises(ValueError, match="vocab"):
        Drafter.shrink(configs.get_config("tinyllama-1.1b"),
                       ckpt=os.path.join(d, "student"))
    # a missing checkpoint directory is loud too
    with pytest.raises(FileNotFoundError):
        Drafter.shrink(cfg, ckpt=str(tmp_path / "nope"))


@pytest.mark.slow
def test_drafter_params_route_rejects_mismatch():
    """`Drafter.shrink(params=...)` validates the tree loudly: a missing
    subtree and a wrong-shape embed both name the problem."""
    from repro.launch.speculative import Drafter

    cfg, out = _distill_bundle()
    good = out["student_params"]
    drafter = Drafter.shrink(cfg, params=good)
    assert drafter.trained

    bad = {k: v for k, v in good.items() if k != "embed"}
    with pytest.raises(ValueError, match="param tree"):
        Drafter.shrink(cfg, params=bad)

    clipped = dict(good, embed=jax.tree.map(
        lambda x: np.asarray(x)[..., :-1], good["embed"]))
    with pytest.raises(ValueError, match="vocab|shape"):
        Drafter.shrink(cfg, params=clipped)


@pytest.mark.slow
def test_packed_student_checkpoint_roundtrips_form_tags(tmp_path):
    """A student checkpoint saved in a packed weight form restores through
    `Drafter.shrink(ckpt=...)` with its `DispatchedWeight` form tags intact
    (no silent fold to dense)."""
    from repro.core import hal
    from repro.core.dispatch import KernelDispatcher
    from repro.launch import distill as distill_mod
    from repro.launch.speculative import Drafter
    from repro.optim.compression import compress_model_params

    cfg, out = _distill_bundle()
    packed = compress_model_params(out["student_params"], "int4_palette")
    d = str(tmp_path / "student")
    CheckpointManager(d).save(
        1, packed, metadata=distill_mod._metadata(
            out["student_cfg"], "draft-student",
            weight_form="int4_palette", target_arch=cfg.name))

    drafter = Drafter.shrink(
        cfg, dispatcher=KernelDispatcher(hal.get_target("tpu-v5e")), ckpt=d)
    assert drafter.trained
    forms = _collect_weight_forms(drafter.params, [])
    assert forms, "no DispatchedWeight nodes survived the round-trip"
    assert all(f == "int4_palette" for f in forms), set(forms)
