"""Continuous-batching scheduler suite (tier: serve).

Four load-bearing properties of `repro.launch.scheduler`:

  * **token-exact parity** — the continuous schedule (bucketed prefill +
    teacher-forced catch-up + slot-masked batched decode + mid-flight
    admission) produces exactly the sequential reference's greedy token
    stream, per request, over config x weight form.
  * **bounded compile set** — heterogeneous prompt lengths hit the
    content-hash ProgramCache with at most `#buckets` prefill programs and
    one decode program: misses <= #buckets x {prefill, decode}.
  * **mid-flight admission** — a request arriving while other lanes are
    mid-generation is admitted into a freed lane without disturbing them.
  * **ExecutionStream accounting** — records keep encode order, charge the
    costmodel floor (`work_s = max(0, wall - floor)`), report queue depth,
    and `execute_sync` always returns a list.

Plus the `_merge_prefill` regression: prefill caches merge into decode
buffers by *named time axis*, raising with the tree path on any rank or
off-axis mismatch (SSM/RG-LRU recurrent state must never be dropped).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import hal
from repro.core.dispatch import (ExecutionStream, KernelDispatcher,
                                 ProgramCache)
from repro.launch import serve as serve_mod
from repro.launch.scheduler import (ContinuousSchedule, Request,
                                    SequentialSchedule, TokenSampler,
                                    bucket_for, default_buckets,
                                    make_scheduler, merge_prefill_caches)
from repro.models.model import build_model
from repro.optim.compression import compress_model_params

V5E = hal.get_target("tpu-v5e")


@functools.lru_cache(maxsize=None)
def _served_model(arch: str, form: str, dispatched: bool = True):
    cfg = configs.get_smoke(arch)
    disp = KernelDispatcher(V5E) if dispatched else None
    model = build_model(cfg, dispatcher=disp)
    params = model.init(jax.random.PRNGKey(0))
    if form != "fp16":
        params = compress_model_params(params, form)
    return cfg, model, params


def _requests(cfg, lens, gen, arrivals=None, seed=1):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0] * len(lens)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32),
                    max_new_tokens=gen, arrival=a)
            for i, (L, a) in enumerate(zip(lens, arrivals))]


def _serve(schedule, arch, form, lens, gen, *, n_slots=3, arrivals=None,
           sampling="greedy", buckets=None, max_len=None):
    cfg, model, params = _served_model(arch, form)
    cache = ProgramCache()
    stream = ExecutionStream(cache, target=V5E)
    sched = make_scheduler(schedule, model, params, cfg, n_slots=n_slots,
                           max_len=max_len or max(lens) + gen,
                           sampling=sampling, seed=0, stream=stream,
                           buckets=buckets)
    results = sched.run(_requests(cfg, lens, gen, arrivals))
    return {r.rid: r for r in results}, sched


# ---------------------------------------------------------------------------
# Token-exact parity: continuous vs the sequential reference
# ---------------------------------------------------------------------------

# heterogeneous lengths on purpose: one below the smallest bucket
# (decode-only admission), one bucket-exact, two in-between (catch-up)
PARITY_LENS = [24, 6, 17, 16]

FAST_PARITY = [("tinyllama-1.1b", "fp16")]
SLOW_PARITY = [("tinyllama-1.1b", "int4_palette"),
               ("mamba2-1.3b", "fp16"),
               ("recurrentgemma-9b", "fp16"),
               ("granite-8b", "fp16")]


def _check_parity(arch, form):
    cont, csched = _serve("continuous", arch, form, PARITY_LENS, gen=6)
    seq, _ = _serve("sequential", arch, form, PARITY_LENS, gen=6)
    assert set(cont) == set(seq) == set(range(len(PARITY_LENS)))
    for rid in cont:
        np.testing.assert_array_equal(
            cont[rid].tokens, seq[rid].tokens,
            err_msg=f"{arch}/{form} rid={rid}: continuous schedule diverged "
                    f"from the sequential greedy reference")
        assert cont[rid].tokens.size == 6
    # the sub-bucket prompt went through decode-only admission
    assert cont[1].bucket == 0 and cont[3].bucket == 16


@pytest.mark.parametrize("arch,form", FAST_PARITY)
def test_greedy_parity(arch, form):
    _check_parity(arch, form)


@pytest.mark.slow
@pytest.mark.parametrize("arch,form", SLOW_PARITY)
def test_greedy_parity_sweep(arch, form):
    _check_parity(arch, form)


@pytest.mark.slow
def test_greedy_parity_encdec():
    """Encoder-decoder serving: the cross-attention cache is built at
    prefill and admitted into the lane alongside the self cache."""
    cfg, model, params = _served_model("whisper-small", "fp16")
    rng = np.random.default_rng(1)
    lens = [16, 9, 12]
    prompts = [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
               for L in lens]
    frames = [np.asarray(rng.normal(size=(cfg.encoder_len, cfg.d_model)),
                         np.float32) for _ in lens]
    outs = {}
    for schedule in ("continuous", "sequential"):
        sched = make_scheduler(schedule, model, params, cfg, n_slots=2,
                               max_len=24, sampling="greedy", seed=0)
        res = sched.run([Request(rid=i, prompt=prompts[i], max_new_tokens=4,
                                 frames=frames[i]) for i in range(3)])
        outs[schedule] = {r.rid: r.tokens for r in res}
    for rid in range(3):
        np.testing.assert_array_equal(outs["continuous"][rid],
                                      outs["sequential"][rid])
    # encdec prompts must reach a prefill bucket (cross cache): loud check
    with pytest.raises(ValueError, match="bucket"):
        make_scheduler("continuous", model, params, cfg, n_slots=1,
                       max_len=24).run(
            [Request(rid=0, prompt=prompts[0][:4], max_new_tokens=2,
                     frames=frames[0])])


# ---------------------------------------------------------------------------
# Bucketing: bounded compile set through the ProgramCache
# ---------------------------------------------------------------------------


def test_continuous_rejects_zero_slots():
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    with pytest.raises(ValueError, match="n_slots"):
        ContinuousSchedule(model, params, cfg, n_slots=0, max_len=16)


def test_bucket_for():
    assert default_buckets(40) == (8, 16, 32)
    assert bucket_for(24, (8, 16, 32)) == 16
    assert bucket_for(32, (8, 16, 32)) == 32
    assert bucket_for(5, (8, 16, 32)) == 0


def test_bucketing_compile_count_bound():
    buckets = (8, 16)
    lens = [9, 10, 17, 18, 20, 12]       # 6 distinct-ish lengths, 2 buckets
    _, sched = _serve("continuous", "tinyllama-1.1b", "fp16", lens, gen=3,
                      n_slots=3, buckets=buckets, max_len=32)
    misses = sched.cache.stats.misses
    # the issue's bound: #buckets x {prefill, decode}
    assert misses <= 2 * len(buckets), \
        f"{misses} compiles for {len(buckets)} buckets"
    # and the exact expectation: one prefill per used bucket + one decode
    assert misses == len({bucket_for(L, buckets) for L in lens}) + 1
    # every later dispatch warm-started from the content-hash cache
    assert sched.cache.stats.hits > 0


# ---------------------------------------------------------------------------
# Mid-flight admission
# ---------------------------------------------------------------------------


def test_midflight_admission_correctness():
    lens = [16, 12, 14]
    gens = 8
    # two lanes; request 2 arrives at step 2 and must wait for a free lane
    arrivals = [0, 0, 2]
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", lens, gen=gens,
                     n_slots=2, arrivals=arrivals)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", lens, gen=gens,
                    arrivals=arrivals)
    for rid in range(3):
        np.testing.assert_array_equal(cont[rid].tokens, seq[rid].tokens)
    # request 2 was admitted after the others started...
    assert cont[2].admitted_step > 0
    # ...and while another lane was still generating (true mid-flight:
    # somebody finished only after the newcomer joined)
    assert any(cont[r].finished_step >= cont[2].admitted_step
               for r in (0, 1))


# ---------------------------------------------------------------------------
# ExecutionStream records and ordering
# ---------------------------------------------------------------------------


def test_execute_sync_always_returns_list():
    cache = ProgramCache()
    compiled, key = cache.compile(lambda x: x + 1, jnp.zeros((4,)))
    stream = ExecutionStream(cache, target=V5E)
    stream.encode_operation(compiled, (jnp.zeros((4,)),), key)
    outs = stream.execute_sync()
    assert isinstance(outs, list) and len(outs) == 1
    assert stream.execute_sync() == []        # empty queue -> empty list


def test_stream_records_floor_and_order():
    cache = ProgramCache()
    compiled, key = cache.compile(lambda x: x * 2, jnp.zeros((8,)))
    stream = ExecutionStream(cache, target=hal.get_target("ane-m1"))
    assert stream.floor_s == hal.ANE_M1.dispatch_floor_s
    # encode-many / execute-once: three ops, one submission
    for i in range(3):
        stream.encode_operation(compiled, (jnp.full((8,), float(i)),),
                                f"op{i}", batch=i + 1)
    assert stream.queue_depth == 3
    outs = stream.execute_sync()
    assert len(outs) == 3 and stream.queue_depth == 0
    assert [r.key for r in stream.records] == ["op0", "op1", "op2"]
    assert [r.queue_depth for r in stream.records] == [0, 1, 2]
    assert [r.batch for r in stream.records] == [1, 2, 3]
    seqs = [r.seq for r in stream.records]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    for r in stream.records:
        # work_s populated from the costmodel floor, not the 0.0 placeholder
        assert r.floor_s == hal.ANE_M1.dispatch_floor_s
        assert r.work_s == pytest.approx(max(0.0, r.wall_s - r.floor_s))
    assert stream.total_floor_s() == pytest.approx(3 * stream.floor_s)


def test_scheduler_stream_invariants():
    _, sched = _serve("continuous", "tinyllama-1.1b", "fp16", [16, 9], gen=4,
                      n_slots=2)
    recs = sched.stream.records
    assert len(recs) >= 3                      # >= 1 prefill + decode steps
    seqs = [r.seq for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert all(r.floor_s == V5E.dispatch_floor_s for r in recs)
    assert all(r.work_s >= 0.0 for r in recs)
    # decode dispatches carry the active-lane count as the batch denominator
    assert max(r.batch for r in recs) == 2
    stats = sched.stats(2)
    assert stats["per_request_dispatch_overhead_s"] == pytest.approx(
        len(recs) * V5E.dispatch_floor_s / 2)


# ---------------------------------------------------------------------------
# Prefill-cache merge: loud failure + named time axis
# ---------------------------------------------------------------------------


def test_merge_rank_mismatch_raises_with_path():
    dec = {"layer": {"state": jnp.zeros((2, 1, 4, 8))}}
    pf = {"layer": {"state": jnp.zeros((2, 1, 4))}}
    with pytest.raises(ValueError, match=r"layer/state.*rank"):
        merge_prefill_caches(dec, pf)


def test_merge_unnamed_axis_mismatch_raises_with_path():
    # batch-axis mismatch on a recurrent leaf: not a named time axis
    dec = {"g0": {"h": jnp.zeros((1, 4, 8))}}
    pf = {"g0": {"h": jnp.zeros((1, 1, 8))}}
    with pytest.raises(ValueError, match=r"g0/h"):
        merge_prefill_caches(dec, pf)
    # a KV leaf may only differ on its single time axis, not on heads too
    dec = {"g0": {"k": jnp.zeros((1, 1, 8, 2, 4))}}
    pf = {"g0": {"k": jnp.zeros((1, 1, 6, 3, 4))}}
    with pytest.raises(ValueError, match=r"g0/k"):
        merge_prefill_caches(dec, pf)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b"])
def test_merge_preserves_recurrent_state(arch):
    """The historical bug: `_merge_prefill` silently returned the empty
    decode buffer when a prefill leaf did not line up, dropping SSM conv /
    RG-LRU recurrent state. The named-time-axis merge must carry every
    recurrent leaf through verbatim and leave the unwritten KV tail
    invalid."""
    cfg, model, params = _served_model(arch, "fp16", dispatched=False)
    rng = np.random.default_rng(0)
    s, max_len = 12, 20
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(1, s)), jnp.int32)}
    pf_caches, _ = jax.jit(model.prefill)(params, batch)
    merged = serve_mod._merge_prefill(model, model.init_cache(1, max_len),
                                      pf_caches, s)

    from repro.kernels import compat
    pf_leaves = {compat.tree_path_str(p): v for p, v in
                 compat.tree_flatten_with_path(pf_caches)[0]}
    any_recurrent = False
    for path, leaf in compat.tree_flatten_with_path(merged)[0]:
        loc = compat.tree_path_str(path)
        name = loc.rsplit("/", 1)[-1]
        src = pf_leaves[loc]
        if name == "pos":
            np.testing.assert_array_equal(
                np.asarray(leaf)[..., :s], np.asarray(src))
            assert np.all(np.asarray(leaf)[..., s:] == -1)
        elif leaf.shape == src.shape:          # recurrent/conv state leaves
            any_recurrent = True
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(src))
            assert np.any(np.asarray(leaf) != 0), \
                f"{loc}: prefill state was dropped"
    assert any_recurrent, f"{arch}: no recurrent state leaf was checked"


# ---------------------------------------------------------------------------
# Sampling modes (the --greedy no-op regression)
# ---------------------------------------------------------------------------


def test_sampler_modes_are_distinct_and_deterministic():
    vocab = 64
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(vocab,)).astype(np.float32)
    greedy = TokenSampler("greedy", vocab, seed=0)
    cat = TokenSampler("categorical", vocab, seed=0)
    # greedy ignores rid/position; categorical is keyed by (seed, rid, pos)
    assert greedy(logits, 0, 5) == greedy(logits, 3, 9) == int(np.argmax(logits))
    draws = [cat(np.zeros(vocab, np.float32), 0, p) for p in range(20)]
    assert len(set(draws)) > 1, "categorical sampling is not sampling"
    redraw = [TokenSampler("categorical", vocab, seed=0)(
        np.zeros(vocab, np.float32), 0, p) for p in range(20)]
    assert draws == redraw, "categorical sampling must be seed-deterministic"
    with pytest.raises(ValueError, match="sampling mode"):
        TokenSampler("nucleus", vocab, seed=0)


@pytest.mark.parametrize("sampling", ["greedy", "categorical"])
def test_serve_smoke_covers_sampling_modes(sampling):
    out = serve_mod.run(["--smoke", "--batch", "2", "--prompt-len", "8",
                         "--gen", "4", "--schedule", "continuous",
                         "--sampling", sampling, "--requests", "2"])
    assert out["tokens"].shape == (2, 4)
    assert out["sampling"] == sampling
    assert out["cache_hits"] > 0              # round 2 warm-started
    # same invocation -> same seeded token streams (rids included: the
    # categorical key is fold_in(fold_in(seed, rid), position))
    rerun = serve_mod.run(["--smoke", "--batch", "2", "--prompt-len", "8",
                           "--gen", "4", "--schedule", "continuous",
                           "--sampling", sampling, "--requests", "2"])
    np.testing.assert_array_equal(out["tokens"], rerun["tokens"])
    if sampling == "greedy":
        # lane-reuse hygiene: round 2 runs on recycled decode lanes, and
        # greedy ignores rids — stale KV leaking past the pos mask would
        # make the rounds diverge
        single = serve_mod.run(["--smoke", "--batch", "2", "--prompt-len",
                                "8", "--gen", "4", "--schedule",
                                "continuous", "--sampling", sampling])
        np.testing.assert_array_equal(out["tokens"], single["tokens"])


@pytest.mark.slow
def test_sampling_parity_categorical():
    """Categorical streams are keyed per (request, position), so they are
    schedule-invariant exactly like greedy."""
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", [16, 9], gen=5,
                     n_slots=2, sampling="categorical")
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [16, 9], gen=5,
                    sampling="categorical")
    for rid in cont:
        np.testing.assert_array_equal(cont[rid].tokens, seq[rid].tokens)
