"""Continuous-batching + overlapped-stream scheduler suite (tier: serve).

Load-bearing properties of `repro.launch.scheduler`:

  * **token-exact parity** — the continuous schedule (bucketed prefill +
    teacher-forced catch-up + slot-masked batched decode + mid-flight
    admission) AND the overlapped SLO schedule (pipelined decode windows on
    `AsyncExecutionStream`, sampling fused on device) produce exactly the
    sequential reference's token stream, per request, over config x weight
    form x sampling mode.
  * **bounded compile set** — heterogeneous prompt lengths hit the
    content-hash ProgramCache with at most `#buckets` prefill programs and
    one decode program: misses <= #buckets x {prefill, decode}.
  * **mid-flight admission** — a request arriving while other lanes are
    mid-generation is admitted into a freed lane without disturbing them;
    under an SLO the gate may defer but never starve.
  * **stream record invariants** — sync and async drains both keep a total
    encode order (`seq`), charge the costmodel floor
    (`work_s = max(0, wall - floor)`), carry submit <= complete timestamps,
    and keep the in-flight depth within the submission window.

Plus the `_merge_prefill` regression: prefill caches merge into decode
buffers by *named time axis*, raising with the tree path on any rank or
off-axis mismatch (SSM/RG-LRU recurrent state must never be dropped).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import hal
from repro.core.dispatch import (AsyncExecutionStream, ExecutionStream,
                                 KernelDispatcher, ProgramCache)
from repro.launch import serve as serve_mod
from repro.launch.scheduler import (ContinuousSchedule, Request,
                                    SequentialSchedule, SLOSchedule,
                                    TokenSampler, bucket_for,
                                    default_buckets, make_scheduler,
                                    merge_prefill_caches)
from repro.launch.speculative import Drafter, SpeculativeSchedule, draft_of
from repro.models.model import build_model
from repro.optim.compression import compress_model_params

V5E = hal.get_target("tpu-v5e")


@functools.lru_cache(maxsize=None)
def _served_model(arch: str, form: str, dispatched: bool = True):
    cfg = configs.get_smoke(arch)
    disp = KernelDispatcher(V5E) if dispatched else None
    model = build_model(cfg, dispatcher=disp)
    params = model.init(jax.random.PRNGKey(0))
    if form != "fp16":
        params = compress_model_params(params, form)
    return cfg, model, params


def _requests(cfg, lens, gen, arrivals=None, seed=1):
    rng = np.random.default_rng(seed)
    arrivals = arrivals or [0] * len(lens)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32),
                    max_new_tokens=gen, arrival=a)
            for i, (L, a) in enumerate(zip(lens, arrivals))]


def _serve(schedule, arch, form, lens, gen, *, n_slots=3, arrivals=None,
           sampling="greedy", buckets=None, max_len=None, **sched_kw):
    cfg, model, params = _served_model(arch, form)
    cache = ProgramCache()
    stream = (AsyncExecutionStream(cache, target=V5E)
              if schedule in ("slo", "spec")
              else ExecutionStream(cache, target=V5E))
    sched = make_scheduler(schedule, model, params, cfg, n_slots=n_slots,
                           max_len=max_len or max(lens) + gen,
                           sampling=sampling, seed=0, stream=stream,
                           buckets=buckets, **sched_kw)
    results = sched.run(_requests(cfg, lens, gen, arrivals))
    return {r.rid: r for r in results}, sched


# ---------------------------------------------------------------------------
# Token-exact parity: continuous vs the sequential reference
# ---------------------------------------------------------------------------

# heterogeneous lengths on purpose: one below the smallest bucket
# (decode-only admission), one bucket-exact, two in-between (catch-up)
PARITY_LENS = [24, 6, 17, 16]

FAST_PARITY = [("tinyllama-1.1b", "fp16")]
SLOW_PARITY = [("tinyllama-1.1b", "int4_palette"),
               ("mamba2-1.3b", "fp16"),
               ("recurrentgemma-9b", "fp16"),
               ("granite-8b", "fp16")]


def _check_parity(arch, form, schedule="continuous", **sched_kw):
    cont, csched = _serve(schedule, arch, form, PARITY_LENS, gen=6,
                          **sched_kw)
    seq, _ = _serve("sequential", arch, form, PARITY_LENS, gen=6)
    assert set(cont) == set(seq) == set(range(len(PARITY_LENS)))
    for rid in cont:
        np.testing.assert_array_equal(
            cont[rid].tokens, seq[rid].tokens,
            err_msg=f"{arch}/{form} rid={rid}: {schedule} schedule diverged "
                    f"from the sequential greedy reference")
        assert cont[rid].tokens.size == 6
    # the sub-bucket prompt went through decode-only admission
    assert cont[1].bucket == 0 and cont[3].bucket == 16
    return csched


@pytest.mark.parametrize("schedule", ["continuous", "slo"])
@pytest.mark.parametrize("arch,form", FAST_PARITY)
def test_greedy_parity(arch, form, schedule):
    _check_parity(arch, form, schedule)


@pytest.mark.slow
@pytest.mark.parametrize("schedule", ["continuous", "slo"])
@pytest.mark.parametrize("arch,form", SLOW_PARITY)
def test_greedy_parity_sweep(arch, form, schedule):
    _check_parity(arch, form, schedule)


def test_slo_vs_continuous_token_identical():
    """The pinned three-way: overlapped decode must be bit-identical to the
    serialized continuous schedule, not merely to the sequential
    reference (same bucketed prefills, same lane composition)."""
    slo, _ = _serve("slo", "tinyllama-1.1b", "fp16", PARITY_LENS, gen=6)
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", PARITY_LENS,
                     gen=6)
    for rid in cont:
        np.testing.assert_array_equal(slo[rid].tokens, cont[rid].tokens)
        assert slo[rid].bucket == cont[rid].bucket


@pytest.mark.slow
def test_greedy_parity_encdec():
    """Encoder-decoder serving: the cross-attention cache is built at
    prefill and admitted into the lane alongside the self cache."""
    cfg, model, params = _served_model("whisper-small", "fp16")
    rng = np.random.default_rng(1)
    lens = [16, 9, 12]
    prompts = [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
               for L in lens]
    frames = [np.asarray(rng.normal(size=cfg.frame_shape),
                         np.float32) for _ in lens]
    outs = {}
    for schedule in ("continuous", "slo", "sequential"):
        sched = make_scheduler(schedule, model, params, cfg, n_slots=2,
                               max_len=24, sampling="greedy", seed=0)
        res = sched.run([Request(rid=i, prompt=prompts[i], max_new_tokens=4,
                                 frames=frames[i]) for i in range(3)])
        outs[schedule] = {r.rid: r.tokens for r in res}
    for rid in range(3):
        np.testing.assert_array_equal(outs["continuous"][rid],
                                      outs["sequential"][rid])
        np.testing.assert_array_equal(outs["slo"][rid],
                                      outs["sequential"][rid])
    # encdec prompts must reach a prefill bucket (cross cache): loud check
    with pytest.raises(ValueError, match="bucket"):
        make_scheduler("continuous", model, params, cfg, n_slots=1,
                       max_len=24).run(
            [Request(rid=0, prompt=prompts[0][:4], max_new_tokens=2,
                     frames=frames[0])])


# ---------------------------------------------------------------------------
# Bucketing: bounded compile set through the ProgramCache
# ---------------------------------------------------------------------------


def test_continuous_rejects_zero_slots():
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    with pytest.raises(ValueError, match="n_slots"):
        ContinuousSchedule(model, params, cfg, n_slots=0, max_len=16)


def test_bucket_for():
    assert default_buckets(40) == (8, 16, 32)
    assert bucket_for(24, (8, 16, 32)) == 16
    assert bucket_for(32, (8, 16, 32)) == 32
    assert bucket_for(5, (8, 16, 32)) == 0


def test_bucketing_compile_count_bound():
    buckets = (8, 16)
    lens = [9, 10, 17, 18, 20, 12]       # 6 distinct-ish lengths, 2 buckets
    _, sched = _serve("continuous", "tinyllama-1.1b", "fp16", lens, gen=3,
                      n_slots=3, buckets=buckets, max_len=32)
    misses = sched.cache.stats.misses
    # the issue's bound: #buckets x {prefill, decode}
    assert misses <= 2 * len(buckets), \
        f"{misses} compiles for {len(buckets)} buckets"
    # and the exact expectation: one prefill per used bucket + one decode
    assert misses == len({bucket_for(L, buckets) for L in lens}) + 1
    # every later dispatch warm-started from the content-hash cache
    assert sched.cache.stats.hits > 0


# ---------------------------------------------------------------------------
# Mid-flight admission
# ---------------------------------------------------------------------------


def test_midflight_admission_correctness():
    lens = [16, 12, 14]
    gens = 8
    # two lanes; request 2 arrives at step 2 and must wait for a free lane
    arrivals = [0, 0, 2]
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", lens, gen=gens,
                     n_slots=2, arrivals=arrivals)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", lens, gen=gens,
                    arrivals=arrivals)
    for rid in range(3):
        np.testing.assert_array_equal(cont[rid].tokens, seq[rid].tokens)
    # request 2 was admitted after the others started...
    assert cont[2].admitted_step > 0
    # ...and while another lane was still generating (true mid-flight:
    # somebody finished only after the newcomer joined)
    assert any(cont[r].finished_step >= cont[2].admitted_step
               for r in (0, 1))


# ---------------------------------------------------------------------------
# ExecutionStream records and ordering
# ---------------------------------------------------------------------------


def test_execute_sync_always_returns_list():
    cache = ProgramCache()
    compiled, key = cache.compile(lambda x: x + 1, jnp.zeros((4,)))
    stream = ExecutionStream(cache, target=V5E)
    stream.encode_operation(compiled, (jnp.zeros((4,)),), key)
    outs = stream.execute_sync()
    assert isinstance(outs, list) and len(outs) == 1
    assert stream.execute_sync() == []        # empty queue -> empty list


def test_stream_records_floor_and_order():
    cache = ProgramCache()
    compiled, key = cache.compile(lambda x: x * 2, jnp.zeros((8,)))
    stream = ExecutionStream(cache, target=hal.get_target("ane-m1"))
    assert stream.floor_s == hal.ANE_M1.dispatch_floor_s
    # encode-many / execute-once: three ops, one submission
    for i in range(3):
        stream.encode_operation(compiled, (jnp.full((8,), float(i)),),
                                f"op{i}", batch=i + 1)
    assert stream.queue_depth == 3
    outs = stream.execute_sync()
    assert len(outs) == 3 and stream.queue_depth == 0
    assert [r.key for r in stream.records] == ["op0", "op1", "op2"]
    assert [r.queue_depth for r in stream.records] == [0, 1, 2]
    assert [r.batch for r in stream.records] == [1, 2, 3]
    seqs = [r.seq for r in stream.records]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    for r in stream.records:
        # work_s populated from the costmodel floor, not the 0.0 placeholder
        assert r.floor_s == hal.ANE_M1.dispatch_floor_s
        assert r.work_s == pytest.approx(max(0.0, r.wall_s - r.floor_s))
    assert stream.total_floor_s() == pytest.approx(3 * stream.floor_s)


def _assert_record_invariants(stream, *, window=None):
    """The satellite's stream-record invariants, shared by the sync and
    async drains: monotone encode/submission order, nonnegative work,
    the costmodel floor charged per dispatch, submit <= complete
    timestamps, and in-flight depth bounded by the submission window."""
    recs = stream.records
    assert recs, "stream retired no records"
    seqs = [r.seq for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    for r in recs:
        assert r.work_s >= 0.0
        assert r.floor_s == stream.floor_s
        assert r.work_s == pytest.approx(max(0.0, r.wall_s - r.floor_s))
        assert r.complete_ts >= r.submit_ts > 0.0
        assert r.queue_depth >= 0
        if window is None:          # sync drain: nothing ever in flight
            assert r.inflight_depth == 0
        else:                       # async drain: depth stays inside window
            assert 0 <= r.inflight_depth < window


@pytest.mark.parametrize("schedule", ["continuous", "slo", "spec"])
def test_scheduler_stream_invariants(schedule):
    _, sched = _serve(schedule, "tinyllama-1.1b", "fp16", [16, 9], gen=4,
                      n_slots=2)
    recs = sched.stream.records
    assert len(recs) >= 3                      # >= 1 prefill + decode steps
    window = sched.stream.max_in_flight if schedule in ("slo", "spec") \
        else None
    _assert_record_invariants(sched.stream, window=window)
    assert all(r.floor_s == V5E.dispatch_floor_s for r in recs)
    # decode dispatches carry the active-lane count as the batch denominator
    assert max(r.batch for r in recs) == 2
    stats = sched.stats(2)
    assert stats["per_request_dispatch_overhead_s"] == pytest.approx(
        len(recs) * V5E.dispatch_floor_s / 2)
    # NOTE: no `inflight_depth > 0` assertion here — a smoke model's decode
    # tick is dispatch-overhead-bound on CPU, so the drain often retires
    # step N before step N+1 submits; observed overlap depth is a property
    # of the workload, pinned deterministically by the compute-heavy op in
    # test_async_stream_window_overlaps_deterministically.


# ---------------------------------------------------------------------------
# AsyncExecutionStream: bounded window, background drain, chaining
# ---------------------------------------------------------------------------


def test_async_stream_rejects_bad_window():
    with pytest.raises(ValueError, match="max_in_flight"):
        AsyncExecutionStream(ProgramCache(), target=V5E, max_in_flight=0)


def test_slo_schedule_rejects_sync_stream():
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    with pytest.raises(ValueError, match="AsyncExecutionStream"):
        SLOSchedule(model, params, cfg, n_slots=1, max_len=16,
                    stream=ExecutionStream(ProgramCache(), target=V5E))


def test_async_stream_submit_chain_and_records():
    """submit() returns live async outputs that chain into the next encoded
    op (donated forward), the background drain retires records in
    submission order, and the in-flight depth never reaches the window."""
    cache = ProgramCache()
    stream = AsyncExecutionStream(cache, target=hal.get_target("ane-m1"),
                                  max_in_flight=2)
    compiled, key = cache.compile(
        lambda c, x: (c + x, (c + x).sum()), jnp.zeros((32, 32)),
        jnp.ones((32, 32)), jit_kwargs={"donate_argnums": (0,)})
    c, x = jnp.zeros((32, 32)), jnp.ones((32, 32))
    sums = []
    for i in range(6):
        stream.encode_operation(compiled, (c, x), f"op{i}", batch=i + 1)
        c, s = stream.submit()[0]     # chained donation across submissions
        sums.append(s)
    stream.sync()
    assert stream.in_flight_depth == 0
    np.testing.assert_allclose([float(v) for v in sums],
                               [1024.0 * (i + 1) for i in range(6)])
    recs = stream.records
    assert [r.key for r in recs] == [f"op{i}" for i in range(6)]
    assert [r.batch for r in recs] == list(range(1, 7))
    _assert_record_invariants(stream, window=2)
    completes = [r.complete_ts for r in recs]
    assert completes == sorted(completes)      # FIFO drain
    stream.close()


def test_async_stream_window_overlaps_deterministically():
    """With an op whose device time far exceeds the host's inter-submit
    gap, the window must actually fill: every submission after the first
    sees the previous one still in flight (depth 1 under a window of 2),
    which is the overlap the floor accounting needs to stay truthful."""
    cache = ProgramCache()
    stream = AsyncExecutionStream(cache, target=V5E, max_in_flight=2)
    x = jnp.ones((800, 800))
    compiled, key = cache.compile(
        lambda c: (c @ c) / 800.0, x, jit_kwargs={"donate_argnums": (0,)})
    c = x
    for i in range(4):
        stream.encode_operation(compiled, (c,), f"mm{i}")
        c = stream.submit()[0]        # ~100 ms device work per link
    stream.sync()
    depths = [r.inflight_depth for r in stream.records]
    assert depths[0] == 0
    assert all(d == 1 for d in depths[1:]), depths
    _assert_record_invariants(stream, window=2)
    stream.close()


def test_async_execute_sync_keeps_base_contract():
    """execute_sync on the async stream = drain + the blocking base path:
    a list in encode order, records with sync semantics (depth 0)."""
    cache = ProgramCache()
    stream = AsyncExecutionStream(cache, target=V5E)
    compiled, key = cache.compile(lambda x: x + 1, jnp.zeros((4,)))
    stream.encode_operation(compiled, (jnp.zeros((4,)),), key)
    stream.encode_operation(compiled, (jnp.ones((4,)),), key)
    outs = stream.execute_sync()
    assert isinstance(outs, list) and len(outs) == 2
    np.testing.assert_array_equal(np.asarray(outs[1]), np.full((4,), 2.0))
    assert stream.execute_sync() == []         # empty queue -> empty list
    # mixing submit() and execute_sync() keeps one total record order
    stream.encode_operation(compiled, (jnp.zeros((4,)),), "async-op")
    stream.submit()
    stream.encode_operation(compiled, (jnp.zeros((4,)),), "sync-op")
    stream.execute_sync()
    seqs = [r.seq for r in stream.records]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert [r.key for r in stream.records[-2:]] == ["async-op", "sync-op"]


def test_async_stream_surfaces_bad_dispatches():
    """A dispatch the compiled program rejects (wrong operand shape) must
    surface as an exception, not vanish into the background drain, and the
    stream must stay usable afterwards."""
    cache = ProgramCache()
    stream = AsyncExecutionStream(cache, target=V5E)
    ok, okey = cache.compile(lambda x: x + 1, jnp.zeros((3, 3)))
    stream.encode_operation(ok, (jnp.zeros((5, 5)),), "boom")
    with pytest.raises(Exception):
        stream.execute_sync()
    stream.reset()
    stream.encode_operation(ok, (jnp.zeros((3, 3)),), okey)
    outs = stream.execute_sync()
    np.testing.assert_array_equal(np.asarray(outs[0]), np.ones((3, 3)))


# ---------------------------------------------------------------------------
# SLO-aware admission
# ---------------------------------------------------------------------------


def test_slo_gate_defers_but_never_starves():
    """An unreachable SLO sheds load: admissions beyond the first are
    deferred while the engine is busy (counted), yet every request is
    served (the idle-engine rule forbids starvation) with the exact
    sequential token streams."""
    lens = [12, 10, 9]
    slo, sched = _serve("slo", "tinyllama-1.1b", "fp16", lens, gen=4,
                        n_slots=3, slo_ms=1e-4)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", lens, gen=4)
    assert set(slo) == set(range(3))
    for rid in slo:
        np.testing.assert_array_equal(slo[rid].tokens, seq[rid].tokens)
    assert sched.deferred_admissions > 0
    # load was actually shed: later requests were admitted strictly after
    # the first despite three free lanes at step 0
    assert min(slo[1].admitted_step, slo[2].admitted_step) \
        > slo[0].admitted_step


def test_slo_gate_open_matches_continuous_admissions():
    """A generous SLO admits exactly like the continuous schedule."""
    lens = [12, 10, 9]
    slo, sched = _serve("slo", "tinyllama-1.1b", "fp16", lens, gen=4,
                        n_slots=3, slo_ms=1e6)
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", lens, gen=4,
                     n_slots=3)
    assert sched.deferred_admissions == 0
    for rid in slo:
        assert slo[rid].admitted_step == cont[rid].admitted_step
        np.testing.assert_array_equal(slo[rid].tokens, cont[rid].tokens)
    assert sched.predicted_token_latency_s() > 0.0


def test_slo_midflight_admission_parity():
    """Mid-flight admission under the pipelined schedule: a request
    arriving later joins a freed lane and every stream stays sequential-
    exact (windows must stop at the arrival step)."""
    lens = [16, 12, 14]
    arrivals = [0, 0, 2]
    slo, _ = _serve("slo", "tinyllama-1.1b", "fp16", lens, gen=8,
                    n_slots=2, arrivals=arrivals)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", lens, gen=8,
                    arrivals=arrivals)
    for rid in range(3):
        np.testing.assert_array_equal(slo[rid].tokens, seq[rid].tokens)
    assert slo[2].admitted_step > 0


# ---------------------------------------------------------------------------
# Categorical sampling: schedule invariance under the overlapped stream
# ---------------------------------------------------------------------------


def test_slo_categorical_schedule_invariance():
    """The satellite case: the per-(request, position) seed fold must make
    the *on-device* categorical draws of the pipelined windows identical
    to the host sampler's sequential stream, token for token."""
    lens = [10, 6]
    slo, _ = _serve("slo", "tinyllama-1.1b", "fp16", lens, gen=4,
                    n_slots=2, sampling="categorical")
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", lens, gen=4,
                    sampling="categorical")
    for rid in slo:
        np.testing.assert_array_equal(slo[rid].tokens, seq[rid].tokens)


@pytest.mark.slow
def test_slo_categorical_invariance_sweep():
    """Wider categorical invariance: heterogeneous lens incl. decode-only
    admission, three-way against continuous and sequential."""
    slo, _ = _serve("slo", "tinyllama-1.1b", "fp16", PARITY_LENS, gen=6,
                    sampling="categorical")
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", PARITY_LENS,
                     gen=6, sampling="categorical")
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", PARITY_LENS,
                    gen=6, sampling="categorical")
    for rid in slo:
        np.testing.assert_array_equal(slo[rid].tokens, seq[rid].tokens)
        np.testing.assert_array_equal(cont[rid].tokens, seq[rid].tokens)


# ---------------------------------------------------------------------------
# Prefill-cache merge: loud failure + named time axis
# ---------------------------------------------------------------------------


def test_merge_rank_mismatch_raises_with_path():
    dec = {"layer": {"state": jnp.zeros((2, 1, 4, 8))}}
    pf = {"layer": {"state": jnp.zeros((2, 1, 4))}}
    with pytest.raises(ValueError, match=r"layer/state.*rank"):
        merge_prefill_caches(dec, pf)


def test_merge_unnamed_axis_mismatch_raises_with_path():
    # batch-axis mismatch on a recurrent leaf: not a named time axis
    dec = {"g0": {"h": jnp.zeros((1, 4, 8))}}
    pf = {"g0": {"h": jnp.zeros((1, 1, 8))}}
    with pytest.raises(ValueError, match=r"g0/h"):
        merge_prefill_caches(dec, pf)
    # a KV leaf may only differ on its single time axis, not on heads too
    dec = {"g0": {"k": jnp.zeros((1, 1, 8, 2, 4))}}
    pf = {"g0": {"k": jnp.zeros((1, 1, 6, 3, 4))}}
    with pytest.raises(ValueError, match=r"g0/k"):
        merge_prefill_caches(dec, pf)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b"])
def test_merge_preserves_recurrent_state(arch):
    """The historical bug: `_merge_prefill` silently returned the empty
    decode buffer when a prefill leaf did not line up, dropping SSM conv /
    RG-LRU recurrent state. The named-time-axis merge must carry every
    recurrent leaf through verbatim and leave the unwritten KV tail
    invalid."""
    cfg, model, params = _served_model(arch, "fp16", dispatched=False)
    rng = np.random.default_rng(0)
    s, max_len = 12, 20
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(1, s)), jnp.int32)}
    pf_caches, _ = jax.jit(model.prefill)(params, batch)
    merged = serve_mod._merge_prefill(model, model.init_cache(1, max_len),
                                      pf_caches, s)

    from repro.kernels import compat
    pf_leaves = {compat.tree_path_str(p): v for p, v in
                 compat.tree_flatten_with_path(pf_caches)[0]}
    any_recurrent = False
    for path, leaf in compat.tree_flatten_with_path(merged)[0]:
        loc = compat.tree_path_str(path)
        name = loc.rsplit("/", 1)[-1]
        src = pf_leaves[loc]
        if name == "pos":
            np.testing.assert_array_equal(
                np.asarray(leaf)[..., :s], np.asarray(src))
            assert np.all(np.asarray(leaf)[..., s:] == -1)
        elif leaf.shape == src.shape:          # recurrent/conv state leaves
            any_recurrent = True
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(src))
            assert np.any(np.asarray(leaf) != 0), \
                f"{loc}: prefill state was dropped"
    assert any_recurrent, f"{arch}: no recurrent state leaf was checked"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-9b"])
def test_pool_insert_validates_paged_leaves_loud(arch):
    """The PR 3 merge regression, extended to the pool's arena writes: a
    prefill leaf that does not line up with the bound arena (rank, time
    extent, off-axis tail) must raise with the tree path before any block
    is inserted — never silently cache truncated KV/ring state."""
    from repro.kernels import compat
    from repro.launch.kv_pool import PagedKVPool

    cfg, model, params = _served_model(arch, "fp16", dispatched=False)
    # max_len == the recurrentgemma ring window so its KV extent spans the
    # whole table and classifies as paged (beyond it, rings only anchor)
    s, max_len = 16, 32
    pool = PagedKVPool(8, 8)
    pool.bind(model.init_cache(1, max_len), max_len=max_len)
    assert pool._paged_paths, f"{arch}: expected paged KV leaves"
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(1, s)), jnp.int32)}
    pf, _ = jax.jit(model.prefill)(params, batch)
    pool.validate_prefill(pf, s)             # the healthy tree passes

    target = sorted(pool._paged_paths)[0]
    leafname = target.rsplit("/", 1)[-1]

    def mangle(fn):
        return compat.tree_map_with_path(
            lambda p, v: fn(v) if compat.tree_path_str(p) == target else v,
            pf)

    # time axis one token short: the off-axis merge bug, paged edition
    with pytest.raises(ValueError, match=rf"{leafname}.*time extent"):
        pool.validate_prefill(mangle(lambda v: v[:, :, : s - 1]), s)
    # rank mismatch (head axis collapsed)
    with pytest.raises(ValueError, match=rf"{leafname}.*rank"):
        pool.validate_prefill(mangle(lambda v: v[..., 0]), s)
    # off-axis tail mismatch (head dim halved)
    bad = mangle(lambda v: v[..., : max(1, v.shape[-1] // 2)])
    with pytest.raises(ValueError, match=rf"{leafname}"):
        pool.validate_prefill(bad, s)


# ---------------------------------------------------------------------------
# Prefix cache: hit admissions are token-exact with cold admissions
# ---------------------------------------------------------------------------


def _serve_prefix(schedule, arch, form, rounds=2, lens=None, gen=6,
                  **sched_kw):
    """One prefix-cached scheduler serving `rounds` identical request
    rounds: round 1 is all cold (inserts), round 2+ admits every bucketed
    prompt from resident blocks. Returns per-round {rid: result} + sched."""
    cfg, model, params = _served_model(arch, form)
    lens = lens or PARITY_LENS
    cache = ProgramCache()
    stream = (AsyncExecutionStream(cache, target=V5E) if schedule == "slo"
              else ExecutionStream(cache, target=V5E))
    sched = make_scheduler(schedule, model, params, cfg, n_slots=3,
                           max_len=max(lens) + gen, sampling="greedy",
                           seed=0, stream=stream, prefix_cache=True,
                           prefix_blocks=64, prefix_block_size=8, **sched_kw)
    outs = []
    for _ in range(rounds):
        outs.append({r.rid: r for r in sched.run(_requests(cfg, lens, gen))})
    return outs, sched


def _check_prefix_parity(arch, form, schedule="continuous"):
    """The sweep body: cold round == warm (prefix-hit) round == the
    sequential reference, token for token, and the warm round really did
    hit (bucketed prompts admit without any prefill dispatch)."""
    (cold, warm), sched = _serve_prefix(schedule, arch, form)
    assert sched.pool.stats["hits"] > 0, "warm round never hit the pool"
    assert sched.pool.stats["hit_tokens"] > 0
    seq, _ = _serve("sequential", arch, form, PARITY_LENS, gen=6)
    for rid in seq:
        np.testing.assert_array_equal(
            cold[rid].tokens, seq[rid].tokens,
            err_msg=f"{arch}/{form} rid={rid}: cold prefix-pool round "
                    f"diverged from the sequential reference")
        np.testing.assert_array_equal(
            warm[rid].tokens, seq[rid].tokens,
            err_msg=f"{arch}/{form} rid={rid}: prefix-HIT admission "
                    f"diverged from the cold stream")
        assert warm[rid].bucket == cold[rid].bucket
    # all lanes released their page tables at completion
    assert sched.pool.owners() == set()
    sched.pool.audit()


@pytest.mark.parametrize("schedule", ["continuous", "slo"])
@pytest.mark.parametrize("arch,form", FAST_PARITY)
def test_prefix_cache_parity(arch, form, schedule):
    _check_prefix_parity(arch, form, schedule)


@pytest.mark.slow
@pytest.mark.parametrize("arch,form", SLOW_PARITY)
def test_prefix_cache_parity_sweep(arch, form):
    """The arch x weight-form sweep of the prefix tier: hybrids and pure
    SSMs exercise the anchor path (recurrent state snapshots at prefill
    boundaries), int4 exercises packed-weight prefill into the arena."""
    _check_prefix_parity(arch, form, "continuous")


def test_prefix_cache_shares_within_one_round():
    """Cross-request sharing, not just cross-round: requests with one
    common system prompt hit the pool inside a single round and save
    whole floor-charged dispatches vs the baseline."""
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, size=(16,)).astype(np.int32)
    reqs = [Request(rid=i, prompt=np.concatenate(
                [shared, rng.integers(0, cfg.vocab,
                                      size=(3 + i,)).astype(np.int32)]),
                    max_new_tokens=5) for i in range(4)]

    def serve(prefix):
        stream = ExecutionStream(ProgramCache(), target=V5E)
        sched = make_scheduler(
            "continuous", model, params, cfg, n_slots=2, max_len=40,
            sampling="greedy", seed=0, stream=stream,
            **(dict(prefix_cache=True, prefix_block_size=8) if prefix
               else {}))
        res = {r.rid: r for r in sched.run(
            [Request(rid=r.rid, prompt=r.prompt.copy(),
                     max_new_tokens=r.max_new_tokens) for r in reqs])}
        return res, sched

    base, bsched = serve(False)
    pooled, psched = serve(True)
    for rid in base:
        np.testing.assert_array_equal(base[rid].tokens, pooled[rid].tokens)
    assert psched.pool.stats["hits"] == 3        # requests 1-3 reuse req 0's
    assert psched.pool.stats["misses"] == 1
    assert len(psched.stream.records) < len(bsched.stream.records), \
        "prefix hits must save whole dispatches"


def test_prefix_cache_rejects_bad_setups():
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    # speculative: the pool only pages the target's cache — loud, not silent
    with pytest.raises(ValueError, match="prefix"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            prefix_cache=True)
    # sequential strips the knob (no slot admission to route through)
    seq = make_scheduler("sequential", model, params, cfg, max_len=16,
                         n_slots=1, prefix_cache=True, prefix_blocks=8)
    assert not hasattr(seq, "pool")
    # encdec: cross-attention cache depends on per-request frames
    ecfg, emodel, eparams = _served_model("whisper-small", "fp16")
    with pytest.raises(ValueError, match="encdec"):
        make_scheduler("continuous", emodel, eparams, ecfg, n_slots=1,
                       max_len=16, prefix_cache=True)


def test_serve_cli_prefix_cache_round_trip():
    """`--prefix-cache` end to end: identical tokens with the pool on and
    off, pool stats surfaced, round 2 admitted from resident blocks."""
    # 17 = 2 whole blocks of matchable prefix (the match limit is L-1, so a
    # 16-token prompt tops out at one block = 8 < bucket 16 and never hits)
    argv = ["--smoke", "--batch", "2", "--prompt-len", "17", "--gen", "4",
            "--sampling", "greedy", "--requests", "2"]
    off = serve_mod.run(argv + ["--schedule", "continuous"])
    on = serve_mod.run(argv + ["--schedule", "continuous", "--prefix-cache"])
    np.testing.assert_array_equal(on["tokens"], off["tokens"])
    assert "prefix_cache" not in off
    assert on["prefix_cache"]["hits"] > 0
    assert on["prefix_cache"]["hit_tokens"] > 0
    assert on["n_dispatches"] < off["n_dispatches"] + \
        on["prefix_cache"]["misses"] + 1   # hits saved prefill dispatches
    slo = serve_mod.run(argv + ["--schedule", "slo", "--prefix-cache"])
    np.testing.assert_array_equal(slo["tokens"], off["tokens"])
    assert slo["prefix_cache"]["hits"] > 0


# ---------------------------------------------------------------------------
# Sampling modes (the --greedy no-op regression)
# ---------------------------------------------------------------------------


def test_sampler_modes_are_distinct_and_deterministic():
    vocab = 64
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(vocab,)).astype(np.float32)
    greedy = TokenSampler("greedy", vocab, seed=0)
    cat = TokenSampler("categorical", vocab, seed=0)
    # greedy ignores rid/position; categorical is keyed by (seed, rid, pos)
    assert greedy(logits, 0, 5) == greedy(logits, 3, 9) == int(np.argmax(logits))
    draws = [cat(np.zeros(vocab, np.float32), 0, p) for p in range(20)]
    assert len(set(draws)) > 1, "categorical sampling is not sampling"
    redraw = [TokenSampler("categorical", vocab, seed=0)(
        np.zeros(vocab, np.float32), 0, p) for p in range(20)]
    assert draws == redraw, "categorical sampling must be seed-deterministic"
    with pytest.raises(ValueError, match="sampling mode"):
        TokenSampler("nucleus", vocab, seed=0)


@pytest.mark.parametrize("sampling", ["greedy", "categorical"])
def test_serve_smoke_covers_sampling_modes(sampling):
    out = serve_mod.run(["--smoke", "--batch", "2", "--prompt-len", "8",
                         "--gen", "4", "--schedule", "continuous",
                         "--sampling", sampling, "--requests", "2"])
    assert out["tokens"].shape == (2, 4)
    assert out["sampling"] == sampling
    assert out["cache_hits"] > 0              # round 2 warm-started
    # same invocation -> same seeded token streams (rids included: the
    # categorical key is fold_in(fold_in(seed, rid), position))
    rerun = serve_mod.run(["--smoke", "--batch", "2", "--prompt-len", "8",
                           "--gen", "4", "--schedule", "continuous",
                           "--sampling", sampling, "--requests", "2"])
    np.testing.assert_array_equal(out["tokens"], rerun["tokens"])
    if sampling == "greedy":
        # lane-reuse hygiene: round 2 runs on recycled decode lanes, and
        # greedy ignores rids — stale KV leaking past the pos mask would
        # make the rounds diverge
        single = serve_mod.run(["--smoke", "--batch", "2", "--prompt-len",
                                "8", "--gen", "4", "--schedule",
                                "continuous", "--sampling", sampling])
        np.testing.assert_array_equal(out["tokens"], single["tokens"])


def test_serve_cli_slo_schedule():
    """`--schedule slo` end to end: warm-started second round, identical
    tokens to the continuous CLI run, SLO knobs surfaced in the stats."""
    argv = ["--smoke", "--batch", "2", "--prompt-len", "8", "--gen", "4",
            "--sampling", "greedy", "--requests", "2"]
    out = serve_mod.run(argv + ["--schedule", "slo"])
    cont = serve_mod.run(argv + ["--schedule", "continuous"])
    np.testing.assert_array_equal(out["tokens"], cont["tokens"])
    assert out["cache_hits"] > 0
    assert out["deferred_admissions"] == 0         # no SLO configured
    assert out["max_in_flight"] >= 1
    tight = serve_mod.run(argv + ["--schedule", "slo", "--slo-ms", "1e-4"])
    assert tight["deferred_admissions"] > 0        # load was shed...
    np.testing.assert_array_equal(tight["tokens"], cont["tokens"])  # ...not dropped


@pytest.mark.slow
def test_sampling_parity_categorical():
    """Categorical streams are keyed per (request, position), so they are
    schedule-invariant exactly like greedy."""
    cont, _ = _serve("continuous", "tinyllama-1.1b", "fp16", [16, 9], gen=5,
                     n_slots=2, sampling="categorical")
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [16, 9], gen=5,
                    sampling="categorical")
    for rid in cont:
        np.testing.assert_array_equal(cont[rid].tokens, seq[rid].tokens)


# ---------------------------------------------------------------------------
# Speculative decoding: draft -> fused verify/accept windows
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("draft", ["self", "shrink"])
def test_spec_greedy_parity(draft):
    """Token-exact greedy parity of the speculative schedule against the
    sequential reference, with both the accept-all drafter (the target
    itself) and a disagreeing depth-pruned drafter (rollback exercised)."""
    sched = _check_parity("tinyllama-1.1b", "fp16", "spec", draft=draft,
                          draft_depth=3)
    if draft == "self":
        assert sched.acceptance_rate == 1.0
    else:       # random-init shrink drafter: rejections actually happened
        assert sched.accepted < sched.proposed


@pytest.mark.slow
@pytest.mark.parametrize("arch,form", SLOW_PARITY)
def test_spec_parity_sweep(arch, form):
    """The existing arch x weight-form sweep, under the rejection-heavy
    shrink drafter: every rejected window must roll the caches back
    bit-exactly (recurrent SSM/RG-LRU state included)."""
    _check_parity(arch, form, "spec", draft="shrink", draft_depth=3)


def test_spec_categorical_schedule_invariance():
    """The on-device gumbel + first-index-argmax of the verify kernel must
    reproduce the host sampler's per-(rid, pos) categorical stream bit for
    bit, whatever the drafter proposed."""
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [10, 6], gen=4,
                    sampling="categorical")
    for draft in ("self", "shrink"):
        spec, sched = _serve("spec", "tinyllama-1.1b", "fp16", [10, 6],
                             gen=4, n_slots=2, sampling="categorical",
                             draft=draft, draft_depth=3)
        for rid in spec:
            np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
        if draft == "self":
            # the drafter samples with the same fold_in keys: identical
            # models draw identical tokens, so nothing is ever rejected
            assert sched.acceptance_rate == 1.0


def test_spec_accept_all_bounds_when_drafter_is_target():
    """drafter == target => every proposal is accepted: acceptance rate
    exactly 1.0 and every full-depth window emits draft_depth + 1 tokens
    for exactly two floor-charged dispatches."""
    spec, sched = _serve("spec", "tinyllama-1.1b", "fp16", [16, 16], gen=10,
                         n_slots=2, draft="self", draft_depth=4)
    assert sched.acceptance_rate == 1.0
    assert sched.proposed > 0
    st = sched.stats(2)
    # token 1 of each lane is sampled at (fully-prefilled) admission; the
    # remaining 9 come from two accept-all windows: depth 4 (5 tokens) +
    # depth 3 (the budget cap shrinks the last window) per lane
    assert st["emitted_tokens"] == 18
    assert st["verify_dispatches"] == st["n_windows"]
    assert st["n_windows"] == 2 and st["draft_dispatches"] == 2


def test_spec_adversarial_drafter_still_correct():
    """An adversarial drafter (independently-initialized weights: its
    proposals are near-uniformly wrong) may slow decode to one token per
    window but can never change the emitted stream."""
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    adversary = Drafter.shrink(cfg, dispatcher=model.dispatcher, seed=123)
    spec, sched = _serve("spec", "tinyllama-1.1b", "fp16", [12, 9], gen=6,
                         n_slots=2, drafter=adversary, draft_depth=4)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [12, 9], gen=6)
    for rid in spec:
        np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
    assert sched.acceptance_rate < 0.5
    assert sched.accepted < sched.proposed     # rejections really occurred


@pytest.mark.slow
@pytest.mark.parametrize("arch,lens,gen", [
    # recurrentgemma: sliding-window KV is a RING (slot = pos % window);
    # prompt 28 + gen 14 > window 32, so rejected speculative writes WRAP
    # and clobber live history — rollback must restore the old entries,
    # not just mask the junk
    ("recurrentgemma-9b", [28, 20], 14),
    # mamba2: no KV at all — rollback is purely recurrent-state selection
    ("mamba2-1.3b", [16, 9], 8),
])
def test_spec_kv_rollback_on_rejection(arch, lens, gen):
    spec, sched = _serve("spec", arch, "fp16", lens, gen,
                         n_slots=2, draft="shrink", draft_depth=3)
    seq, _ = _serve("sequential", arch, "fp16", lens, gen)
    for rid in spec:
        np.testing.assert_array_equal(
            spec[rid].tokens, seq[rid].tokens,
            err_msg=f"{arch} rid={rid}: rollback corrupted the stream")
    assert sched.accepted < sched.proposed     # the rollback path ran


def test_spec_depth_clamped_to_cache_geometry():
    """An absurd draft depth is clamped by the cache end — the stream must
    stay token-exact instead of wrapping speculative writes past max_len."""
    spec, sched = _serve("spec", "tinyllama-1.1b", "fp16", [12, 9], gen=6,
                         n_slots=2, draft="self", draft_depth=50)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [12, 9], gen=6)
    for rid in spec:
        np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
    assert sched._min_positional_size() == 12 + 6     # full-cache slots


@pytest.mark.slow
def test_spec_depth_clamped_to_ring_window():
    """A draft depth past a sliding-window ring would wrap the rollback
    onto the slot being committed; the window-depth clamp must keep the
    rejection-heavy stream exact anyway."""
    spec, sched = _serve("spec", "recurrentgemma-9b", "fp16", [28, 20],
                         gen=10, n_slots=2, draft="shrink", draft_depth=100)
    seq, _ = _serve("sequential", "recurrentgemma-9b", "fp16", [28, 20],
                    gen=10)
    for rid in spec:
        np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
    # smoke recurrentgemma's local-attention ring is 32 slots
    assert sched._min_positional_size() == 32


def test_spec_midflight_admission_parity():
    """A request arriving later joins a freed lane; speculative windows
    must stop at the arrival step (never drafting past a host decision)."""
    lens = [16, 12, 14]
    arrivals = [0, 0, 2]
    spec, _ = _serve("spec", "tinyllama-1.1b", "fp16", lens, gen=8,
                     n_slots=2, arrivals=arrivals, draft="self",
                     draft_depth=3)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", lens, gen=8,
                    arrivals=arrivals)
    for rid in range(3):
        np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
    assert spec[2].admitted_step > 0


def test_spec_stream_records_two_floors_per_window():
    """The honest §9 accounting: every draft and every verify dispatch is
    a floor-charged DispatchRecord on the shared stream — a full window
    shows exactly two, admission dispatches carry the drafter for free."""
    _, sched = _serve("spec", "tinyllama-1.1b", "fp16", [16, 16], gen=10,
                      n_slots=2, draft="self", draft_depth=4)
    recs = sched.stream.records
    _assert_record_invariants(sched.stream,
                              window=sched.stream.max_in_flight)
    draft_recs = [r for r in recs if r.key in sched._draft_keys]
    verify_recs = [r for r in recs if r.key in sched._verify_keys]
    assert len(verify_recs) == sched.n_windows == 2
    assert len(draft_recs) == 2                # every window drafted
    for r in draft_recs + verify_recs:
        assert r.floor_s == V5E.dispatch_floor_s > 0.0
        assert r.batch == 2                    # both lanes share the floor
    # each window's draft submits strictly before its verify (the proposal
    # tensor chains in as a live async value): pairing the i-th draft with
    # the i-th verify in submission order pins the per-window ordering
    draft_seqs = sorted(r.seq for r in draft_recs)
    verify_seqs = sorted(r.seq for r in verify_recs)
    assert all(d < v for d, v in zip(draft_seqs, verify_seqs)), \
        (draft_seqs, verify_seqs)
    # the drafter's admission work rode the target's dispatches: the
    # per-request floor count matches the non-speculative admission shape
    assert sum(1 for r in recs if r.key == "spec_admit_slot") == 2


def test_draft_of_shrink_rule():
    """The shrink rule: depth-pruned, width- and vocab-preserving, valid
    for every family (hybrids keep one whole block-pattern period)."""
    cfg = configs.get_smoke("tinyllama-1.1b")
    dcfg = draft_of(cfg)
    assert dcfg.n_layers == 1
    assert dcfg.vocab == cfg.vocab and dcfg.d_model == cfg.d_model
    assert dcfg.name.endswith("-draft")
    assert dcfg.mtp_depth == 0
    hyb = configs.get_smoke("recurrentgemma-9b")
    dhyb = draft_of(hyb)
    assert dhyb.n_layers == len(hyb.block_pattern)
    enc = configs.get_smoke("whisper-small")
    denc = draft_of(enc)
    assert denc.n_encoder_layers == 1 and denc.encoder_len == enc.encoder_len
    # MoE prunes to the dense path — dbrx has zero leading dense layers,
    # so without the explicit rule its draft would still route experts
    moe = configs.get_smoke("dbrx-132b")
    dmoe = draft_of(moe)
    assert not any(dmoe.layer_is_moe(i) for i in range(dmoe.n_layers))
    # every registry config must shrink into a buildable draft
    for arch in configs.ARCH_NAMES:
        d = draft_of(configs.get_smoke(arch))
        assert d.n_layers >= 1 and d.vocab > 0


def test_spec_rejects_bad_setups():
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    with pytest.raises(ValueError, match="AsyncExecutionStream"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            stream=ExecutionStream(ProgramCache(),
                                                   target=V5E))
    with pytest.raises(ValueError, match="draft_depth"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            draft_depth=0)
    with pytest.raises(ValueError, match="draft"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            draft="ngram")
    import dataclasses as _dc
    other = _dc.replace(cfg, vocab=cfg.vocab * 2)
    bad = Drafter(model, params, other, kind="self")
    with pytest.raises(ValueError, match="vocab"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            drafter=bad)


def test_serve_cli_spec_schedule():
    """`--schedule spec` end to end: warm-started second round, identical
    greedy tokens to the continuous CLI run, spec stats surfaced."""
    argv = ["--smoke", "--batch", "2", "--prompt-len", "8", "--gen", "6",
            "--sampling", "greedy", "--requests", "2"]
    cont = serve_mod.run(argv + ["--schedule", "continuous"])
    out = serve_mod.run(argv + ["--schedule", "spec", "--draft", "self",
                                "--draft-depth", "2"])
    np.testing.assert_array_equal(out["tokens"], cont["tokens"])
    assert out["cache_hits"] > 0
    assert out["acceptance_rate"] == 1.0
    assert out["n_windows"] > 0 and out["verify_dispatches"] > 0
    shr = serve_mod.run(argv + ["--schedule", "spec", "--draft", "shrink",
                                "--draft-depth", "2"])
    np.testing.assert_array_equal(shr["tokens"], cont["tokens"])
    assert shr["acceptance_rate"] < 1.0


# ---------------------------------------------------------------------------
# Tree / multi-draft verification (--draft-branches)
# ---------------------------------------------------------------------------


def test_tree_kernel_single_branch_matches_chain_bitwise():
    """NBR=1 tree verify IS the chain kernel: samples and accept lengths
    bit-for-bit identical, winning branch identically 0."""
    from repro.kernels.specdec.specdec import (verify_accept_kernel,
                                               verify_accept_tree_kernel)
    rng = np.random.default_rng(3)
    b, t, v = 4, 5, 300
    scores = rng.normal(size=(b, t, v)).astype(np.float32)
    picks = np.argmax(scores, -1)
    draft = rng.integers(0, v, size=(b, t - 1)).astype(np.int32)
    draft[0] = picks[0, :-1]                        # one accept-all lane
    cs, ca = verify_accept_kernel(jnp.asarray(scores), jnp.asarray(draft))
    ts_, ta, tb = verify_accept_tree_kernel(jnp.asarray(scores[:, None]),
                                            jnp.asarray(draft[:, None]))
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(ts_))
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(ta))
    np.testing.assert_array_equal(np.asarray(tb), np.zeros(b, np.int32))


@pytest.mark.parametrize("draft", ["self", "shrink"])
def test_spec_tree_greedy_parity(draft):
    """branches=2 tree windows stay token-exact against the sequential
    reference; branch 0 is exactly the chain proposal, so the self drafter
    still accepts everything."""
    sched = _check_parity("tinyllama-1.1b", "fp16", "spec", draft=draft,
                          draft_depth=3, draft_branches=2)
    assert sched.draft_branches == 2
    if draft == "self":
        assert sched.acceptance_rate == 1.0
    else:       # random-init shrink: the winning-branch rollback really ran
        assert sched.accepted < sched.proposed


def test_spec_tree_categorical_schedule_invariance():
    """Tree verify under seeded categorical sampling: the per-(rid, pos)
    gumbel perturbation is shared by every sibling branch, so the emitted
    stream is schedule-invariant whichever branch wins."""
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [10, 6], gen=4,
                    sampling="categorical")
    for draft in ("self", "shrink"):
        spec, sched = _serve("spec", "tinyllama-1.1b", "fp16", [10, 6],
                             gen=4, n_slots=2, sampling="categorical",
                             draft=draft, draft_depth=3, draft_branches=2)
        for rid in spec:
            np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
        if draft == "self":
            assert sched.acceptance_rate == 1.0


@pytest.mark.slow
@pytest.mark.parametrize("arch,form", SLOW_PARITY)
def test_spec_tree_parity_sweep(arch, form):
    """Tree windows under the rejection-heavy shrink drafter across the
    arch x weight-form sweep: winning-branch selection plus rollback must
    keep the recurrent families (SSM state, RG-LRU, ring KV) bit-exact."""
    _check_parity(arch, form, "spec", draft="shrink", draft_depth=3,
                  draft_branches=2)


def test_spec_tree_two_floors_per_window():
    """A tree window is still exactly two floor-charged dispatches — the
    whole B*branches tile rides inside them."""
    _, sched = _serve("spec", "tinyllama-1.1b", "fp16", [16, 16], gen=10,
                      n_slots=2, draft="self", draft_depth=4,
                      draft_branches=2)
    recs = sched.stream.records
    draft_recs = [r for r in recs if r.key in sched._draft_keys]
    verify_recs = [r for r in recs if r.key in sched._verify_keys]
    assert len(verify_recs) == sched.n_windows == 2
    assert len(draft_recs) == 2
    for r in draft_recs + verify_recs:
        assert r.floor_s == V5E.dispatch_floor_s > 0.0
    st = sched.stats(2)
    assert st["draft_branches"] == 2
    assert st["drafter_trained"] is True           # self drafter
    assert st["emitted_tokens"] == 18


def test_spec_zero_window_stats_guard():
    """gen=1 on fully-prefilled prompts: every request finishes on its
    admission sample, no window ever runs. proposed == 0 must report
    acceptance 0.0 — not a fake-perfect 1.0 — and every stat stays finite."""
    spec, sched = _serve("spec", "tinyllama-1.1b", "fp16", [16, 16], gen=1,
                         n_slots=2, draft="shrink", draft_depth=4)
    seq, _ = _serve("sequential", "tinyllama-1.1b", "fp16", [16, 16], gen=1)
    for rid in spec:
        np.testing.assert_array_equal(spec[rid].tokens, seq[rid].tokens)
    assert sched.proposed == 0 and sched.n_windows == 0
    assert sched.acceptance_rate == 0.0
    st = sched.stats(2)
    assert st["drafter_trained"] is False          # random-init shrink
    for k, v in st.items():
        if isinstance(v, float):
            assert np.isfinite(v), (k, v)


def test_spec_tree_rejects_bad_setups():
    cfg, model, params = _served_model("tinyllama-1.1b", "fp16")
    with pytest.raises(ValueError, match="draft_branches"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            draft_branches=0)
    with pytest.raises(ValueError, match="draft_ckpt"):
        SpeculativeSchedule(model, params, cfg, n_slots=1, max_len=16,
                            draft="self", draft_ckpt="/nope")


def test_serve_cli_spec_tree_round_trip():
    """`--schedule spec --draft-branches 2` end to end through the CLI:
    identical greedy tokens to the continuous run, accept-all self drafter."""
    argv = ["--smoke", "--batch", "2", "--prompt-len", "8", "--gen", "6",
            "--sampling", "greedy", "--requests", "1"]
    cont = serve_mod.run(argv + ["--schedule", "continuous"])
    out = serve_mod.run(argv + ["--schedule", "spec", "--draft", "self",
                                "--draft-depth", "2",
                                "--draft-branches", "2"])
    np.testing.assert_array_equal(out["tokens"], cont["tokens"])
    assert out["acceptance_rate"] == 1.0
