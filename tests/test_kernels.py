"""Per-kernel allclose sweeps: shapes x dtypes against the pure-jnp oracles.

Kernels execute under interpret=True on CPU; the same pallas_call lowers for
TPU with explicit BlockSpec VMEM tiling (the dry-run exercises lowering)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hal
from repro.kernels.anemm.anemm import anemm
from repro.kernels.anemm.ref import anemm_ref
from repro.kernels.anemm import ops as anemm_ops
from repro.kernels.palette.palette_matmul import pack_kn, palette_matmul
from repro.kernels.palette.ref import palette_matmul_ref
from repro.kernels.palette.ops import PaletteLinear
from repro.kernels.sparse.sparse_matmul import pack_pair_sparse, sparse_matmul
from repro.kernels.sparse.ref import sparse_matmul_ref
from repro.kernels.sparse.ops import SparseLinear
from repro.kernels.act_lut.ops import lut_activation
from repro.kernels.act_lut.ref import act_lut_ref, build_lut
from repro.kernels.flash.flash_attention import flash_attention
from repro.kernels.flash.ref import flash_attention_ref
from repro.kernels.flash import ops as flash_ops

rng = np.random.default_rng(42)

MM_SHAPES = [(128, 512, 128), (96, 256, 64), (8, 32, 8), (1, 1024, 16),
             (200, 300, 100), (256, 1024, 384)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


class TestAnemm:
    @pytest.mark.slow
    @pytest.mark.parametrize("shape", MM_SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_vs_oracle(self, shape, dtype):
        m, k, n = shape
        a = jnp.asarray(rng.normal(size=(m, k)), dtype)
        b = jnp.asarray(rng.normal(size=(k, n)), dtype)
        # fp32 tolerance covers blocked-K accumulation-order differences
        tol = 1e-3 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(anemm(a, b), np.float32),
            np.asarray(anemm_ref(a, b), np.float32), rtol=tol, atol=tol)

    def test_epilogue_scale_bias(self):
        a = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 32)), jnp.float32)
        s = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        np.testing.assert_allclose(anemm(a, b, s, c),
                                   anemm_ref(a, b, s, c), rtol=1e-4, atol=1e-4)

    def test_ane_mode_saturates_at_2_15(self):
        # the paper's MAC output-port ceiling, in the kernel epilogue
        a = jnp.full((1, 2), 128.0, jnp.float16)
        assert np.isinf(anemm(a, jnp.full((2, 1), 128.0, jnp.float16),
                              ane_mode=True)[0, 0])
        below = anemm(a, jnp.asarray([[127.9], [127.9]], jnp.float16),
                      ane_mode=True)[0, 0]
        assert np.isfinite(np.asarray(below, np.float32))

    def test_vjp_matches_xla(self):
        a = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 32)), jnp.float32)
        g1 = jax.grad(lambda a, b: anemm_ops.matmul(a, b).sum(), (0, 1))(a, b)
        g2 = jax.grad(lambda a, b: (a @ b).sum(), (0, 1))(a, b)
        for x, y in zip(g1, g2):
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)


class TestPalette:
    @pytest.mark.slow
    @pytest.mark.parametrize("shape", [(64, 256, 192), (32, 128, 64),
                                       (128, 512, 256)])
    def test_vs_oracle(self, shape):
        m, k, n = shape
        w = rng.normal(size=(k, n)).astype(np.float32)
        packed, lut = pack_kn(w, iters=4)
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        got = palette_matmul(a, jnp.asarray(packed), jnp.asarray(lut))
        ref = palette_matmul_ref(a, jnp.asarray(packed), jnp.asarray(lut))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)

    def test_hbm_bytes_quartered(self):
        # the streaming property: packed bytes ~ dense/4 (paper:§7.3 int4)
        lin = PaletteLinear.pack(rng.normal(size=(256, 128)).astype(np.float32))
        assert lin.dense_bytes() / lin.hbm_bytes() > 3.5

    def test_bf16_activations(self):
        w = rng.normal(size=(128, 64)).astype(np.float32)
        packed, lut = pack_kn(w, iters=4)
        a = jnp.asarray(rng.normal(size=(16, 128)), jnp.bfloat16)
        got = palette_matmul(a, jnp.asarray(packed), jnp.asarray(lut))
        ref = palette_matmul_ref(a, jnp.asarray(packed), jnp.asarray(lut))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestSparse:
    @pytest.mark.slow
    @pytest.mark.parametrize("shape", [(64, 256, 192), (16, 128, 64),
                                       (96, 512, 128)])
    def test_vs_oracle(self, shape):
        m, k, n = shape
        w = rng.normal(size=(k, n)).astype(np.float32)
        vals, sel = pack_pair_sparse(w)
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        got = sparse_matmul(a, jnp.asarray(vals), jnp.asarray(sel))
        ref = sparse_matmul_ref(a, jnp.asarray(vals), jnp.asarray(sel))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)

    def test_keeps_larger_magnitude_of_each_pair(self):
        w = np.tile(np.array([[0.1], [-2.0]], np.float32), (8, 8))  # (16, 8)
        vals, sel = pack_pair_sparse(w)
        assert np.all(np.asarray(vals) == np.float16(-2.0))

    def test_byte_ratio(self):
        lin = SparseLinear.pack(rng.normal(size=(256, 128)).astype(np.float32))
        ratio = lin.hbm_bytes() / lin.dense_bytes()
        assert 0.5 < ratio < 0.57      # 0.53x: values + packed mask


class TestActLut:
    @pytest.mark.parametrize("name", ["sigmoid", "tanh", "gelu", "swish",
                                      "erf", "softsign"])
    def test_vs_numerics_oracle(self, name):
        t = build_lut(name)
        x = np.linspace(t.xs[0] - 3, t.xs[-1] + 3, 1311).astype(np.float32)
        got = np.asarray(lut_activation(name)(jnp.asarray(x)), np.float64)
        ref = act_lut_ref(x, t)
        np.testing.assert_allclose(got, ref, atol=2e-3)

    def test_nan_coercion_in_kernel(self):
        got = lut_activation("sigmoid")(jnp.asarray([np.nan, 0.0], jnp.float32))
        assert float(got[0]) == 1.0

    def test_gradient_is_segment_slope(self):
        f = lut_activation("sigmoid")
        g = jax.grad(lambda x: f(x).sum())(jnp.asarray([0.0], jnp.float32))
        assert abs(float(g[0]) - 0.25) < 0.02   # sigmoid'(0) = 0.25

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = jnp.asarray(rng.normal(size=(257,)), dtype)
        y = lut_activation("tanh")(x)
        assert y.shape == x.shape and y.dtype == dtype


class TestFlash:
    @pytest.mark.slow
    @pytest.mark.parametrize("cfg", [
        (2, 4, 2, 128, 128, 64, True, None),
        (1, 8, 8, 100, 100, 32, True, None),
        (2, 4, 1, 64, 256, 64, False, None),
        (1, 4, 2, 256, 256, 64, True, 64),
        (1, 2, 2, 333, 333, 16, True, None),
    ])
    def test_vs_oracle(self, cfg):
        b, h, kvh, sq, skv, d, caus, win = cfg
        q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, kvh, skv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kvh, skv, d)), jnp.float32)
        got = flash_attention(q, k, v, causal=caus, window=win, bq=64, bk=64)
        ref = flash_attention_ref(q, k, v, causal=caus, window=win)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
    def test_narrow_dtypes(self, dtype):
        q = jnp.asarray(rng.normal(size=(1, 4, 64, 32)), dtype)
        k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype)
        v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype)
        got = flash_attention(q, k, v, bq=32, bk=32)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_vjp(self):
        q = jnp.asarray(rng.normal(size=(1, 4, 64, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
        g1 = jax.grad(lambda *a: flash_ops.attention(*a).sum(), (0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda *a: flash_attention_ref(*a).sum(), (0, 1, 2))(q, k, v)
        for x, y in zip(g1, g2):
            np.testing.assert_allclose(x, y, rtol=3e-3, atol=3e-3)

    def test_vmem_budget_respected(self):
        # the paper's working-set rule: default tiles fit the VMEM budget
        bq = bk = 512
        d = 128
        live = (bq * d + 2 * bk * d) * 4 + (bq * d + 2 * bq) * 4 + bq * bk * 4
        assert live < hal.TPU_V5E.onchip_bytes


class TestDecodeAttention:
    """One-token GQA decode against a long cache (the serving hot path)."""

    @pytest.mark.slow
    @pytest.mark.parametrize("cfg", [
        (2, 8, 2, 256, 64, None, 200),
        (1, 4, 1, 128, 32, None, 100),
        (2, 4, 4, 512, 64, 128, 400),    # rolling window
        (3, 16, 8, 96, 128, None, 50),
    ])
    def test_vs_oracle(self, cfg):
        from repro.kernels.flash.decode_attention import (decode_attention,
                                                          decode_attention_ref)
        b, h, kvh, s, d, win, length = cfg
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
        pos = jnp.where(pos < length, pos, -1)
        cur = jnp.full((b,), length - 1, jnp.int32)
        got = decode_attention(q, k, v, pos, cur, window=win, bk=64)
        ref = decode_attention_ref(q, k, v, pos, cur, window=win)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)

    def test_matches_model_decode_path(self):
        """The kernel agrees with the model zoo's decode attention on the
        same cache layout."""
        from repro.kernels.flash.decode_attention import decode_attention
        from repro.models.attention import _decode_attention
        from repro import configs
        import dataclasses
        cfg = dataclasses.replace(configs.get_smoke("tinyllama-1.1b"),
                                  attn_window=None)
        b, s, kvh, dh, h = 2, 64, cfg.n_kv_heads, cfg.d_head, cfg.n_heads
        q = jnp.asarray(rng.normal(size=(b, 1, h, dh)), jnp.float32)
        cache = {
            "k": jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32),
            "v": jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32),
            "pos": jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32),
        }
        positions = jnp.full((b, 1), s - 1, jnp.int32)
        ref = _decode_attention(cfg, q, cache, positions)   # (b,1,h,dh)
        got = decode_attention(q[:, 0].reshape(b, h, dh), cache["k"],
                               cache["v"], cache["pos"], positions[:, 0],
                               bk=32)
        np.testing.assert_allclose(got, np.asarray(ref[:, 0]).reshape(b, h, dh),
                                   rtol=2e-3, atol=2e-3)
