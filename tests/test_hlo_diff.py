"""HLO-diff regression: the ProgramCache key and the lowered program are
deterministic functions of (program structure, shapes, options).

The serving stack leans on `content_hash` for compile-stability: two
processes (or two rounds in one process) tracing the same program over the
same specs must land on the same cache entry, and the HLO they lower must be
identical text modulo memory addresses. A refactor that makes tracing
nondeterministic (dict-order-dependent closure, address-bearing param,
unstable name) silently degrades every warm start into a recompile — these
tests pin the three program families the servers cache: decode steps,
prefill chunks, and the conv-stem programs the encoder scenario added.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.dispatch import ProgramCache, content_hash
from repro.models.model import build_model

_ADDR = re.compile(r"0x[0-9a-f]+")


def _scrub(text: str) -> str:
    return _ADDR.sub("0x", text)


def _hlo(fn, *args) -> str:
    return _scrub(jax.jit(fn).lower(*args).as_text())


@pytest.fixture(scope="module")
def decoder():
    cfg = configs.get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def encoder():
    cfg = configs.get_smoke("whisper-small")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _decode_args(model, params, b=2, ctx=16):
    caches = model.init_cache(b, ctx)
    token = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    return params, caches, token, pos


def _chunk_args(model, params, b=2, ctx=16, c=4):
    caches = model.init_cache(b, ctx)
    tokens = jnp.zeros((b, c), jnp.int32)
    pos0 = jnp.zeros((b,), jnp.int32)
    return params, caches, tokens, pos0


def _conv_args(b=1, t=12, mels=8, d=16):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, 1, t, mels)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1, 3, mels, d)), jnp.float32)
    return x, w


def _conv_program(x, w):
    from repro.kernels.conv.ref import conv2d_ref
    return conv2d_ref(x, w, stride=(1, 2), padding="SAME", epilogue="gelu")


# ---------------------------------------------------------------------------
# Stability: same program + same specs -> same key, same HLO
# ---------------------------------------------------------------------------


def test_decode_program_hash_is_stable(decoder):
    _, model, params = decoder
    args = _decode_args(model, params)
    hashes = {content_hash(model.decode_step, args) for _ in range(3)}
    assert len(hashes) == 1
    # fresh caches (fresh memo tables, fresh receiver ids) agree too
    keys = {ProgramCache()._key(model.decode_step, args, "") for _ in range(2)}
    assert keys == hashes


def test_chunk_program_hash_is_stable(decoder):
    _, model, params = decoder
    args = _chunk_args(model, params)
    hashes = {content_hash(model.prefill_chunk, args) for _ in range(3)}
    assert len(hashes) == 1


def test_conv_program_hash_is_stable():
    args = _conv_args()
    hashes = {content_hash(_conv_program, args) for _ in range(3)}
    assert len(hashes) == 1


def test_decode_hlo_is_stable_across_lowerings(decoder):
    _, model, params = decoder
    args = _decode_args(model, params)
    assert _hlo(model.decode_step, *args) == _hlo(model.decode_step, *args)


def test_chunk_hlo_is_stable_across_lowerings(decoder):
    _, model, params = decoder
    args = _chunk_args(model, params)
    assert _hlo(model.prefill_chunk, *args) == _hlo(model.prefill_chunk, *args)


def test_conv_hlo_is_stable_across_lowerings():
    args = _conv_args()
    assert _hlo(_conv_program, *args) == _hlo(_conv_program, *args)


def test_encoder_prefill_hash_is_stable(encoder):
    cfg, model, params = encoder
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "frames": jnp.asarray(rng.normal(size=(2,) + cfg.frame_shape),
                                   jnp.float32)}
    args = (params, batch)
    hashes = {content_hash(model.prefill, args) for _ in range(2)}
    assert len(hashes) == 1


def test_warm_start_hits_the_cache(decoder):
    _, model, params = decoder
    args = _decode_args(model, params)
    pc = ProgramCache()
    _, k1 = pc.compile(model.decode_step, *args)
    assert not pc.is_new_compile_required(model.decode_step, *args)
    _, k2 = pc.compile(model.decode_step, *args)
    assert k1 == k2 and pc.stats.hits == 1 and pc.stats.misses == 1


# ---------------------------------------------------------------------------
# Sensitivity: a deliberate perturbation MUST change key and HLO
# ---------------------------------------------------------------------------


def test_shape_perturbation_changes_hash_and_hlo(decoder):
    _, model, params = decoder
    base = _chunk_args(model, params, c=4)
    bumped = _chunk_args(model, params, c=5)
    assert content_hash(model.prefill_chunk, base) \
        != content_hash(model.prefill_chunk, bumped)
    assert _hlo(model.prefill_chunk, *base) \
        != _hlo(model.prefill_chunk, *bumped)


def test_options_perturbation_changes_hash(decoder):
    _, model, params = decoder
    args = _decode_args(model, params)
    assert content_hash(model.decode_step, args, options="donate=1") \
        != content_hash(model.decode_step, args, options="")


def test_conv_static_perturbation_changes_hash_and_hlo():
    from repro.kernels.conv.ref import conv2d_ref

    def stride1(x, w):
        return conv2d_ref(x, w, stride=(1, 1), padding="SAME",
                          epilogue="gelu")

    args = _conv_args()
    assert content_hash(_conv_program, args) != content_hash(stride1, args)
    assert _hlo(_conv_program, *args) != _hlo(stride1, *args)
