"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from reports/.

    PYTHONPATH=src python -m benchmarks.make_experiments

Reads reports/dryrun/*.json (baseline sweep) and reports/dryrun2/*.json
(optimized-defaults sweep) and writes reports/tables.md, which EXPERIMENTS.md
includes verbatim. Analytic terms are recomputed live (the model improved
after the first sweep; artifact numbers stay as recorded)."""

from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.core import analytic, costmodel, hal

BASE = os.path.join(os.path.dirname(__file__), "..", "reports")


def one_sentence_lever(dom: str, cfg, shape) -> str:
    if dom == "compute":
        return ("already compute-bound; next: cut overcompute (causal-block "
                "skip, MoE capacity) or grow per-chip batch")
    if dom == "memory":
        if shape.kind == "decode":
            return ("stream weights compressed (int4 palette kernel, 4x "
                    "fewer HBM bytes) and context-shard the KV cache")
        return "sequence-shard residuals (SP) and stream weights compressed"
    return ("overlap or shrink collectives: EP+SP fusion, bf16/int8 wire "
            "dtypes, replicated small embeddings")


def load(dirname: str) -> dict:
    out = {}
    for p in sorted(glob.glob(os.path.join(BASE, dirname, "*.json"))):
        d = json.load(open(p))
        tag = os.path.basename(p)[:-5]
        if d.get("overrides"):
            continue
        if "__" in tag and len(tag.split("__")) > 3:
            continue  # hillclimb variants live in §Perf, not the table
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def main() -> None:
    base = load("dryrun")
    opt = load("dryrun2")
    lines: list[str] = []
    v5e = hal.TPU_V5E

    lines.append("### Dry-run + roofline table (single-pod 16x16 = 256 chips; "
                 "multi-pod 2x16x16 = 512 chips)\n")
    lines.append("Terms in seconds per step (analytic, recomputed with the "
                 "final cost model); `mem` = per-chip peak from "
                 "`memory_analysis()` of the compiled artifact "
                 "(baseline sweep -> optimized-defaults sweep).\n")
    lines.append("| arch | shape | mesh | compute_s | memory_s | collective_s "
                 "| dominant | MODEL/HLO flops | mem GB (base->opt) | "
                 "roofline fraction | lever |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")

    for arch in configs.ARCH_NAMES:
        cfg = configs.get_config(arch)
        for shape_name in configs.SHAPES:
            shape = configs.SHAPES[shape_name]
            for mesh in ("pod", "multipod"):
                key = (arch, shape_name, mesh)
                d = base.get(key)
                if d is None:
                    continue
                if d["status"] == "SKIP":
                    lines.append(f"| {arch} | {shape_name} | {mesh} | — | — | "
                                 f"— | SKIP | — | — | — | "
                                 f"principled skip: full-attention arch at "
                                 f"512k context |")
                    continue
                terms = analytic.analyze_cell(cfg, shape,
                                              analytic.mesh_of(mesh))
                sec = terms.seconds(v5e)
                dom = terms.dominant(v5e)
                # conservative (no-overlap) roofline fraction: useful compute
                # time over the SUM of the three terms
                step = sum(sec.values())
                useful = ((costmodel.model_flops(cfg, shape)
                           + costmodel.attention_flops(cfg, shape))
                          / analytic.mesh_of(mesh).chips / v5e.peak_flops)
                frac = useful / step if step else 0.0
                mem_b = d["roofline"]["peak_mem_gb"]
                d2 = opt.get(key)
                mem_o = d2["roofline"]["peak_mem_gb"] if d2 and d2["status"] == "OK" else None
                memtxt = f"{mem_b:.1f}->{mem_o:.1f}" if mem_o is not None else f"{mem_b:.1f}"
                ratio = d["roofline"]["useful_ratio"]
                lines.append(
                    f"| {arch} | {shape_name} | {mesh} | {sec['compute_s']:.4f} "
                    f"| {sec['memory_s']:.4f} | {sec['collective_s']:.4f} "
                    f"| {dom} | {ratio:.1f}x (loop-once) | {memtxt} "
                    f"| {min(frac, 1.0):.2f} "
                    f"| {one_sentence_lever(dom, cfg, shape)} |")

    # dominant-term census
    lines.append("")
    doms = {"compute": 0, "memory": 0, "collective": 0}
    n_ok = n_skip = 0
    for (arch, s, m), d in base.items():
        if d["status"] == "SKIP":
            n_skip += 1
            continue
        n_ok += 1
        cfg = configs.get_config(arch)
        t = analytic.analyze_cell(cfg, configs.SHAPES[s], analytic.mesh_of(m))
        doms[t.dominant(v5e)] += 1
    lines.append(f"**Census**: {n_ok} compiled cells + {n_skip} principled "
                 f"skips; dominant terms — compute {doms['compute']}, "
                 f"memory {doms['memory']}, collective {doms['collective']}.\n")

    path = os.path.join(BASE, "tables.md")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
