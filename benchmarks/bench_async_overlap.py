"""Overlapped-vs-serialized decode bench (paper §2.4's open question).

    PYTHONPATH=src python -m benchmarks.bench_async_overlap [--fast]

The paper's command protocol encodes work into command buffers the firmware
drains while the host keeps encoding; our sound default
(`ExecutionStream.execute_sync`) instead serializes every dispatch, paying
the §9.4 floor with the host idle in between. This bench measures what the
overlap buys on the serving stack: the same request set is served by

  * `ContinuousSchedule` on a sync `ExecutionStream` — one blocking
    dispatch per decode tick, logits round-tripped to the host sampler;
  * `SLOSchedule` on an `AsyncExecutionStream` — pipelined decode windows
    (encode step N+1 while step N executes), sampling fused on device, the
    host blocking once per window instead of once per token;

at decode-lane counts {4, 16}, and compares *per-generated-token wall
time* on the warm (cache-hit) round. Greedy token streams must stay
bit-identical between the two schedules — overlap may never buy speed with
different tokens.

Wall times are host-CPU correctness-path costs, never presented as
accelerator performance; the point is the *shape*: overlapped decode must
be strictly faster per token than serialized decode once lanes are busy.

Writes `BENCH_async.json` (repo root by default). Exits nonzero when
overlap shows no strict per-token improvement at any measured lane count
(the acceptance bar is lanes {4, 16}), or on any token mismatch — this is
the CI gate alongside the §9.4 amortization check.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import (AsyncExecutionStream, ExecutionStream,
                                 KernelDispatcher, ProgramCache)
from repro.launch.scheduler import ContinuousSchedule, Request, SLOSchedule
from repro.models.model import build_model

LANES = (4, 16)


def _requests(cfg, lens, gen, *, rid0: int = 0, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32),
                    max_new_tokens=gen)
            for i, L in enumerate(lens)]


def _timed_round(sched, cfg, lens, gen, rep: int):
    reqs = _requests(cfg, lens, gen, rid0=rep * len(lens))
    t0 = time.perf_counter()
    results = sched.run(reqs)
    wall = time.perf_counter() - t0
    return wall, {r.rid - rep * len(lens): r.tokens for r in results}


def _run_interleaved(scheds: dict, cfg, lens, gen, reps: int):
    """Warm every schedule once, then time `reps` identical warm rounds
    per schedule, *interleaved* (sync round, async round, sync round, ...)
    so host-clock drift hits both sides equally; best-of-N per schedule is
    the slope-method discipline. Greedy streams are identical across
    rounds, so one round's tokens represent all."""
    for sched in scheds.values():
        sched.run(_requests(cfg, lens, gen, rid0=0))
    best = {name: float("inf") for name in scheds}
    toks = {}
    for rep in range(1, reps + 1):
        for name, sched in scheds.items():
            wall, t = _timed_round(sched, cfg, lens, gen, rep)
            best[name] = min(best[name], wall)
            toks[name] = t
    return best, toks


def bench(arch: str, *, prompt_len: int, gen: int, target_name: str,
          max_in_flight: int, reps: int = 3, seed: int = 0) -> dict:
    cfg = configs.get_smoke(arch)
    target = hal.get_target(target_name)
    model = build_model(cfg, dispatcher=KernelDispatcher(target))
    params = model.init(jax.random.PRNGKey(seed))

    curve = []
    for n_slots in LANES:
        # heterogeneous prompts around prompt_len: bucketed prefills + the
        # teacher-forced catch-up path, not just one shape
        lens = [max(2, prompt_len - (i % 3) * (prompt_len // 4))
                for i in range(n_slots)]
        max_len = max(lens) + gen
        n_tokens = gen * n_slots

        async_stream = AsyncExecutionStream(ProgramCache(), target=target,
                                            max_in_flight=max_in_flight)
        scheds = {
            "sync": ContinuousSchedule(
                model, params, cfg, n_slots=n_slots, max_len=max_len,
                stream=ExecutionStream(ProgramCache(), target=target),
                sampling="greedy", seed=seed),
            "async": SLOSchedule(
                model, params, cfg, n_slots=n_slots, max_len=max_len,
                stream=async_stream, sampling="greedy", seed=seed),
        }
        best, toks = _run_interleaved(scheds, cfg, lens, gen, reps)
        sync_wall, async_wall = best["sync"], best["async"]

        parity = all(np.array_equal(toks["sync"][i], toks["async"][i])
                     for i in range(n_slots))
        recs = async_stream.records
        row = {
            "n_slots": n_slots,
            "n_requests": n_slots,
            "prompt_lens": lens,
            "sync_s_per_token": sync_wall / n_tokens,
            "async_s_per_token": async_wall / n_tokens,
            "sync_wall_s": sync_wall,
            "async_wall_s": async_wall,
            "speedup_x": sync_wall / max(async_wall, 1e-12),
            "mean_inflight_depth": float(np.mean(
                [r.inflight_depth for r in recs])) if recs else 0.0,
            "async_dispatches": len(recs),
            "token_parity": bool(parity),
        }
        curve.append(row)
        print(f"lanes={n_slots:3d}: sync {row['sync_s_per_token']*1e6:8.1f} "
              f"us/tok, overlapped {row['async_s_per_token']*1e6:8.1f} us/tok "
              f"({row['speedup_x']:.2f}x), parity={parity}")

    return {
        "arch": cfg.name,
        "target": target.name,
        "dispatch_floor_s": target.dispatch_floor_s,
        "gen": gen,
        "max_in_flight": max_in_flight,
        "reps": reps,
        "lanes": list(LANES),
        "curve": curve,
        "paper_ref": "§2.4 overlapping streams (open question) + "
                     "§9.4 dispatch floor",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: short prompts/gen")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-in-flight", type=int, default=8)
    ap.add_argument("--reps", type=int, default=5,
                    help="timed warm rounds per (schedule, lanes), "
                         "interleaved; best wall is reported")
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS))
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_async.json"))
    args = ap.parse_args(argv)

    if args.fast:
        args.prompt_len, args.gen = 12, 12

    report = bench(args.arch, prompt_len=args.prompt_len, gen=args.gen,
                   target_name=args.target, max_in_flight=args.max_in_flight,
                   reps=args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"-> {os.path.abspath(args.out)}")

    failed = False
    for row in report["curve"]:
        if not row["token_parity"]:
            print(f"FAIL: lanes={row['n_slots']}: overlapped greedy tokens "
                  f"diverged from the serialized schedule", file=sys.stderr)
            failed = True
        if row["async_s_per_token"] >= row["sync_s_per_token"]:
            print(f"FAIL: lanes={row['n_slots']}: overlapped decode "
                  f"({row['async_s_per_token']*1e6:.1f} us/tok) is not "
                  f"faster than execute_sync "
                  f"({row['sync_s_per_token']*1e6:.1f} us/tok)",
                  file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
