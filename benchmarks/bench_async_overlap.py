"""Overlapped-vs-serialized decode bench (paper §2.4's open question).

    PYTHONPATH=src python -m benchmarks.bench_async_overlap [--fast]

The paper's command protocol encodes work into command buffers the firmware
drains while the host keeps encoding; our sound default
(`ExecutionStream.execute_sync`) instead serializes every dispatch, paying
the §9.4 floor with the host idle in between. This bench measures what the
overlap buys on the serving stack: the same request set is served by

  * `ContinuousSchedule` on a sync `ExecutionStream` — one blocking
    dispatch per decode tick, logits round-tripped to the host sampler;
  * `SLOSchedule` on an `AsyncExecutionStream` — pipelined decode windows
    (encode step N+1 while step N executes), sampling fused on device, the
    host blocking once per window instead of once per token;

at decode-lane counts {4, 16}, and compares *per-generated-token wall
time* on the warm (cache-hit) round. Greedy token streams must stay
bit-identical between the two schedules — overlap may never buy speed with
different tokens.

Wall times are host-CPU correctness-path costs, never presented as
accelerator performance; the point is the *shape*: overlapped decode must
be strictly faster per token than serialized decode once lanes are busy.

Writes `BENCH_async.json` (repo root by default). Exits nonzero when
overlap shows no strict per-token improvement at any measured lane count
(the acceptance bar is lanes {4, 16}), or on any token mismatch — this is
the CI gate alongside the §9.4 amortization check.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import (AsyncExecutionStream, ExecutionStream,
                                 ProgramCache)
from repro.launch.scheduler import ContinuousSchedule, SLOSchedule

from benchmarks._common import (build_smoke_model, emit_report, gate,
                                hetero_lens, interleaved_best_of)

LANES = (4, 16)


def bench(arch: str, *, prompt_len: int, gen: int, target_name: str,
          max_in_flight: int, reps: int = 3, seed: int = 0) -> dict:
    cfg, target, model, params = build_smoke_model(arch, target_name, seed)

    curve = []
    for n_slots in LANES:
        # heterogeneous prompts around prompt_len: bucketed prefills + the
        # teacher-forced catch-up path, not just one shape
        lens = hetero_lens(prompt_len, n_slots)
        max_len = max(lens) + gen
        n_tokens = gen * n_slots

        async_stream = AsyncExecutionStream(ProgramCache(), target=target,
                                            max_in_flight=max_in_flight)
        scheds = {
            "sync": ContinuousSchedule(
                model, params, cfg, n_slots=n_slots, max_len=max_len,
                stream=ExecutionStream(ProgramCache(), target=target),
                sampling="greedy", seed=seed),
            "async": SLOSchedule(
                model, params, cfg, n_slots=n_slots, max_len=max_len,
                stream=async_stream, sampling="greedy", seed=seed),
        }
        best, toks = interleaved_best_of(scheds, cfg, lens, gen, reps)
        sync_wall, async_wall = best["sync"], best["async"]

        parity = all(np.array_equal(toks["sync"][i], toks["async"][i])
                     for i in range(n_slots))
        recs = async_stream.records
        row = {
            "n_slots": n_slots,
            "n_requests": n_slots,
            "prompt_lens": lens,
            "sync_s_per_token": sync_wall / n_tokens,
            "async_s_per_token": async_wall / n_tokens,
            "sync_wall_s": sync_wall,
            "async_wall_s": async_wall,
            "speedup_x": sync_wall / max(async_wall, 1e-12),
            "mean_inflight_depth": float(np.mean(
                [r.inflight_depth for r in recs])) if recs else 0.0,
            "async_dispatches": len(recs),
            "token_parity": bool(parity),
        }
        curve.append(row)
        print(f"lanes={n_slots:3d}: sync {row['sync_s_per_token']*1e6:8.1f} "
              f"us/tok, overlapped {row['async_s_per_token']*1e6:8.1f} us/tok "
              f"({row['speedup_x']:.2f}x), parity={parity}")

    return {
        "arch": cfg.name,
        "target": target.name,
        "dispatch_floor_s": target.dispatch_floor_s,
        "gen": gen,
        "max_in_flight": max_in_flight,
        "reps": reps,
        "lanes": list(LANES),
        "curve": curve,
        "paper_ref": "§2.4 overlapping streams (open question) + "
                     "§9.4 dispatch floor",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: short prompts/gen")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-in-flight", type=int, default=8)
    ap.add_argument("--reps", type=int, default=5,
                    help="timed warm rounds per (schedule, lanes), "
                         "interleaved; best wall is reported")
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS))
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_async.json"))
    args = ap.parse_args(argv)

    if args.fast:
        args.prompt_len, args.gen = 12, 12

    report = bench(args.arch, prompt_len=args.prompt_len, gen=args.gen,
                   target_name=args.target, max_in_flight=args.max_in_flight,
                   reps=args.reps)
    emit_report(report, args.out)

    failures = []
    for row in report["curve"]:
        if not row["token_parity"]:
            failures.append(f"lanes={row['n_slots']}: overlapped greedy "
                            f"tokens diverged from the serialized schedule")
        if row["async_s_per_token"] >= row["sync_s_per_token"]:
            failures.append(
                f"lanes={row['n_slots']}: overlapped decode "
                f"({row['async_s_per_token']*1e6:.1f} us/tok) is not faster "
                f"than execute_sync "
                f"({row['sync_s_per_token']*1e6:.1f} us/tok)")
    return gate(failures)


if __name__ == "__main__":
    sys.exit(main())
