"""Chunked-prefill SLO tail bench (paper §9.4 head-of-line blocking).

    PYTHONPATH=src python -m benchmarks.bench_slo_tail [--fast]

The §9 dispatch economics make a monolithic long-prompt prefill the worst
head-of-line block on a serving stream: one dispatch whose wall grows with
the prompt, issued at an admission barrier while every in-flight decode
lane waits. `--prefill-chunk` splits that admission into fixed-size chunk
dispatches with decode windows between them, so the in-flight lanes' token
cadence survives a long arrival.

Scenario: short requests decoding from step 0, one long prompt arriving
mid-stream at step 2, served by `SLOSchedule` at the same SLO twice —
chunked vs unchunked. The measured tail is the p99 *decode gap*: the
distribution of completion-time deltas between consecutive fused decode
dispatches on the warm (cache-hit) round. Unchunked, one gap swallows the
whole prefill wall; chunked, every gap is bounded by one chunk.

Gates (exit nonzero on any failure — the CI `slo-chunked` leg):
  * greedy token streams bit-identical chunked vs unchunked, per request;
  * chunked p99 decode gap strictly below unchunked at the same SLO;
  * every chunk is floor-charged on the scheduler's own stream and the
    recorded spans tile [0, target) exactly.

With >= 8 visible devices the bench also serves the long prompt through
`ring_prefill` routing on a 2x4 mesh and gates greedy-stream equality
against the single-device run (the long-context route). Wall times are
host-CPU correctness-path costs, never accelerator performance claims.

Writes `BENCH_slo.json` (repo root by default).
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

import numpy as np

from repro.core.dispatch import AsyncExecutionStream, ProgramCache
from repro.launch.scheduler import (ChunkConfig, Request, ServeConfig,
                                    SLOConfig, build_scheduler)

from benchmarks._common import build_smoke_model, emit_report, gate, \
    make_requests


def _requests(cfg, short_lens, long_len, gen, gen_long, *, rid0, seed=0):
    reqs = make_requests(cfg, list(short_lens) + [long_len], gen,
                         rid0=rid0, seed=seed)
    long_req = reqs[-1]
    reqs[-1] = Request(rid=long_req.rid, prompt=long_req.prompt,
                       max_new_tokens=gen_long, arrival=2)
    return reqs


def _decode_gap_p99(sched, recs) -> float:
    """p99 of completion-time deltas between consecutive fused decode
    dispatches: the serving tail an in-flight request actually feels."""
    ts = sorted(r.complete_ts for r in recs if r.key in sched._decode_keys)
    gaps = np.diff(np.asarray(ts))
    return float(np.percentile(gaps, 99)) if gaps.size else 0.0


def _audit_chunks(sched, recs, long_len: int, chunk: int) -> list[str]:
    failures = []
    spans = sorted(r.span for r in recs if r.span is not None)
    target = chunk * ((long_len - 1) // chunk)
    if not spans:
        return [f"no chunk dispatches recorded for the {long_len}-token "
                f"prompt"]
    if spans[0][0] != 0 or spans[-1][1] != target:
        failures.append(f"chunk spans {spans} do not cover [0, {target})")
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        if a1 != b0:
            failures.append(f"chunk spans gap/overlap at {a1} vs {b0}")
    floor = sched.stream.floor_s
    if not all(r.floor_s == floor for r in recs if r.span is not None):
        failures.append("a chunk dispatch was not floor-charged on the "
                        "scheduler's stream")
    return failures


def bench(arch: str, *, short_lens, long_len: int, gen: int, gen_long: int,
          chunk: int, slo_ms: float, target_name: str, reps: int,
          seed: int = 0) -> dict:
    cfg, target, model, params = build_smoke_model(arch, target_name, seed)
    max_len = max(max(short_lens) + gen, long_len + gen_long)
    n_slots = len(short_lens) + 1

    def make_sched(chunked: bool):
        stream = AsyncExecutionStream(ProgramCache(), target=target)
        config = ServeConfig(
            schedule="slo", max_len=max_len, n_slots=n_slots, stream=stream,
            seed=seed, slo=SLOConfig(slo_ms=slo_ms),
            chunk=ChunkConfig(prefill_chunk=chunk) if chunked else None)
        return build_scheduler(config, model, params, cfg)

    scheds = {"unchunked": make_sched(False), "chunked": make_sched(True)}
    # warm round: compiles land here, never in a measured round
    for name, sched in scheds.items():
        sched.run(_requests(cfg, short_lens, long_len, gen, gen_long,
                            rid0=0, seed=seed))
    best = {name: float("inf") for name in scheds}
    toks: dict = {}
    round_recs: dict = {}
    for rep in range(1, reps + 1):
        for name, sched in scheds.items():
            seen = len(sched.stream.records)
            res = sched.run(_requests(cfg, short_lens, long_len, gen,
                                      gen_long, rid0=rep * n_slots,
                                      seed=seed))
            recs = sched.stream.records[seen:]
            p99 = _decode_gap_p99(sched, recs)
            if p99 < best[name]:
                best[name] = p99
                round_recs[name] = recs
            toks[name] = {r.rid - rep * n_slots: r.tokens for r in res}

    failures = []
    for rid in toks["unchunked"]:
        if not np.array_equal(toks["unchunked"][rid], toks["chunked"][rid]):
            failures.append(f"request {rid}: chunked tokens diverge from "
                            f"unchunked (greedy must be bit-identical)")
    if not best["chunked"] < best["unchunked"]:
        failures.append(
            f"chunked p99 decode gap {best['chunked']*1e3:.3f} ms not "
            f"strictly below unchunked {best['unchunked']*1e3:.3f} ms: "
            f"chunking failed to break head-of-line blocking")
    failures += _audit_chunks(scheds["chunked"], round_recs["chunked"],
                              long_len, chunk)

    report = {
        "bench": "slo_tail",
        "arch": arch,
        "target": target_name,
        "short_lens": list(short_lens),
        "long_len": long_len,
        "gen": gen,
        "prefill_chunk": chunk,
        "slo_ms": slo_ms,
        "reps": reps,
        "p99_decode_gap_s": {k: best[k] for k in best},
        "improvement": best["unchunked"] / max(best["chunked"], 1e-12),
        "chunk_stats": scheds["chunked"].stats(n_slots).get(
            "chunked_prefill"),
        "token_parity": not any("diverge" in f for f in failures),
    }

    # long-context ring route: only with enough devices for a 2x4 mesh
    import jax
    if jax.device_count() >= 8:
        from repro.models.model import build_model
        from repro.parallel.ctx import ParallelContext
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ring_ctx = dataclasses.replace(ParallelContext(mesh=mesh),
                                       ring_prefill_min=chunk)
        ring_model = build_model(cfg, ring_ctx,
                                 dispatcher=model.dispatcher)
        stream = AsyncExecutionStream(ProgramCache(), target=target)
        config = ServeConfig(schedule="slo", max_len=max_len,
                             n_slots=n_slots, stream=stream, seed=seed,
                             slo=SLOConfig(slo_ms=slo_ms), ctx=ring_ctx)
        ring_sched = build_scheduler(config, ring_model, params, cfg)
        res = ring_sched.run(_requests(cfg, short_lens, long_len, gen,
                                       gen_long, rid0=0, seed=seed))
        ring_toks = {r.rid: r.tokens for r in res}
        ring_ok = all(np.array_equal(ring_toks[rid],
                                     toks["unchunked"][rid])
                      for rid in toks["unchunked"])
        if not ring_ok:
            failures.append("ring-routed greedy streams diverge from the "
                            "single-device run")
        report["ring"] = {"mesh": "2x4", "ring_prefill_min": chunk,
                          "token_parity": ring_ok}
    else:
        report["ring"] = None

    report["failures"] = failures
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--target", default="tpu-v5e")
    ap.add_argument("--fast", action="store_true",
                    help="CI sizing: shorter prompts, fewer reps")
    ap.add_argument("--out", default="BENCH_slo.json")
    args = ap.parse_args(argv)

    # the long prompt must be deep enough that its monolithic prefill wall
    # dominates the per-dispatch overhead (the smoke model on CPU is
    # dispatch-bound below ~128 tokens: a chunk and a short prefill cost
    # the same wall, and chunking could not show its win)
    if args.fast:
        report = bench(args.arch, short_lens=(12, 9, 14), long_len=260,
                       gen=16, gen_long=4, chunk=32, slo_ms=1e6,
                       target_name=args.target, reps=2)
    else:
        report = bench(args.arch, short_lens=(16, 12, 20), long_len=260,
                       gen=24, gen_long=6, chunk=32, slo_ms=1e6,
                       target_name=args.target, reps=3)

    emit_report(report, args.out)
    up = report["improvement"]
    print(f"p99 decode gap: unchunked "
          f"{report['p99_decode_gap_s']['unchunked']*1e3:.3f} ms -> "
          f"chunked {report['p99_decode_gap_s']['chunked']*1e3:.3f} ms "
          f"({up:.2f}x), parity={report['token_parity']}, "
          f"ring={report['ring'] and report['ring']['token_parity']}")
    return gate(report["failures"])


if __name__ == "__main__":
    sys.exit(main())
