"""§9.4 batching-amortization bench on the continuous-batching scheduler.

    PYTHONPATH=src python -m benchmarks.bench_serve_batching [--fast]

The paper's dispatch-floor curve: batching to 512 samples drops per-sample
dispatch cost ~127x because the fixed per-command floor t0 is shared by the
whole batch. We reproduce the *shape* of that curve on the serving stack:
the same request set is served by `ContinuousSchedule` at decode-lane
counts {1, 4, 16}, every model dispatch flows through one
`ExecutionStream`, and each `DispatchRecord` charges the costmodel floor
estimate of the HAL target (`Target.dispatch_floor_s`). Per-request
dispatch overhead = total floor charged / #requests, which must fall
strictly monotonically as lanes share each decode dispatch.

Wall times here are host-CPU correctness-path costs, never presented as
accelerator performance (DESIGN.md §7 evidence marks); the floor-derived
overhead column is the modeled reproduction target.

Writes `BENCH_serve.json` (repo root by default) and exits nonzero if the
overhead curve is not strictly decreasing.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro import configs
from repro.core import hal
from repro.core.dispatch import ExecutionStream, ProgramCache
from repro.launch.scheduler import ContinuousSchedule

from benchmarks._common import (build_smoke_model, emit_report, gate,
                                hetero_lens, make_requests)

BATCH_SIZES = (1, 4, 16)


def bench(arch: str, *, n_requests: int, prompt_len: int, gen: int,
          target_name: str, seed: int = 0) -> dict:
    cfg, target, model, params = build_smoke_model(arch, target_name, seed)
    lens = hetero_lens(prompt_len, n_requests)
    max_len = max(lens) + gen

    curve = []
    for n_slots in BATCH_SIZES:
        stream = ExecutionStream(ProgramCache(), target=target)
        sched = ContinuousSchedule(model, params, cfg, n_slots=n_slots,
                                   max_len=max_len, stream=stream,
                                   sampling="greedy", seed=seed)
        results = sched.run(make_requests(cfg, lens, gen, seed=seed))
        assert len(results) == n_requests
        stats = sched.stats(n_requests)
        curve.append({
            "n_slots": n_slots,
            "n_dispatches": stats["n_dispatches"],
            "per_request_dispatches": stats["per_request_dispatches"],
            "per_request_dispatch_overhead_s":
                stats["per_request_dispatch_overhead_s"],
            "per_request_work_s": stats["work_s"] / n_requests,
            "dispatch_wall_s": stats["dispatch_wall_s"],
            "cache_misses": stream.cache.stats.misses,
            "cache_hits": stream.cache.stats.hits,
        })
        print(f"lanes={n_slots:3d}: {stats['n_dispatches']:4d} dispatches, "
              f"floor/request {stats['per_request_dispatch_overhead_s']*1e6:8.1f} us, "
              f"cache h{stream.cache.stats.hits}/m{stream.cache.stats.misses}")

    overh = [c["per_request_dispatch_overhead_s"] for c in curve]
    monotonic = all(b < a for a, b in zip(overh, overh[1:]))
    return {
        "arch": cfg.name,
        "target": target.name,
        "dispatch_floor_s": target.dispatch_floor_s,
        "n_requests": n_requests,
        "prompt_lens": lens,
        "gen": gen,
        "batch_sizes": list(BATCH_SIZES),
        "curve": curve,
        "per_request_dispatch_overhead_s": overh,
        "amortization_x": overh[0] / overh[-1],
        "monotonic_decreasing": monotonic,
        "paper_ref": "§9.4: batch 512 drops per-sample dispatch cost ~127x",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: short prompts/gen")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS))
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args(argv)

    if args.fast:
        args.prompt_len, args.gen = 12, 4

    report = bench(args.arch, n_requests=args.requests,
                   prompt_len=args.prompt_len, gen=args.gen,
                   target_name=args.target)
    print(f"amortization 1 -> {BATCH_SIZES[-1]} lanes: "
          f"{report['amortization_x']:.1f}x less dispatch floor per request")
    emit_report(report, args.out)
    failures = []
    if not report["monotonic_decreasing"]:
        failures.append("per-request dispatch overhead is not strictly "
                        "decreasing with batch size")
    return gate(failures)


if __name__ == "__main__":
    sys.exit(main())
