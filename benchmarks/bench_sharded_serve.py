"""EP-sharded multi-host serving bench (DESIGN.md §5 on the serve stack).

    PYTHONPATH=src python -m benchmarks.bench_sharded_serve [--fast]

Serving shards differently than training: params stay replicated EXCEPT the
packed MoE expert banks (EP over the "model" axis — the only placement that
keeps greedy token streams bit-identical, since TP would reorder the psum
reductions), and the decode lanes (the batch dim of the shared KV cache)
shard over the batch axes — each "host" is one batch-axis rank with its
model-axis device column co-located. This bench proves, on 8 virtual CPU
devices (`--xla_force_host_platform_device_count=8`, set at import), the
three claims the ISSUE gates:

  * **bit-identical streams** — the same request round served single-device
    and on a `4x2` ("data","model") mesh must produce byte-equal greedy
    token matrices, for (continuous, slo) x (fp16, int4_palette), with the
    SAME dispatch count: SPMD means every host dispatches every program, so
    the per-host ledger is unchanged and the fleet pays
    `n_hosts x floor_s` — that identity is gated exactly.
  * **EP actually routes** — a packed (int4_palette) dbrx MoE served on a
    `2x4` mesh with 8 lanes must take the `shard_map` expert-parallel path:
    `repro.models.moe.ROUTE_COUNTS["ep"]` must tick during the serve
    trace, and a direct prefill of the same packed params on and off the
    mesh must agree to float tolerance (1e-4). The EP combine legitimately
    reorders the expert reduction, so MoE logits match to ~1e-7, not
    bitwise — greedy argmax on a random-init smoke model can flip on that,
    which is why this leg reports (never gates) token agreement. (The
    batch-1 bucketed prefill stays on the dense path by design — only the
    decode batch clears the tokens-divisibility gate.)
  * **evacuation is token-exact** — a mid-stream host loss (injected
    vanish at a decode tick, and a watchdog-caught hang in the full run)
    must evacuate the failed host's lanes through the ServeSupervisor:
    mesh shrinks `4x2 -> 3x2` over the survivors, the interrupted lanes
    re-admit with their generated prefix teacher-forced, and the final
    token matrix is byte-equal to the uninterrupted single-device run,
    with exactly one restart and one rescale in the ledger.

Wall clocks are reported, never gated (host-CPU shard_map overhead is not
accelerator performance — DESIGN.md evidence marks). Writes
`BENCH_shard.json`; exits nonzero on any violated gate. `--fast` keeps one
parity pair, the EP leg and the vanish evacuation (the CI matrix leg).
"""

from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}".strip()

import argparse  # noqa: E402
import sys       # noqa: E402

import numpy as np  # noqa: E402

from repro.models.moe import ROUTE_COUNTS          # noqa: E402
from repro.launch.serve import run as serve_run    # noqa: E402

from benchmarks._common import emit_report, gate   # noqa: E402

#: parity matrix: every (schedule, weight form) pair must stream
#: bit-identically on and off the mesh
PARITY_LEGS = (("continuous", "fp16"), ("slo", "fp16"),
               ("continuous", "int4_palette"), ("slo", "int4_palette"))
MESH = "4x2"          # lanes over data=4, expert banks over model=2
EP_MESH = "2x4"       # dbrx smoke: 4 experts % model=4 == 0, 8 lanes % 8 == 0


def _argv(arch, schedule, form, batch, plen, gen, *extra):
    return ["--arch", arch, "--smoke", "--schedule", schedule,
            "--weight-form", form, "--batch", str(batch),
            "--prompt-len", str(plen), "--gen", str(gen),
            "--sampling", "greedy", *extra]


def _row(tag, out):
    row = {"tag": tag, "wall_s": round(out["wall_s"], 4),
           "tok_per_s": round(out["tok_per_s"], 2),
           "n_dispatches": out["n_dispatches"]}
    for k in ("mesh_axes", "n_hosts", "per_host_floor_s", "fleet_floor_s",
              "restarts", "evacuated_rids"):
        if k in out:
            row[k] = out[k]
    if "rescales" in out:
        row["rescales"] = [r["new_mesh_shape"] for r in out["rescales"]]
    return row


def _ep_logits_err() -> float:
    """Max |logits| gap between a packed dbrx prefill on the EP mesh and
    the same params single-device: 8x8 tokens clears the EP divisibility
    gate, so this is the shard_map path against the dense loop."""
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core import hal
    from repro.core.dispatch import KernelDispatcher
    from repro.launch.serve import parse_mesh
    from repro.models.model import build_model
    from repro.optim.compression import compress_model_params
    from repro.parallel.ctx import ParallelContext

    cfg = configs.get_smoke("dbrx-132b")
    dispatcher = KernelDispatcher(hal.get_target("tpu-v5e"))
    ref = build_model(cfg, ParallelContext(mesh=None), dispatcher=dispatcher)
    meshed = build_model(cfg, parse_mesh(EP_MESH), dispatcher=dispatcher)
    params = compress_model_params(ref.init(jax.random.PRNGKey(0)),
                                   "int4_palette")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(8, 8)), jnp.int32)}
    before = ROUTE_COUNTS["ep"]
    _, lg_mesh = meshed.prefill(params, batch)
    assert ROUTE_COUNTS["ep"] > before, "prefill never took the EP path"
    _, lg_ref = ref.prefill(params, batch)
    return float(jnp.max(jnp.abs(lg_mesh - lg_ref)))


def bench(fast: bool):
    failures, rows = [], []
    arch, batch, plen, gen = "tinyllama-1.1b", 4, 12, 8
    legs = PARITY_LEGS[:1] if fast else PARITY_LEGS

    for schedule, form in legs:
        tag = f"{schedule}/{form}"
        single = serve_run(_argv(arch, schedule, form, batch, plen, gen))
        mesh = serve_run(_argv(arch, schedule, form, batch, plen, gen,
                               "--mesh-shape", MESH))
        rows += [_row(f"{tag} single", single), _row(f"{tag} mesh", mesh)]
        if not np.array_equal(single["tokens"], mesh["tokens"]):
            failures.append(f"{tag}: mesh {MESH} streams diverge from "
                            "single-device")
        if single["n_dispatches"] != mesh["n_dispatches"]:
            failures.append(
                f"{tag}: dispatch count {mesh['n_dispatches']} on mesh vs "
                f"{single['n_dispatches']} single — the per-host ledger "
                "must be placement-invariant")
        fleet = mesh["fleet_floor_s"]
        want = mesh["n_hosts"] * mesh["per_host_floor_s"]
        if abs(fleet - want) > 1e-12:
            failures.append(f"{tag}: fleet floor {fleet} != n_hosts x "
                            f"per-host floor {want}")

    # --- EP routing proof: packed dbrx banks through shard_map ----------
    ep_args = ("dbrx-132b", "continuous", "int4_palette", 8, 8, 4)
    single = serve_run(_argv(*ep_args))
    ROUTE_COUNTS["ep"] = ROUTE_COUNTS["dense"] = 0
    mesh = serve_run(_argv(*ep_args, "--mesh-shape", EP_MESH))
    ep_traces = ROUTE_COUNTS["ep"]
    agree = float(np.mean(single["tokens"] == mesh["tokens"]))
    rows += [_row("ep/dbrx single", single),
             dict(_row("ep/dbrx mesh", mesh), ep_traces=ep_traces,
                  dense_traces=ROUTE_COUNTS["dense"],
                  token_agreement=round(agree, 3))]
    if ep_traces < 1:
        failures.append(f"dbrx on mesh {EP_MESH}: packed MoE never traced "
                        "the shard_map EP path (ROUTE_COUNTS['ep'] == 0)")
    err = _ep_logits_err()
    rows.append({"tag": "ep/dbrx prefill logits", "max_abs_err": err})
    if not err < 1e-4:
        failures.append(f"dbrx EP prefill logits off by {err} vs "
                        "single-device (want < 1e-4)")

    # --- evacuation round-trip -----------------------------------------
    evac_legs = [("continuous", "vanish", 1, 3)]
    if not fast:
        evac_legs.append(("slo", "hang", 2, 2))
    ref = serve_run(_argv(arch, "continuous", "fp16", batch, plen, gen))
    for schedule, kind, host, at_step in evac_legs:
        if schedule != "continuous":
            ref = serve_run(_argv(arch, schedule, "fp16", batch, plen, gen))
        out = serve_run(_argv(arch, schedule, "fp16", batch, plen, gen,
                              "--mesh-shape", MESH,
                              "--fail-host", str(host),
                              "--fail-at-step", str(at_step),
                              "--fail-kind", kind))
        tag = f"evac/{schedule}/{kind}"
        rows.append(_row(tag, out))
        if not np.array_equal(ref["tokens"], out["tokens"]):
            failures.append(f"{tag}: evacuated streams diverge from the "
                            "uninterrupted run")
        if out["restarts"] != 1 or len(out["rescales"]) != 1:
            failures.append(f"{tag}: expected exactly 1 restart + 1 "
                            f"rescale, got {out['restarts']} / "
                            f"{len(out['rescales'])}")
        if out["n_hosts"] != 3:
            failures.append(f"{tag}: survivor fleet has {out['n_hosts']} "
                            "hosts, want 3")
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="one parity pair + EP + vanish evacuation (CI)")
    ap.add_argument("--out", default="BENCH_shard.json")
    args = ap.parse_args(argv)
    rows, failures = bench(args.fast)
    emit_report({"mesh": MESH, "ep_mesh": EP_MESH, "fast": args.fast,
                 "rows": rows, "failures": failures}, args.out)
    return gate(failures)


if __name__ == "__main__":
    sys.exit(main())
