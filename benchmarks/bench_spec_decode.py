"""Speculative-decoding economics bench (paper §9.3/§9.4 on the serving stack).

    PYTHONPATH=src python -m benchmarks.bench_spec_decode [--fast]

The paper's decode regime is floor-bound: every dispatch pays the fixed t0
before any useful work, so per-token cost ~ floor / (tokens per dispatch).
`SLOSchedule` pipelines one fused step — one floor — per token;
`SpeculativeSchedule` spends two floors per window (draft + fused
verify/accept) for up to `draft_depth + 1` emitted tokens. This bench
serves the same request set through both at decode-lane counts {4, 16} and
draft depths {2, 4} and compares the **§9-modeled per-token cost**:

    (total floor charged by the stream ledger
       + model-forwards x costmodel roofline step estimate) / tokens

The floor term is read off the `DispatchRecord` ledger — every draft,
verify, prefill and admission dispatch of BOTH models charges the target's
`dispatch_floor_s`, so the drafter's overhead (its prefills, its extra
window steps, the double verify compute) counts *against* speculation; the
work term prices each model forward at the HAL target's roofline
(`max(flops/peak, bytes/bw)`). Host-CPU wall clocks are reported alongside
but never gated: on this correctness-path host the fused verify's K+1 real
forwards dominate the microseconds-level dispatch overhead, which inverts
the floor-bound economics the paper measures (DESIGN.md evidence marks —
walls here are not accelerator performance).

Two experiments share the harness:

  * **self-draft ceiling** (uniform-random prompts): the gated baseline rows
    draft with the target itself — the agreement ceiling, and the only
    aligned drafter when the TARGET's weights are random-init. A random-init
    `shrink` row rides along, reported-only: its acceptance ~0 is the
    placebo the distilled section exists to beat.
  * **distilled shrink drafter** (motif prompts — the §9 headline): a real
    two-model path. The teacher (target arch) trains on the synthetic motif
    corpus, `draft_of(cfg)` distills against its logits
    (`launch.distill`, run inline or loaded from `--distill-dir`), and the
    serve traffic is drawn from the same motif distribution
    (`prompt_batch`, a held-out stream). Rows cover draft depth >= 2 at 1
    and 2 tree branches. GATED at 16 lanes: acceptance_rate >= 0.4 with
    proposed > 0 (an empty window ledger cannot fake it),
    speedup_vs_slo_x > 1.0, bit-identical greedy streams, every draft +
    verify dispatch floor-charged — speculation must WIN without
    self-drafting, or this bench exits nonzero.

Writes `BENCH_spec.json` (repo root by default). Exits nonzero unless, at
16 lanes, speculative decode is strictly cheaper per token than
`SLOSchedule` at draft depth 2 or 4 with bit-identical greedy streams and
every draft + verify dispatch visible as a floor-charged record — and the
distilled-shrink gate above holds.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import AsyncExecutionStream, ProgramCache
from repro.launch.scheduler import SLOSchedule
from repro.launch.speculative import Drafter, SpeculativeSchedule

from benchmarks._common import (build_smoke_model, emit_report, gate,
                                hetero_lens, interleaved_best_of,
                                make_motif_requests, make_requests,
                                modeled_step_s)

LANES = (4, 16)
DEPTHS = (2, 4)
#: (draft_depth, draft_branches) rows of the distilled-shrink experiment;
#: depth >= 2 per the gate, branches 2 exercises tree verification
DISTILLED_CONFIGS = ((2, 1), (2, 2), (4, 2))
#: the §9 break-even bar for the distilled drafter (ISSUE: speculation must
#: win without self-drafting)
MIN_SHRINK_ACCEPTANCE = 0.4


def _ledger_round(sched, cfg, lens, gen):
    """One fresh round on a fresh scheduler: the per-round dispatch ledger
    (floor charges and model-forward counts are identical every round)."""
    results = sched.run(make_requests(cfg, lens, gen, rid0=0))
    toks = {r.rid: r.tokens for r in results}
    return toks, sched.stats(len(lens))


def bench(arch: str, *, prompt_len: int, gen: int, target_name: str,
          reps: int = 3, seed: int = 0) -> dict:
    cfg, target, model, params = build_smoke_model(arch, target_name, seed)
    floor = target.dispatch_floor_s
    drafter_self = Drafter.self_draft(model, params, cfg)
    drafter_shrink = Drafter.shrink(cfg, dispatcher=model.dispatcher)

    def make_sched(kind, n_slots, max_len, **kw):
        stream = AsyncExecutionStream(ProgramCache(), target=target)
        if kind == "slo":
            return SLOSchedule(model, params, cfg, n_slots=n_slots,
                               max_len=max_len, stream=stream,
                               sampling="greedy", seed=seed)
        return SpeculativeSchedule(model, params, cfg, n_slots=n_slots,
                                   max_len=max_len, stream=stream,
                                   sampling="greedy", seed=seed, **kw)

    curve = []
    for n_slots in LANES:
        lens = hetero_lens(prompt_len, n_slots)
        max_len = max(lens) + gen
        n_tokens = gen * n_slots
        w_step = modeled_step_s(cfg, target, n_slots, max_len)
        w_draft = modeled_step_s(drafter_shrink.cfg, target, n_slots, max_len)

        # -- the §9 ledger, one fresh round per schedule (the same warm
        # scheduler then serves the timed wall rounds: stats are
        # snapshotted here, so no program compiles twice) ------------------
        slo = make_sched("slo", n_slots, max_len)
        slo_toks, slo_stats = _ledger_round(slo, cfg, lens, gen)
        slo_steps = sum(1 for r in slo.stream.records
                        if r.key in slo._decode_keys)
        slo_modeled = (slo_stats["floor_s"] + slo_steps * w_step) / n_tokens

        row = {
            "n_slots": n_slots,
            "n_requests": n_slots,
            "prompt_lens": lens,
            "slo": {
                "n_dispatches": slo_stats["n_dispatches"],
                "floor_s": slo_stats["floor_s"],
                "decode_steps": slo_steps,
                "modeled_s_per_token": slo_modeled,
                "tokens_per_dispatch":
                    n_tokens / max(slo_stats["n_dispatches"], 1),
            },
            "spec": {},
        }
        scheds = {"slo": slo}
        for depth in DEPTHS:
            spec = make_sched("spec", n_slots, max_len,
                              draft_depth=depth, drafter=drafter_self)
            spec_toks, st = _ledger_round(spec, cfg, lens, gen)
            recs = spec.stream.records
            window_recs = [r for r in recs
                           if r.key in spec._draft_keys
                           or r.key in spec._verify_keys]
            ledger_ok = (
                st["verify_dispatches"] == st["n_windows"]
                and st["draft_dispatches"] >= 1
                and all(r.floor_s == floor > 0.0 for r in window_recs))
            # self-draft: the drafter is the target, so its steps price at
            # the target's roofline step (the shrink row uses w_draft)
            work = (st["verify_steps"] + st["draft_steps"]
                    + 2 * st["catchup_steps"]) * w_step
            modeled = (st["floor_s"] + work) / n_tokens
            parity = all(np.array_equal(spec_toks[r], slo_toks[r])
                         for r in slo_toks)
            row["spec"][str(depth)] = {
                "draft": "self",
                "n_dispatches": st["n_dispatches"],
                "floor_s": st["floor_s"],
                "n_windows": st["n_windows"],
                "draft_dispatches": st["draft_dispatches"],
                "verify_dispatches": st["verify_dispatches"],
                "acceptance_rate": st["acceptance_rate"],
                "tokens_per_window_dispatch":
                    st["tokens_per_window_dispatch"],
                "modeled_s_per_token": modeled,
                "speedup_vs_slo_x": slo_modeled / modeled,
                "token_parity": bool(parity),
                "ledger_ok": bool(ledger_ok),
            }
            scheds[f"spec{depth}"] = spec
            print(f"lanes={n_slots:3d} depth={depth}: modeled "
                  f"{modeled*1e6:8.1f} us/tok vs slo "
                  f"{slo_modeled*1e6:8.1f} us/tok "
                  f"({slo_modeled/modeled:.2f}x), acceptance "
                  f"{st['acceptance_rate']:.2f}, "
                  f"{st['tokens_per_window_dispatch']:.2f} tok/window-"
                  f"dispatch, parity={parity}")

        # -- host walls, warm + interleaved (reported, never gated) ---------
        best, toks = interleaved_best_of(scheds, cfg, lens, gen, reps)
        for name, wall in best.items():
            key = "slo" if name == "slo" else ("spec", name[len("spec"):])
            entry = row["slo"] if name == "slo" else row["spec"][key[1]]
            entry["host_wall_s_per_token"] = wall / n_tokens
        for name in scheds:
            if name == "slo":
                continue
            if not all(np.array_equal(toks[name][r], toks["slo"][r])
                       for r in toks["slo"]):
                row["spec"][name[len("spec"):]]["token_parity"] = False

        # -- the true two-model path (reported: acceptance is the story) ----
        shr = make_sched("spec", n_slots, max_len, draft_depth=DEPTHS[0],
                         drafter=drafter_shrink)
        shr_toks, shr_stats = _ledger_round(shr, cfg, lens, gen)
        work = (shr_stats["verify_steps"] * w_step
                + shr_stats["draft_steps"] * w_draft
                + shr_stats["catchup_steps"] * (w_step + w_draft))
        row["spec_shrink"] = {
            "draft": "shrink",
            "drafter": "random-init (the placebo the distilled section "
                       "beats)",
            "draft_depth": DEPTHS[0],
            "acceptance_rate": shr_stats["acceptance_rate"],
            "modeled_s_per_token":
                (shr_stats["floor_s"] + work) / n_tokens,
            "token_parity": bool(all(
                np.array_equal(shr_toks[r], slo_toks[r])
                for r in slo_toks)),
        }
        curve.append(row)

    return {
        "arch": cfg.name,
        "target": target.name,
        "dispatch_floor_s": floor,
        "gen": gen,
        "lanes": list(LANES),
        "depths": list(DEPTHS),
        "reps": reps,
        "modeled_metric": "(ledger floor charges + model-forwards x "
                          "roofline step) / tokens; host walls reported, "
                          "not gated (correctness-path CPU)",
        "curve": curve,
        "paper_ref": "§9.3 dispatch floor + §9.4 amortization: more tokens "
                     "per dispatch is the only decode lever",
    }


def bench_distilled(arch: str, *, prompt_len: int, gen: int,
                    target_name: str, distill_dir: str | None = None,
                    fast: bool = False, seed: int = 0) -> dict:
    """The gated shrink-drafter experiment: a distilled `draft_of(cfg)`
    student speculating for its trained teacher on held-out motif prompts.
    With `distill_dir` the teacher/student load from a `launch.distill`
    checkpoint directory (the CI round-trip); otherwise the pipeline runs
    inline."""
    from repro.launch import distill as distill_mod

    cfg, target, model, _ = build_smoke_model(arch, target_name, seed)
    floor = target.dispatch_floor_s
    if distill_dir:
        teacher_dir = os.path.join(distill_dir, "teacher")
        student_dir = os.path.join(distill_dir, "student")
        _, tparams = distill_mod.load_teacher(cfg, teacher_dir)
        drafter = Drafter.shrink(cfg, dispatcher=model.dispatcher,
                                 ckpt=student_dir)
        from repro.checkpoint.checkpoint import CheckpointManager
        smeta = CheckpointManager(student_dir).metadata() or {}
        agreement = smeta.get("agreement_top1")
        source = distill_dir
    else:
        knobs = dict(distill_mod.DEFAULTS)
        if fast:
            knobs.update(teacher_steps=60, steps=80, seq=48)
        bundle = distill_mod.distill_pipeline(cfg, **knobs, seed=seed,
                                              eval_steps=8, log_every=50)
        tparams = bundle["teacher_params"]
        drafter = Drafter.shrink(cfg, dispatcher=model.dispatcher,
                                 params=bundle["student_params"])
        agreement = bundle["agreement"]
        source = "inline distill_pipeline"
    assert drafter.trained, "the distilled drafter must not be random-init"

    curve = []
    for n_slots in LANES:
        lens = hetero_lens(prompt_len, n_slots)
        max_len = max(lens) + gen
        n_tokens = gen * n_slots
        w_step = modeled_step_s(cfg, target, n_slots, max_len)
        w_draft = modeled_step_s(drafter.cfg, target, n_slots, max_len)

        def reqs():
            # held-out motif prompts: the traffic the teacher learned
            return make_motif_requests(cfg, lens, gen, rid0=0,
                                       seed=seed + 11)

        slo = SLOSchedule(model, tparams, cfg, n_slots=n_slots,
                          max_len=max_len, sampling="greedy", seed=seed,
                          stream=AsyncExecutionStream(ProgramCache(),
                                                      target=target))
        slo_toks = {r.rid: r.tokens for r in slo.run(reqs())}
        slo_stats = slo.stats(n_slots)
        slo_steps = sum(1 for r in slo.stream.records
                        if r.key in slo._decode_keys)
        slo_modeled = (slo_stats["floor_s"] + slo_steps * w_step) / n_tokens

        row = {"n_slots": n_slots, "prompt_lens": lens,
               "slo": {"floor_s": slo_stats["floor_s"],
                       "decode_steps": slo_steps,
                       "modeled_s_per_token": slo_modeled},
               "spec": {}}
        for depth, branches in DISTILLED_CONFIGS:
            spec = SpeculativeSchedule(
                model, tparams, cfg, n_slots=n_slots, max_len=max_len,
                sampling="greedy", seed=seed, draft_depth=depth,
                draft_branches=branches, drafter=drafter,
                stream=AsyncExecutionStream(ProgramCache(), target=target))
            spec_toks = {r.rid: r.tokens for r in spec.run(reqs())}
            st = spec.stats(n_slots)
            window_recs = [r for r in spec.stream.records
                           if r.key in spec._draft_keys
                           or r.key in spec._verify_keys]
            ledger_ok = (
                st["verify_dispatches"] == st["n_windows"]
                and st["draft_dispatches"] >= 1
                and all(r.floor_s == floor > 0.0 for r in window_recs))
            work = (st["verify_steps"] * w_step
                    + st["draft_steps"] * w_draft
                    + st["catchup_steps"] * (w_step + w_draft))
            modeled = (st["floor_s"] + work) / n_tokens
            parity = all(np.array_equal(spec_toks[r], slo_toks[r])
                         for r in slo_toks)
            key = f"depth{depth}_br{branches}"
            row["spec"][key] = {
                "draft": "shrink",
                "drafter": "distilled",
                "draft_depth": depth,
                "draft_branches": branches,
                "proposed": st["proposed"],
                "accepted": st["accepted"],
                "acceptance_rate": st["acceptance_rate"],
                "n_windows": st["n_windows"],
                "draft_dispatches": st["draft_dispatches"],
                "verify_dispatches": st["verify_dispatches"],
                "tokens_per_window_dispatch":
                    st["tokens_per_window_dispatch"],
                "modeled_s_per_token": modeled,
                "speedup_vs_slo_x": slo_modeled / modeled,
                "token_parity": bool(parity),
                "ledger_ok": bool(ledger_ok),
            }
            print(f"[distilled] lanes={n_slots:3d} depth={depth} "
                  f"branches={branches}: acceptance "
                  f"{st['acceptance_rate']:.2f} "
                  f"({st['accepted']}/{st['proposed']}), modeled "
                  f"{modeled*1e6:8.1f} us/tok vs slo "
                  f"{slo_modeled*1e6:8.1f} us/tok "
                  f"({slo_modeled/modeled:.2f}x), parity={parity}")
        curve.append(row)

    return {"source": source,
            "rollout_agreement_top1":
                None if agreement is None else float(agreement),
            "configs": [list(c) for c in DISTILLED_CONFIGS],
            "min_acceptance_gate": MIN_SHRINK_ACCEPTANCE,
            "prompts": "held-out motif stream (SyntheticLM.prompt_batch)",
            "curve": curve}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: short prompts/gen")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=15)
    ap.add_argument("--reps", type=int, default=3,
                    help="timed warm rounds per (schedule, lanes), "
                         "interleaved; best wall is reported")
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS))
    ap.add_argument("--distill-dir", default="",
                    help="a `launch.distill --ckpt-dir` directory (teacher/ "
                         "and student/ subdirs) to serve the gated shrink "
                         "rows from; without it the distillation pipeline "
                         "runs inline")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_spec.json"))
    args = ap.parse_args(argv)

    if args.fast:
        args.prompt_len, args.gen, args.reps = 12, 6, 2

    report = bench(args.arch, prompt_len=args.prompt_len, gen=args.gen,
                   target_name=args.target, reps=args.reps)
    report["distilled_shrink"] = bench_distilled(
        args.arch, prompt_len=args.prompt_len, gen=args.gen,
        target_name=args.target, distill_dir=args.distill_dir or None,
        fast=args.fast)
    emit_report(report, args.out)

    failures = []
    for row in report["curve"]:
        wins = []
        for depth, cell in row["spec"].items():
            if not cell["token_parity"]:
                failures.append(
                    f"lanes={row['n_slots']} depth={depth}: speculative "
                    f"greedy tokens diverged from SLOSchedule")
            if not cell["ledger_ok"]:
                failures.append(
                    f"lanes={row['n_slots']} depth={depth}: draft/verify "
                    f"dispatches missing from the floor ledger")
            if cell["token_parity"] and cell["modeled_s_per_token"] \
                    < row["slo"]["modeled_s_per_token"]:
                wins.append(depth)
        if row["n_slots"] == max(LANES) and not wins:
            failures.append(
                f"lanes={row['n_slots']}: speculative decode is not "
                f"strictly cheaper per token than SLOSchedule at any "
                f"draft depth in {list(report['depths'])}")

    # -- the distilled-shrink gate: speculation must win WITHOUT
    # self-drafting (acceptance 0.0 or speedup <= 1.0 is the regression
    # this bench exists to catch) --------------------------------------
    for row in report["distilled_shrink"]["curve"]:
        if row["n_slots"] != max(LANES):
            continue
        for key, cell in row["spec"].items():
            where = f"distilled shrink lanes={row['n_slots']} {key}"
            if cell["proposed"] <= 0:
                failures.append(f"{where}: no drafts were ever proposed "
                                f"(zero-window run proves nothing)")
            if cell["acceptance_rate"] < MIN_SHRINK_ACCEPTANCE:
                failures.append(
                    f"{where}: acceptance {cell['acceptance_rate']:.3f} < "
                    f"{MIN_SHRINK_ACCEPTANCE} — the drafter does not track "
                    f"the target (re-distill; random-init serves at ~0)")
            if not cell["token_parity"]:
                failures.append(f"{where}: greedy tokens diverged from "
                                f"SLOSchedule")
            if not cell["ledger_ok"]:
                failures.append(f"{where}: draft/verify dispatches missing "
                                f"from the floor ledger")
        # speculation must WIN at some gated depth >= 2: the floor
        # amortizes across lanes in both schedules, so shallow windows
        # only break even — the deeper configs are where two floors buy
        # clearly more than `1 + drafter-overhead` tokens
        best_key, best = max(row["spec"].items(),
                             key=lambda kv: kv[1]["speedup_vs_slo_x"])
        report["distilled_shrink"]["gated_row"] = dict(best, config=best_key)
        if best["speedup_vs_slo_x"] <= 1.0:
            failures.append(
                f"distilled shrink lanes={row['n_slots']}: best modeled "
                f"speedup {best['speedup_vs_slo_x']:.3f}x ({best_key}) <= "
                f"1.0 — two floors per window are not buying > 1 token "
                f"over SLOSchedule at any depth/branches in "
                f"{report['distilled_shrink']['configs']}")
        emit_report(report, args.out)   # gated_row now resolved
    return gate(failures)


if __name__ == "__main__":
    sys.exit(main())
