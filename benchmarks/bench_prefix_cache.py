"""§9 prefix-cache bench: resident prefixes save whole dispatch floors.

    PYTHONPATH=src python -m benchmarks.bench_prefix_cache [--fast]

The paper's floor model charges every engine command a fixed ~t0 regardless
of useful work, so the cheapest prefill is the one never dispatched.
Chat-shaped traffic re-prefills identical prefixes from token 0 on every
admission; the block-paged KV pool (`launch/kv_pool.py`) makes the shared
prefix resident instead. This bench serves a shared-system-prompt workload
(every request = one common prefix + a unique tail) through
`ContinuousSchedule` twice — pool off (the continuous baseline) and pool on
— over one shared `ExecutionStream` ledger each, and gates on the ISSUE 6
acceptance criteria:

  * dispatches-per-generated-token with the pool is *strictly below* the
    continuous baseline (the first request pays prefill + pool insert +
    lane write; every later request admits with ONE gather dispatch instead
    of the prefill + lane-write pair);
  * greedy token streams are *bit-identical* between prefix-hit and
    cold-prefill admissions (sampling is keyed per (rid, position) and the
    pooled blocks are bitwise copies of prefill state, so a hit must not
    change a single token).

Wall times are host-CPU correctness-path costs (DESIGN.md §7 evidence
marks); the floor-derived dispatch columns are the reproduction target.
Writes `BENCH_prefix.json` (repo root) and exits nonzero on gate failure.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import ExecutionStream, ProgramCache
from repro.launch.scheduler import ContinuousSchedule, Request

from benchmarks._common import build_smoke_model, emit_report, gate


def shared_prefix_requests(cfg, *, n_requests: int, shared_len: int,
                           tail_len: int, gen: int, seed: int):
    """One common system prompt + a unique per-request tail: the workload
    where today's serving stack re-prefills `shared_len` tokens n times."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, size=(shared_len,)).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, cfg.vocab,
                            size=(1 + (i % tail_len),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=gen))
    return reqs


def _serve(model, params, cfg, target, reqs, *, n_slots, max_len,
           prefix: bool, seed: int) -> tuple[dict, dict]:
    kw = dict(prefix_cache=True, prefix_blocks=max(64, 4 * len(reqs)),
              prefix_block_size=8) if prefix else {}
    stream = ExecutionStream(ProgramCache(), target=target)
    sched = ContinuousSchedule(model, params, cfg, n_slots=n_slots,
                               max_len=max_len, stream=stream,
                               sampling="greedy", seed=seed, **kw)
    results = sched.run(reqs)
    assert len(results) == len(reqs)
    return sched.stats(len(reqs)), {r.rid: r.tokens for r in results}


def bench(arch: str, *, n_requests: int, shared_len: int, tail_len: int,
          gen: int, target_name: str, seed: int = 0) -> dict:
    cfg, target, model, params = build_smoke_model(arch, target_name, seed)
    reqs = shared_prefix_requests(cfg, n_requests=n_requests,
                                  shared_len=shared_len, tail_len=tail_len,
                                  gen=gen, seed=seed)
    max_len = max(r.prompt.size for r in reqs) + gen
    n_slots = min(4, n_requests)
    total_tokens = gen * n_requests

    sides = {}
    toks = {}
    for name, prefix in (("continuous_baseline", False), ("prefix_pool", True)):
        stats, toks[name] = _serve(
            model, params, cfg, target,
            shared_prefix_requests(cfg, n_requests=n_requests,
                                   shared_len=shared_len, tail_len=tail_len,
                                   gen=gen, seed=seed),
            n_slots=n_slots, max_len=max_len, prefix=prefix, seed=seed)
        side = {
            "n_dispatches": stats["n_dispatches"],
            "dispatches_per_token": stats["n_dispatches"] / total_tokens,
            "floor_s": stats["floor_s"],
            "floor_per_token_s": stats["floor_s"] / total_tokens,
        }
        if prefix:
            side["prefix_cache"] = stats["prefix_cache"]
        sides[name] = side
        note = ""
        if prefix:
            pc = stats["prefix_cache"]
            note = (f" | {pc['hits']} hits, {pc['hit_tokens']} prefill "
                    f"tokens skipped")
        print(f"{name:20s}: {side['n_dispatches']:4d} dispatches, "
              f"{side['dispatches_per_token']:.3f} per token{note}")

    bit_identical = set(toks["continuous_baseline"]) == set(
        toks["prefix_pool"]) and all(
        np.array_equal(toks["continuous_baseline"][rid],
                       toks["prefix_pool"][rid])
        for rid in toks["continuous_baseline"])
    return {
        "arch": cfg.name,
        "target": target.name,
        "dispatch_floor_s": target.dispatch_floor_s,
        "n_requests": n_requests,
        "shared_prefix_len": shared_len,
        "gen": gen,
        "n_slots": n_slots,
        "sides": sides,
        "dispatches_per_token": {
            k: v["dispatches_per_token"] for k, v in sides.items()},
        "dispatch_floor_saved_s": (
            sides["continuous_baseline"]["floor_s"]
            - sides["prefix_pool"]["floor_s"]),
        "streams_bit_identical": bool(bit_identical),
        "strictly_below": (sides["prefix_pool"]["dispatches_per_token"]
                           < sides["continuous_baseline"]
                           ["dispatches_per_token"]),
        "paper_ref": "§9: every dispatch pays the fixed floor t0; a prefix "
                     "hit saves the whole prefill dispatch",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: fewer/shorter requests")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--shared-len", type=int, default=32,
                    help="shared system-prompt length (bucket-aligned so "
                         "the chain anchors)")
    ap.add_argument("--tail-len", type=int, default=8,
                    help="unique per-request tail lengths cycle 1..tail-len")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS))
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_prefix.json"))
    args = ap.parse_args(argv)

    if args.fast:
        args.requests, args.shared_len, args.gen = 6, 16, 4

    report = bench(args.arch, n_requests=args.requests,
                   shared_len=args.shared_len, tail_len=args.tail_len,
                   gen=args.gen, target_name=args.target)
    base = report["dispatches_per_token"]["continuous_baseline"]
    pool = report["dispatches_per_token"]["prefix_pool"]
    print(f"dispatches/token {base:.3f} -> {pool:.3f} "
          f"({base / pool:.2f}x fewer), floor saved "
          f"{report['dispatch_floor_saved_s'] * 1e3:.2f} ms")
    emit_report(report, args.out)
    failures = []
    if not report["strictly_below"]:
        failures.append("prefix-pool dispatches-per-token is not strictly "
                        "below the continuous baseline")
    if not report["streams_bit_identical"]:
        failures.append("prefix-hit token streams diverge from cold-prefill "
                        "admissions")
    return gate(failures)


if __name__ == "__main__":
    sys.exit(main())
