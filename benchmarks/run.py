"""Benchmark harness: one function per paper table.

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run --only t3_1,t9_2

Output: `name,us_per_call,derived` CSV rows on stdout ('#' lines are
commentary). `us_per_call` is a wall measurement on THIS host (CPU) where
one exists, else empty; `derived` is the paper-comparable number (model
value, ratio, or reproduction) with its meaning in the name.

Evidence marks (DESIGN.md §7): rows are measured (host wall time), derived
(computed from compiled artifacts or the oracle), or modeled (roofline /
energy model for a target we cannot run). Host CPU wall-times are never
presented as TPU/ANE performance — the *shape* of each curve is the
reproduction target (e.g. fusion amortization flatness), not its scale.

Everything also lands in reports/bench.json for EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (analytic, capability, compression as cp, costmodel,
                        dispatch, hal, numerics as nu, roofline,
                        segmenter as sg)
from repro import configs

REPORT = {}
ROWS = []


def row(name: str, us_per_call: float | None, derived):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{'' if us_per_call is None else f'{us_per_call:.2f}'},{derived}")


def _time(fn, n=50, warmup=3) -> float:
    """Median-of-3 wall time per call, in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    outs = []
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn()
        jax.block_until_ready(out)
        outs.append((time.perf_counter() - t0) / n * 1e6)
    return float(np.median(outs))


# ---------------------------------------------------------------------------
def t2_3_dispatch_budget():
    """Table 2.3 / §9.3: the per-dispatch floor and its stage split.

    The paper isolates ~0.23 ms on the M1 (98% dispatch overhead). We
    isolate this host's jit dispatch floor the same way: a tiny op in a hot
    loop, then split python-dispatch vs AOT-call overhead."""
    print("# Table 2.3 — per-dispatch budget (host-measured analog)")
    stats = dispatch.measure_dispatch_floor(n=300)
    row("t2_3.per_call_floor", stats["per_call_s"] * 1e6, "measured")
    row("t2_3.aot_call_floor", stats["aot_call_s"] * 1e6, "measured")
    row("t2_3.python_overhead", stats["python_overhead_s"] * 1e6, "measured")
    # paper's claim to reproduce: tiny-op wall time is overhead-dominated
    x = jnp.ones((8, 8))
    f_tiny = jax.jit(lambda a: (a * 1.0).sum()).lower(x).compile()
    t_tiny = _time(lambda: f_tiny(x))
    big = jnp.ones((512, 512))
    f_big = jax.jit(lambda a: a @ a).lower(big).compile()
    t_big = _time(lambda: f_big(big), n=20)
    row("t2_3.overhead_fraction_tiny_op",
        t_tiny, f"derived:{min(0.999, stats['aot_call_s']*1e6/max(t_tiny,1e-9)):.2f}")
    row("t2_3.big_op_over_floor_ratio", t_big, f"derived:{t_big/max(t_tiny,1e-9):.1f}x")
    REPORT["t2_3"] = {**stats, "tiny_us": t_tiny, "big_us": t_big}


def t3_1_survivor_sweep():
    """Table 3.1: the cancellation-threshold survivor sweep."""
    print("# Table 3.1 — survivor sweep (oracle reproduction; paper M1 measured)")
    mags = [1024, 3000, 4090, 4096, 8000, 16000, 30000]
    paper = [16, 16, 16, 4, 4, 4, 4]
    ours = {tie: nu.survivor_sweep(mags, tie=tie) for tie in ("even", "away")}
    for m, p, e, a in zip(mags, paper, ours["even"], ours["away"]):
        row(f"t3_1.survivors@{m}", None, f"paper:{p} ours_even:{e} ours_away:{a}")
    floor_ok = all(v == 4 for v in ours["even"][3:]) and all(v == 4 for v in ours["away"][3:])
    row("t3_1.hard_floor_of_4_at_4096+", None, f"derived:{'REPRODUCED' if floor_ok else 'MISS'}")
    ws = nu.wide_reduce(np.array([4096.0] + [1.0] * 1024))
    row("t3_1.worked_sum_4096+1024ones", None,
        f"paper:5116 ours:{ws:.0f} naive_fp16:4096 exact:5120")
    REPORT["t3_1"] = {"mags": mags, "paper": paper, **ours, "worked_sum": ws}


def t3_3_numeric_constants():
    """Table 3.3: fp16 numeric constants + activation-table errors."""
    print("# Table 3.3 — numeric constants (oracle vs paper)")
    checks = [
        ("fp16_max", 65504.0, hal.FP16_MAX),
        ("mac_output_ceiling", 32768.0, hal.ACCUM_OUT_CEILING),
        ("width_slice_gain", 16.0, hal.WIDTH_SLICE_GAIN),
        ("width_slice_finite_fill", 4094.0, hal.WIDTH_SLICE_FINITE_FILL),
        ("exp_overflow_input", 11.094, hal.EXP_OVERFLOW_INPUT),
        ("lut_knots", 33, hal.LUT_KNOTS),
    ]
    for name, paper, ours in checks:
        row(f"t3_3.{name}", None, f"paper:{paper} ours:{ours}")
    for name, bound in [("sigmoid", 0.0034), ("tanh", 0.0017), ("gelu", 0.0059)]:
        err = nu.lut_worst_error(nu.build_lut(name))
        row(f"t3_3.lut_{name}_worst_err", None,
            f"paper:{bound} ours:{err:.5f} ({'OK' if err <= bound else 'OVER'})")
    REPORT["t3_3"] = "see rows"


def t7_1_compression_streams():
    """Tables 7.1/7.4: stream-vs-fold per form per generation + speedups."""
    print("# Table 7.1/7.4 — compressed-weight streaming (gates + byte ratios)")
    rng = np.random.default_rng(0)
    w = rng.normal(size=(4096, 1024)).astype(np.float32)
    # Calibration: one measured anchor — the paper's int4 2.37x on the M1 —
    # fixes the activation-byte share of its conv-stack probe at ~0.30x the
    # dense weight bytes ((D+a)/(D/4+a)=2.37 -> a=0.2975D). The model then
    # PREDICTS the other formats' speedups; comparing those predictions to
    # their independent measurements is the reproduction.
    act = 0.2975 * (w.size * 2.0)
    paper_speedup = {("int4_palette", "ane-m1"): 2.37,
                     ("sparse", "ane-m1"): 1.6,
                     ("int8", "ane-m1"): 1.0,
                     ("int8", "ane-m2"): 1.0 / 0.52}
    for form in (hal.WeightForm.INT4_PALETTE, hal.WeightForm.SPARSE,
                 hal.WeightForm.INT8, hal.WeightForm.BLOCKWISE):
        p = cp.encode(form, w)
        for target in (hal.ANE_M1, hal.ANE_M2, hal.ANE_M5, hal.TPU_V5E):
            streams = target.streams(form)
            sp = cp.stream_speedup(p, target, act_bytes=act)
            key = (form.value, target.name)
            ref = f" paper:{paper_speedup[key]:.2f}" if key in paper_speedup else ""
            row(f"t7_1.{form.value}.{target.name}", None,
                f"{'stream' if streams else 'fold'} predicted_speedup:{sp:.2f}{ref}")
    REPORT["t7_1"] = "see rows"


def t7_3_kernel_streaming():
    """The TPU transcription: in-kernel dequant bytes; correctness is covered
    in tests — here, the HBM byte ratios of the real packed layers."""
    print("# Table 7.3 — kernel-level streaming byte ratios (derived)")
    from repro.kernels.palette.ops import PaletteLinear
    from repro.kernels.sparse.ops import SparseLinear
    rng = np.random.default_rng(0)
    w = rng.normal(size=(1024, 512)).astype(np.float32)
    pal = PaletteLinear.pack(w)
    spr = SparseLinear.pack(w)
    row("t7_3.palette_hbm_ratio", None,
        f"derived:{pal.dense_bytes()/pal.hbm_bytes():.2f}x fewer bytes")
    row("t7_3.sparse_hbm_ratio", None,
        f"derived:{spr.dense_bytes()/spr.hbm_bytes():.2f}x fewer bytes")
    # wall time in interpret mode is NOT kernel perf; reported as the
    # correctness-path cost only
    x = jnp.asarray(rng.normal(size=(16, 1024)), jnp.float32)
    t_pal = _time(lambda: pal(x), n=5)
    row("t7_3.palette_interpret_wall", t_pal, "measured(interpret-only)")
    REPORT["t7_3"] = {"palette_ratio": pal.dense_bytes() / pal.hbm_bytes()}


def t9_2_roofline_constants():
    """Table 9.2: the roofline constants + R(I) curve + working-set rule."""
    print("# Table 9.2 — roofline constants (M1 paper values vs our HAL; v5e target)")
    m1, v5e = hal.ANE_M1, hal.TPU_V5E
    row("t9_2.m1_ridge", None, f"paper:141 ours:{m1.ridge_flop_per_byte:.0f} FLOP/B")
    row("t9_2.m1_peak", None, f"paper:12e12 ours:{m1.peak_flops:.0e}")
    row("t9_2.m1_bw", None, f"paper:85e9 ours:{m1.hbm_bandwidth:.0e}")
    row("t9_2.m1_dispatch_floor", None, f"paper:0.23ms ours:{m1.dispatch_floor_s*1e3}ms")
    row("t9_2.v5e_ridge", None, f"derived:{v5e.ridge_flop_per_byte:.0f} FLOP/B")
    # R(I) curve: bandwidth-bound below ridge, compute roof above
    for inten in (10, 50, 141, 500, 2000):
        r = roofline.attainable_rate(float(inten), m1)
        row(f"t9_2.R(I={inten})", None, f"modeled:{r:.2e} FLOP/s")
    # conv 3x3 @256ch intensity (paper: 466 FLOP/B, compute-bound)
    flops = 2 * 256 * 256 * 3 * 3 * 32 * 32
    byts = (256 * 32 * 32 * 2) * 2 + 3 * 3 * 256 * 256 * 2
    row("t9_2.conv3x3_256ch_intensity", None,
        f"paper:466 ours:{flops/byts:.0f} FLOP/B (compute-bound: "
        f"{flops/byts > m1.ridge_flop_per_byte})")
    REPORT["t9_2"] = "see rows"


def t9_4_fusion_amortization():
    """§9.4: fused chains hold per-call latency ~flat 1->32 layers; batching
    amortizes the floor per sample. Reproduced with real wall times here."""
    print("# §9.4 — fusion economics (host-measured shape reproduction)")
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64)) * 0.01
    per_call = {}
    for depth in (1, 4, 16, 32):
        def chain(a, w=w, depth=depth):
            def body(a, _):
                return jnp.tanh(a @ w), None
            out, _ = jax.lax.scan(body, a, None, length=depth)
            return out
        f = jax.jit(chain).lower(x).compile()
        t = _time(lambda f=f: f(x), n=30)
        per_call[depth] = t
        row(f"t9_4.fused_chain_depth{depth}", t,
            f"derived:per_op={t/depth:.1f}us")
    flatness = per_call[32] / per_call[1]
    row("t9_4.call_time_ratio_32_vs_1", None,
        f"derived:{flatness:.2f}x (paper: ~flat at the floor)")
    # unfused: one dispatch per layer
    f1 = jax.jit(lambda a: jnp.tanh(a @ w)).lower(x).compile()
    t1 = _time(lambda: f1(x), n=30)
    unfused32 = 32 * t1
    row("t9_4.unfused_32_dispatches", unfused32,
        f"derived:fusion_gain={unfused32/per_call[32]:.1f}x")
    # batch amortization (paper: 512 samples -> ~127x per-sample reduction)
    base = None
    for batch in (1, 64, 512):
        xb = jnp.ones((batch, 64))
        fb = jax.jit(lambda a: jnp.tanh(a @ w)).lower(xb).compile()
        t = _time(lambda fb=fb, xb=xb: fb(xb), n=30)
        per_sample = t / batch
        if base is None:
            base = per_sample
            row(f"t9_4.batch{batch}_per_sample", per_sample, "baseline")
        else:
            row(f"t9_4.batch{batch}_per_sample", per_sample,
                f"derived:amortization={base/per_sample:.1f}x")
    REPORT["t9_4"] = per_call


def t10_4_energy_per_format():
    """Table 10.4: energy per inference across weight formats (modeled).

    The paper: latency falls faster than power rises, so narrower streams
    cut energy/inference (int8 0.59x, int4 0.41x, sparse 0.57x vs fp16)."""
    print("# Table 10.4 — compression as an energy control (roofline+power model)")
    rng = np.random.default_rng(0)
    w = rng.normal(size=(4096, 4096)).astype(np.float32)
    act = 16 * 2 * 4096 * 2.0
    flops = 2 * 16 * 4096 * 4096
    paper_ratio = {"fp16": 1.0, "int8": 0.59, "int4_palette": 0.41,
                   "sparse": 0.57}
    m2 = hal.ANE_M2
    e_fp16 = None
    for form in (hal.WeightForm.FP16, hal.WeightForm.INT8,
                 hal.WeightForm.INT4_PALETTE, hal.WeightForm.SPARSE):
        p = cp.encode(form, w)
        byts = cp.dram_bytes(p, m2) + act
        t, _ = roofline.dispatch_time(flops, byts, m2)
        e = roofline.energy_joules(flops, t, m2)
        if e_fp16 is None:
            e_fp16 = e
        row(f"t10_4.energy_{form.value}", None,
            f"modeled:{e/e_fp16:.2f}x paper:{paper_ratio[form.value]:.2f}x")
    REPORT["t10_4"] = "see rows"


def ta_capability_census():
    """Appendix A: the operation-by-device matrix (attested vs reachable)."""
    print("# Appendix A — capability census")
    for target in (hal.ANE_M1, hal.ANE_M2, hal.ANE_M3, hal.ANE_M5):
        rows_ = capability.attested_vs_reachable(target)
        attested = sum(1 for _, a, _r in rows_ if a)
        reachable = sum(1 for _, _a, r in rows_ if r)
        row(f"tA.{target.name}", None,
            f"attested:{attested} reachable:{reachable} gap:{attested-reachable}")
    # live compile-and-run on the actual backend
    native = sum(capability.confirm_op(op, hal.TPU_V5E).reachable
                 for op in ("matmul", "conv2d", "softmax", "gather",
                            "scatter", "reduce_prod", "cumsum"))
    row("tA.xla_backend_confirmed", None, f"measured:{native}/7 native")
    REPORT["tA"] = "see rows"


def t5_3_segmenter():
    """§5.3: cost-driven placement — solution quality + the long-segment
    property, on real per-arch op graphs."""
    print("# §5.3 — placement segmenter")
    for arch in ("tinyllama-1.1b", "deepseek-v3-671b", "mamba2-1.3b"):
        cfg = configs.get_config(arch)
        ops = costmodel.op_graph(cfg, configs.SHAPES["decode_32k"])
        p = sg.place(ops, sg.ANE_BACKENDS)
        all_ane = sum(sg.ANE_BACKENDS[0].op_cost(o) for o in ops) + 0.23e-3
        row(f"t5_3.{arch}", None,
            f"derived:segments={len(p.segments)} cost={p.cost*1e3:.2f}ms "
            f"all_engine={all_ane*1e3:.2f}ms")
    REPORT["t5_3"] = "see rows"


def roofline_cells_summary():
    """§Roofline: the per-(arch x shape x mesh) three-term table, read from
    the dry-run artifacts."""
    print("# §Roofline — per-cell dominant terms (from reports/dryrun)")
    import glob
    base = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")
    cells = sorted(glob.glob(os.path.join(base, "*.json")))
    if not cells:
        row("cells.none", None, "run `python -m repro.launch.dryrun --all` first")
        return
    doms = {"compute": 0, "memory": 0, "collective": 0}
    n_ok = n_skip = 0
    for path in cells:
        tag = os.path.basename(path)[:-5]
        if len(tag.split("__")) > 3:
            continue  # hillclimb variants live in §Perf, not the census
        d = json.load(open(path))
        if d.get("status") == "SKIP":
            n_skip += 1
            continue
        if d.get("status") != "OK" or "analytic" not in d or d.get("overrides"):
            continue
        n_ok += 1
        doms[d["analytic"]["dominant"]] += 1
    row("cells.counts", None, f"derived:ok={n_ok} principled_skips={n_skip}")
    row("cells.dominant_split", None,
        f"derived:compute={doms['compute']} memory={doms['memory']} "
        f"collective={doms['collective']}")
    REPORT["cells"] = doms


TABLES = {
    "t2_3": t2_3_dispatch_budget,
    "t3_1": t3_1_survivor_sweep,
    "t3_3": t3_3_numeric_constants,
    "t5_3": t5_3_segmenter,
    "t7_1": t7_1_compression_streams,
    "t7_3": t7_3_kernel_streaming,
    "t9_2": t9_2_roofline_constants,
    "t9_4": t9_4_fusion_amortization,
    "t10_4": t10_4_energy_per_format,
    "tA": ta_capability_census,
    "cells": roofline_cells_summary,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    print("name,us_per_call,derived")
    for name in names:
        TABLES[name]()
    outdir = os.path.join(os.path.dirname(__file__), "..", "reports")
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "bench.json"), "w") as f:
        json.dump({"rows": [(n, u, str(d)) for n, u, d in ROWS]}, f, indent=1)


if __name__ == "__main__":
    main()
