"""Shared harness for the serving benches (batching / overlap / speculative).

Every bench in this directory follows the same discipline: build a smoke
model routed through the kernel dispatcher, serve heterogeneous request
rounds through a scheduler, time warm (cache-hit) rounds interleaved so
host-clock drift hits every schedule equally, write a JSON report next to
the repo root, and exit nonzero when the acceptance gate fails. This module
is that discipline, once — the per-bench files keep only what they measure.
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core import costmodel, hal
from repro.core.dispatch import KernelDispatcher
from repro.launch.scheduler import Request
from repro.models.model import build_model


def build_smoke_model(arch: str, target_name: str, seed: int = 0):
    """(cfg, target, model, params) with dispatcher-routed matmuls."""
    cfg = configs.get_smoke(arch)
    target = hal.get_target(target_name)
    model = build_model(cfg, dispatcher=KernelDispatcher(target))
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, target, model, params


def hetero_lens(prompt_len: int, n: int) -> list[int]:
    """Heterogeneous prompts around `prompt_len`: exercises the bucketed
    prefill shapes and the teacher-forced catch-up path, not just one."""
    return [max(2, prompt_len - (i % 3) * (prompt_len // 4))
            for i in range(n)]


def make_requests(cfg, lens, gen: int, *, rid0: int = 0, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=(L,)).astype(np.int32),
                    max_new_tokens=gen,
                    frames=(np.asarray(rng.normal(size=cfg.frame_shape),
                                       np.float32)
                            if cfg.family == "encdec" else None))
            for i, L in enumerate(lens)]


def make_motif_requests(cfg, lens, gen: int, *, rid0: int = 0,
                        seed: int = 0, step: int = 0):
    """Requests whose prompts come from the synthetic MOTIF distribution
    (`data.pipeline.SyntheticLM.prompt_batch`) instead of uniform noise —
    in-distribution traffic for a teacher trained on the motif corpus.
    Drafter acceptance is only measurable here: on uniform prompts a
    teacher and its distilled student agree only by luck."""
    from repro.data.pipeline import DataConfig, SyntheticLM

    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=max(lens),
                                 global_batch=len(lens), seed=seed))
    toks = src.prompt_batch(step, len(lens), max(lens))
    return [Request(rid=rid0 + i,
                    prompt=np.asarray(toks[i, :L], np.int32),
                    max_new_tokens=gen)
            for i, L in enumerate(lens)]


def timed_round(sched, cfg, lens, gen: int, rep: int):
    """One fresh-rid serving round; returns (wall_s, {local rid: tokens})."""
    reqs = make_requests(cfg, lens, gen, rid0=rep * len(lens))
    t0 = time.perf_counter()
    results = sched.run(reqs)
    wall = time.perf_counter() - t0
    return wall, {r.rid - rep * len(lens): r.tokens for r in results}


def interleaved_best_of(scheds: dict, cfg, lens, gen: int, reps: int):
    """Warm every schedule once, then time `reps` identical warm rounds per
    schedule, *interleaved* (round of A, round of B, round of A, ...) so
    host-clock drift hits every side equally; best-of-N per schedule is the
    slope-method discipline. Greedy streams are identical across rounds, so
    one round's tokens represent all. Returns (best walls, tokens)."""
    for sched in scheds.values():
        sched.run(make_requests(cfg, lens, gen, rid0=0))
    best = {name: float("inf") for name in scheds}
    toks = {}
    for rep in range(1, reps + 1):
        for name, sched in scheds.items():
            wall, t = timed_round(sched, cfg, lens, gen, rep)
            best[name] = min(best[name], wall)
            toks[name] = t
    return best, toks


def modeled_step_s(cfg, target, batch: int, ctx_len: int) -> float:
    """Costmodel roofline estimate of ONE batched decode step on `target`:
    max(flops/peak, bytes/bandwidth) with the full weight read plus the KV/
    recurrent state the step touches — the work term of the §9 split (the
    floor term comes from the stream ledger, not from here)."""
    shape = configs.ShapeConfig("decode_bench", ctx_len, batch, "decode")
    flops = costmodel.model_flops(cfg, shape) \
        + costmodel.attention_flops(cfg, shape)
    bytes_ = costmodel.weight_bytes(cfg) \
        + costmodel.kv_cache_bytes(cfg, shape)
    return max(flops / target.peak_flops, bytes_ / target.hbm_bandwidth)


def emit_report(report: dict, out_path: str) -> None:
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"-> {os.path.abspath(out_path)}")


def gate(failures: list) -> int:
    """Print every failure to stderr; exit code for main()."""
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0
