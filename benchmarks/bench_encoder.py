"""Encoder serving bench: fused conv-stem epilogues vs the separate-op path.

    PYTHONPATH=src python -m benchmarks.bench_encoder [--fast]

The encoder scenario is dispatch-count economics at prefill time: Whisper's
conv stem is two convolutions, each followed by a GELU. Unfused, every
request pays FOUR engine dispatches for the stem (conv, act, conv, act) —
four t0 floors before the transformer even starts. Fused, the LUT
activation runs at the conv kernel's output port and the stem is TWO
dispatches. The fused path must be *bit-identical* to kernel-then-LUT (the
epilogue contract `tests/test_conv_family.py` pins), so the floor savings
are free.

This bench routes the whisper-small smoke encoder through the kernel
dispatcher both ways and reads the dispatcher's route ledger — every routed
op is one engine command paying the target's `dispatch_floor_s`:

  * GATED: fused stem dispatches/request strictly below unfused, with both
    route logs all-native on the TPU target and outputs bit-identical.
  * GATED: dispatched encoder output matches the undispatched reference
    (same LUT numerics, conv accumulation order is the only difference) at
    the conv2d registry row's fp32 tolerance.
  * GATED: a serve round-trip (continuous batching, per-request mel frames)
    completes with ProgramCache hits > 0 on the second round — the encoder
    prefill program is cacheable, not a per-request recompile.

Writes `BENCH_encoder.json` (repo root by default). Exits nonzero when any
gate fails. Host walls are reported, never gated (correctness-path CPU).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import ExecutionStream, KernelDispatcher, ProgramCache
from repro.launch.scheduler import ServeConfig, build_scheduler
from repro.models import dispatched as dsp
from repro.models import encdec
from repro.parallel.ctx import CPU_CTX

from benchmarks._common import (build_smoke_model, emit_report, gate,
                                make_requests)

#: tolerance for dispatched-vs-reference encoder output: the conv2d registry
#: row's fp32 tolerance, scaled like the parity harness (whole-model
#: accumulation differences compound across the stem + encoder stack)
PARITY_SCALE = 4.0


def _frames(cfg, batch: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.asarray(rng.normal(size=(batch,) + cfg.frame_shape),
                      np.float32)


def _stem_routes(model, params, frames, *, fused: bool):
    """Run the encoder eagerly under a fresh dispatcher; return (output,
    route ledger). Each route record is one engine command — the unit that
    pays the dispatch floor t0."""
    disp = KernelDispatcher(model.dispatcher.target)
    with dsp.use_dispatcher(disp), dsp.fuse_epilogues(fused):
        out = encdec.encode(model.cfg, params["encdec"], frames, CPU_CTX)
    jax.block_until_ready(out)
    return np.asarray(out), list(disp.routes)


def bench(arch: str, *, batch: int, gen: int, target_name: str,
          seed: int = 0) -> dict:
    from repro.kernels import registry

    cfg, target, model, params = build_smoke_model(arch, target_name, seed)
    if cfg.family != "encdec" or not cfg.n_mels:
        raise SystemExit(f"{arch} has no mel conv stem; this bench measures "
                         f"the encoder scenario")
    frames = _frames(cfg, batch, seed)

    # -- fused vs unfused stem: the dispatch-count ledger -------------------
    t0 = time.perf_counter()
    out_fused, routes_fused = _stem_routes(model, params, frames, fused=True)
    wall_fused = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_unfused, routes_unfused = _stem_routes(model, params, frames,
                                               fused=False)
    wall_unfused = time.perf_counter() - t0

    def ledger(routes):
        kinds: dict[str, int] = {}
        for r in routes:
            kinds[r.kernel] = kinds.get(r.kernel, 0) + 1
        return {"n_dispatches": len(routes),
                "per_request": len(routes) / batch,
                "by_kernel": kinds,
                "all_native": bool(all(r.native for r in routes)),
                "floor_s_per_request":
                    len(routes) / batch * target.dispatch_floor_s}

    fused_row = ledger(routes_fused)
    unfused_row = ledger(routes_unfused)
    fused_row["host_wall_s"] = wall_fused
    unfused_row["host_wall_s"] = wall_unfused
    bit_identical = bool(np.array_equal(out_fused, out_unfused))

    # -- parity against the undispatched reference encoder ------------------
    ref = np.asarray(encdec.encode(cfg, params["encdec"],
                                   jax.numpy.asarray(frames), CPU_CTX))
    rtol, atol = registry.get("conv2d").tol(jax.numpy.float32)
    err = float(np.max(np.abs(out_fused - ref)))
    parity_ok = bool(np.allclose(out_fused, ref, rtol=PARITY_SCALE * rtol,
                                 atol=PARITY_SCALE * atol))

    print(f"stem dispatches/request: fused {fused_row['per_request']:.1f} "
          f"vs unfused {unfused_row['per_request']:.1f} "
          f"(bit-identical={bit_identical}), parity err {err:.2e}")

    # -- serve round-trip: encoder workloads admitted, programs cached ------
    pc = ProgramCache()
    sched_cfg = ServeConfig(
        schedule="continuous", max_len=8 + batch + gen, n_slots=2,
        stream=ExecutionStream(pc, target=target), program_cache=pc)

    def round_reqs(rid0: int):
        # prompts >= 8 tokens: encdec prefill must reach a bucket (the
        # cross-attention cache is built at prefill)
        return make_requests(cfg, [8 + i for i in range(batch)], gen,
                             rid0=rid0, seed=seed + rid0)

    sched = build_scheduler(sched_cfg, model, params, cfg)
    t0 = time.perf_counter()
    res1 = sched.run(round_reqs(0))
    wall_cold = time.perf_counter() - t0
    hits_after_cold = pc.stats.hits
    t0 = time.perf_counter()
    res2 = sched.run(round_reqs(batch))
    wall_warm = time.perf_counter() - t0
    serve_row = {
        "n_requests": 2 * batch,
        "tokens": int(sum(len(r.tokens) for r in res1 + res2)),
        "cache_hits": pc.stats.hits,
        "cache_misses": pc.stats.misses,
        "warm_round_hits": pc.stats.hits - hits_after_cold,
        "host_wall_cold_s": wall_cold,
        "host_wall_warm_s": wall_warm,
    }
    print(f"serve: {serve_row['tokens']} tokens, cache "
          f"{pc.stats.hits} hits / {pc.stats.misses} misses "
          f"(warm round: {serve_row['warm_round_hits']} hits)")

    return {
        "arch": cfg.name,
        "target": target.name,
        "dispatch_floor_s": target.dispatch_floor_s,
        "batch": batch,
        "frame_shape": list(cfg.frame_shape),
        "stem": {"fused": fused_row, "unfused": unfused_row,
                 "bit_identical": bit_identical},
        "parity": {"max_abs_err": err, "rtol": PARITY_SCALE * rtol,
                   "atol": PARITY_SCALE * atol, "ok": parity_ok},
        "serve": serve_row,
        "paper_ref": "§3.5 fused output-port activations + §9.3 dispatch "
                     "floor: fewer engine commands per request is the "
                     "prefill lever",
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="whisper-small",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: smaller batch / shorter gen")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=6)
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS))
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_encoder.json"))
    args = ap.parse_args(argv)

    if args.fast:
        args.batch, args.gen = 2, 4

    report = bench(args.arch, batch=args.batch, gen=args.gen,
                   target_name=args.target)
    emit_report(report, args.out)

    failures = []
    stem = report["stem"]
    if not stem["fused"]["per_request"] < stem["unfused"]["per_request"]:
        failures.append(
            f"fused stem is not strictly cheaper: "
            f"{stem['fused']['per_request']} dispatches/request fused vs "
            f"{stem['unfused']['per_request']} unfused")
    if not stem["bit_identical"]:
        failures.append("fused stem output diverged from the separate-op "
                        "pipeline — the epilogue contract is bit-exactness")
    for leg in ("fused", "unfused"):
        if not stem[leg]["all_native"]:
            failures.append(f"{leg} stem route log has oracle fallbacks on "
                            f"{report['target']} — the encoder scenario "
                            f"measures native dispatch counts")
    if not report["parity"]["ok"]:
        failures.append(
            f"dispatched encoder diverged from the reference: max err "
            f"{report['parity']['max_abs_err']:.3e} outside "
            f"{PARITY_SCALE}x conv2d registry tolerance")
    if report["serve"]["warm_round_hits"] <= 0:
        failures.append("second serve round produced no ProgramCache hits — "
                        "the encoder prefill program is recompiling per "
                        "request")
    return gate(failures)


if __name__ == "__main__":
    sys.exit(main())
