"""Weight-compression sweep — paper ch.7 as a workflow.

    PYTHONPATH=src python examples/compression_sweep.py

For one linear layer: every compressed form's stored bytes, DRAM/HBM bytes
per use (stream vs fold, per chip generation), round-trip accuracy, the
§7.6 automatic choice, and the Pallas streaming kernels run against their
oracles.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import compression as cp, hal
from repro.kernels.palette.ops import PaletteLinear
from repro.kernels.sparse.ops import SparseLinear

rng = np.random.default_rng(0)
w = rng.normal(size=(2048, 512)).astype(np.float32)
w[rng.random(w.shape) < 0.55] = 0.0          # prunable layer

print(f"layer (2048x512), {np.mean(w==0)*100:.0f}% zeros\n")
print(f"{'form':14s} {'stored':>8s} {'M1 moves':>9s} {'M5 moves':>9s} {'rel err':>8s}")
for form in (hal.WeightForm.FP16, hal.WeightForm.INT8,
             hal.WeightForm.INT4_PALETTE, hal.WeightForm.SPARSE,
             hal.WeightForm.BLOCKWISE):
    p = cp.encode(form, w)
    err = cp.accuracy_error(form, w) if form != hal.WeightForm.FP16 else 0.0
    print(f"{form.value:14s} {p.stored_bytes/2**10:7.0f}K "
          f"{cp.dram_bytes(p, hal.ANE_M1)/2**10:8.0f}K "
          f"{cp.dram_bytes(p, hal.ANE_M5)/2**10:8.0f}K {err:8.4f}")

choice = cp.choose_weight_form(w, hal.ANE_M1, flops=2 * w.size * 8,
                               act_bytes=8 * 2048 * 2, tolerance=0.3)
print(f"\n§7.6 chooser on M1 (bandwidth-bound, 30% tol): {choice.value}")

print("\nstreaming kernels vs dense compute (interpret mode):")
x = jnp.asarray(rng.normal(size=(16, 2048)), jnp.float32)
dense = np.asarray(x) @ w
pal = PaletteLinear.pack(w)
spr = SparseLinear.pack(w)
for name, lin in (("palette", pal), ("sparse", spr)):
    out = np.asarray(lin(x))
    rel = np.linalg.norm(out - dense) / np.linalg.norm(dense)
    print(f"  {name:8s}: HBM {lin.dense_bytes()/lin.hbm_bytes():.1f}x fewer "
          f"bytes, output rel err {rel:.4f}")
