"""Batched serving example — the paper's serving shape end to end.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1.3b
    PYTHONPATH=src python examples/serve_batched.py --schedule slo --slo-ms 5

Compile once (content-hash program cache), route every matmul op-by-device
through the kernel dispatcher (packed weights stream through the
palette/sparse kernels), keep KV/SSM state resident (donated buffers), and
schedule the request queue continuously over the decode lanes so every
dispatch's fixed floor is shared by all active requests (paper §9.4).
`--schedule slo` additionally overlaps the decode stream (the host encodes
step N+1 while step N executes, sampling fused on device — paper §2.4's
open overlapping-streams path) and sheds admissions that would breach
`--slo-ms`. Works for any of the 10 architectures in reduced form on CPU;
the same driver serves the full configs on a pod.
"""

import argparse

from repro import configs
from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--weight-form", default="fp16",
                    choices=serve.WEIGHT_FORMS)
    ap.add_argument("--sampling", default="greedy",
                    choices=("greedy", "categorical"))
    ap.add_argument("--schedule", default="continuous",
                    choices=("continuous", "slo", "spec"))
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="slo schedule: defer admissions while the "
                         "predicted token latency exceeds this")
    ap.add_argument("--draft-depth", type=int, default=4,
                    help="spec schedule: drafter proposals per window")
    ap.add_argument("--draft", default="self", choices=("self", "shrink"),
                    help="spec schedule: draft with the target itself "
                         "(accept-all ceiling) or a depth-pruned second "
                         "model (random-init: low acceptance)")
    args = ap.parse_args()

    print(f"serving {args.arch} (reduced config), batch={args.batch}, "
          f"weights={args.weight_form}, schedule={args.schedule}, "
          f"two identical request rounds")
    argv = ["--arch", args.arch, "--smoke",
            "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len),
            "--gen", str(args.gen),
            "--weight-form", args.weight_form,
            "--sampling", args.sampling,
            "--schedule", args.schedule,
            "--requests", "2"]
    if args.schedule == "slo" and args.slo_ms is not None:
        argv += ["--slo-ms", str(args.slo_ms)]
    if args.schedule == "spec":
        argv += ["--draft", args.draft, "--draft-depth",
                 str(args.draft_depth)]
    out = serve.run(argv)
    # compile-once discipline: the second identical request round must
    # warm-start from the content-hash program cache — a zero hit rate means
    # some direct-matmul path bypassed the dispatcher/compile route.
    assert out["cache_hits"] > 0, \
        "second request round missed the ProgramCache: the dispatched " \
        "serving path is being bypassed"
    print(f"generated {out['tokens'].shape[1]} tokens x {args.batch} requests "
          f"at {out['tok_per_s']:.1f} tok/s (CPU, reduced model); "
          f"program-cache hits={out['cache_hits']} "
          f"misses={out['cache_misses']}; routes={out.get('routes')}")
    if args.schedule == "slo":
        print(f"overlapped stream: in-flight window "
              f"{out['max_in_flight']}, mean depth "
              f"{out['mean_inflight_depth']:.2f}, "
              f"{out['deferred_admissions']} admissions deferred by the "
              f"SLO gate, predicted p99 token latency "
              f"{out['predicted_token_latency_s']*1e3:.2f} ms")
    elif args.schedule == "spec":
        print(f"speculative decode: {args.draft} drafter depth "
              f"{args.draft_depth}, {out['n_windows']} windows, "
              f"acceptance {out['acceptance_rate']:.2f}, "
              f"{out['tokens_per_window_dispatch']:.2f} tokens per "
              f"window dispatch (two floors buy up to depth+1 tokens, §9)")
    # batching amortization, the paper's §9.4 point: the same requests
    # served one at a time pay the full dispatch floor each
    single = serve.run(["--arch", args.arch, "--smoke", "--batch", "1",
                        "--prompt-len", str(args.prompt_len),
                        "--gen", str(args.gen),
                        "--weight-form", args.weight_form,
                        "--sampling", args.sampling,
                        "--schedule", "sequential"])
    amort = (single["per_request_dispatch_overhead_s"]
             / max(out["per_request_dispatch_overhead_s"], 1e-12))
    print(f"dispatch floor per request vs sequential: {amort:.1f}x lower "
          f"from continuous batching (floor amortization, §9.4)")


if __name__ == "__main__":
    main()
