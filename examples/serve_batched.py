"""Batched serving example — the paper's serving shape end to end.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1.3b

Compile once, keep KV/SSM state resident (donated buffers), batch requests
to amortize the dispatch floor (paper §9.4), report tokens/s. Works for any
of the 10 architectures in reduced form on CPU; the same driver serves the
full configs on a pod.
"""

import argparse

from repro import configs
from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    print(f"serving {args.arch} (reduced config), batch={args.batch}")
    out = serve.run(["--arch", args.arch, "--smoke",
                     "--batch", str(args.batch),
                     "--prompt-len", str(args.prompt_len),
                     "--gen", str(args.gen)])
    print(f"generated {out['tokens'].shape[1]} tokens x {args.batch} requests "
          f"at {out['tok_per_s']:.1f} tok/s (CPU, reduced model)")
    # batching amortization, the paper's §9.4 point:
    single = serve.run(["--arch", args.arch, "--smoke", "--batch", "1",
                        "--prompt-len", str(args.prompt_len),
                        "--gen", str(args.gen)])
    amort = (out["tok_per_s"] / args.batch) / max(single["tok_per_s"], 1e-9)
    print(f"per-request throughput vs batch=1: {out['tok_per_s']/single['tok_per_s']:.1f}x "
          f"from batching (dispatch-floor amortization)")


if __name__ == "__main__":
    main()
