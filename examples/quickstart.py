"""Quickstart: the paper's technique as a library, in five minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

Walks the seven contributions (DESIGN.md C1-C7): roofline placement, the
numerics oracle, compile-once/dispatch-many, weight-form choice, the
segmenter, capability confirmation — then trains and serves a reduced model.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import (capability, compression as cp, costmodel, dispatch,
                        hal, numerics as nu, roofline, segmenter as sg)
from repro.models.model import build_model

print("=== C1: roofline placement (paper ch.9) ===")
m1 = hal.ANE_M1
print(f"M1 ridge point: {m1.ridge_flop_per_byte:.0f} FLOP/B; "
      f"v5e: {hal.TPU_V5E.ridge_flop_per_byte:.0f} FLOP/B")
flops, byts = 2 * 256 * 256 * 9 * 32 * 32, 256 * 32 * 32 * 4 + 9 * 256 * 256 * 2
t, rate = roofline.dispatch_time(flops, byts, m1)
print(f"3x3x256 conv: intensity {flops/byts:.0f} FLOP/B -> "
      f"{'compute' if flops/byts > m1.ridge_flop_per_byte else 'bandwidth'}-bound, "
      f"{t*1e3:.2f} ms/dispatch on the modeled M1")

print("\n=== C2: the fp16 + wide-accumulator numerics oracle (ch.3) ===")
print(f"survivor sweep {nu.survivor_sweep([1024, 4096, 8000])} (paper: 16,4,4)")
print(f"32752 passes the MAC port, 32768 -> "
      f"{nu.ane_matmul(np.array([[32768.0]]), np.ones((1, 1)))[0, 0]}")

print("\n=== C3: compile once, dispatch many (ch.2/5/6) ===")
cache = dispatch.ProgramCache()
f = lambda x: jnp.tanh(x @ x.T).sum()  # noqa: E731
x = jnp.ones((32, 32))
cache.compile(f, x)
cache.compile(f, x)                      # content-hash hit
print(f"cache stats after two identical compiles: hits={cache.stats.hits} "
      f"misses={cache.stats.misses}")

print("\n=== C4: choose a weight form the paper's way (§7.6) ===")
rng = np.random.default_rng(0)
w = rng.choice(np.linspace(-1, 1, 16), size=(2048, 512)).astype(np.float32)
form = cp.choose_weight_form(w, hal.ANE_M1, flops=2 * 2048 * 512 * 4,
                             act_bytes=4096.0)
packed = cp.encode(form, w)
print(f"bandwidth-bound layer on M1 -> {form.value}; "
      f"stored {packed.stored_bytes/packed.dense_bytes:.2f}x dense, "
      f"stream speedup ~{cp.stream_speedup(packed, hal.ANE_M1):.1f}x")

print("\n=== C5: shortest-path placement (§5.3) ===")
ops = costmodel.op_graph(configs.get_config("tinyllama-1.1b"),
                         configs.SHAPES["decode_32k"])
placement = sg.place(ops, sg.ANE_BACKENDS)
print(f"decode graph placed as segments {placement.segments} "
      f"(cost {placement.cost*1e3:.2f} ms)")

print("\n=== C6: attested is not reachable (§4.4) ===")
v = capability.confirm_op("conv3d", hal.ANE_M1)
print(f"conv3d on M1: attested={hal.ANE_M1.attests('conv3d')}, "
      f"confirm_op -> {v.status} at layer {v.layer!r}")

print("\n=== train + serve a reduced model (any of the 10 archs) ===")
cfg = configs.get_smoke("tinyllama-1.1b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jnp.ones((2, 32), jnp.int32),
         "targets": jnp.ones((2, 32), jnp.int32)}
loss, _ = jax.jit(model.loss)(params, batch)
caches, logits = jax.jit(model.prefill)(params, batch)
print(f"loss={float(loss):.3f}; prefill logits {logits.shape}; "
      f"all 10 archs: {configs.ARCH_NAMES}")

print("\n=== C7: dispatcher-routed compressed serving (§4 + §7, end to end) ===")
from collections import Counter

from repro.optim.compression import compress_model_params, weight_form_census

dispatcher = dispatch.KernelDispatcher(hal.TPU_V5E)
served = build_model(cfg, dispatcher=dispatcher)
cparams = compress_model_params(params, hal.WeightForm.INT4_PALETTE)
print(f"packed {len(weight_form_census(cparams))} matmul weights as "
      f"int4_palette; every matmul now routes op-by-device")
pcache = dispatch.ProgramCache()
prefill, _ = pcache.compile(served.prefill, cparams, batch)
prefill(cparams, batch)                     # request 1: compile + dispatch
pcache.compile(served.prefill, cparams, batch)  # request 2: content-hash hit
assert pcache.stats.hits > 0, \
    "second identical request must hit the program cache (anehash warm start)"
census = Counter((r.kernel, r.backend) for r in dispatcher.routes)
print(f"program cache: hits={pcache.stats.hits} misses={pcache.stats.misses}; "
      f"routes: {dict(census)}")

print("\nquickstart OK")
