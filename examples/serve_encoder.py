"""Encoder serving quickstart — whisper-small through the serve CLI.

    PYTHONPATH=src python examples/serve_encoder.py
    PYTHONPATH=src python examples/serve_encoder.py --schedule slo

The encoder workload end to end: per-request log-mel frames
(`cfg.frame_shape`) enter Whisper's two-conv stem — each conv carrying its
GELU as a fused LUT epilogue, so the stem is two engine dispatches, not
four — then the bidirectional encoder runs once at prefill, the
cross-attention K/V become resident state, and the decoder streams tokens
over continuous batching like any decode-only arch. The second identical
request round must warm-start from the content-hash ProgramCache: encoder
prefill is a cacheable program, not a per-request recompile.
"""

import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--schedule", default="continuous",
                    choices=("continuous", "slo"))
    args = ap.parse_args()

    print(f"serving whisper-small (reduced config), batch={args.batch}, "
          f"schedule={args.schedule}, conv stem dispatched with fused "
          f"LUT-GELU epilogues, two identical request rounds")
    out = serve.run(["--arch", "whisper-small", "--smoke",
                     "--batch", str(args.batch),
                     "--prompt-len", str(args.prompt_len),
                     "--gen", str(args.gen),
                     "--schedule", args.schedule,
                     "--requests", "2"])
    # compile-once discipline: round two must hit the program cache — the
    # encoder prefill (conv stem included) shares one cached program across
    # requests of the same shape.
    assert out["cache_hits"] > 0, \
        "second request round missed the ProgramCache: encoder prefill is " \
        "recompiling per request"
    print(f"generated {out['tokens'].shape[1]} tokens x {args.batch} "
          f"requests at {out['tok_per_s']:.1f} tok/s (CPU, reduced model); "
          f"program-cache hits={out['cache_hits']} "
          f"misses={out['cache_misses']}; routes={out.get('routes')}")


if __name__ == "__main__":
    main()
