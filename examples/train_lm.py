"""End-to-end training driver example.

Default: a ~10M-parameter llama-family model for 200 steps on CPU (minutes).
`--preset 100m` selects the ~100M configuration for real hardware (same
code path; a v5e slice trains it in seconds per hundred steps).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Everything the production path has is on: checkpointing + resume, the
deterministic pipeline, supervisor restarts, cosine schedule, grad clipping.
"""

import argparse
import dataclasses
import sys

from repro import configs
from repro.configs.base import ModelConfig
from repro.launch import train


PRESETS = {
    # ~10M: CPU-friendly demonstration
    "10m": ModelConfig(name="demo-10m", family="dense", n_layers=4,
                       d_model=256, n_heads=8, n_kv_heads=4, d_head=32,
                       d_ff=768, vocab=8192, dtype="float32"),
    # ~100M: the deliverable-scale config (run on real hardware)
    "100m": ModelConfig(name="demo-100m", family="dense", n_layers=10,
                        d_model=640, n_heads=10, n_kv_heads=5, d_head=64,
                        d_ff=2560, vocab=32000, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/anevm_train_demo")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    from repro.core import costmodel
    print(f"preset {args.preset}: {costmodel.param_count(cfg)/1e6:.1f}M params")

    # monkey-patch the registry so the standard driver sees this config
    import repro.configs as cfgs
    mod = type(sys)("demo")
    mod.CONFIG = cfg
    cfgs._MODULES[cfg.name] = mod
    cfgs.ARCH_NAMES.append(cfg.name)

    out = train.run(["--arch", cfg.name, "--steps", str(args.steps),
                     "--batch", str(args.batch), "--seq", str(args.seq),
                     "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
                     "--ckpt-every", "50", "--log-every", "20",
                     "--mesh", "none"])
    print(f"final loss {out['final_loss']:.4f} after {out['final_step']} steps "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
