"""repro.core — the paper's contribution as a composable library.

C1 roofline (`roofline`), C2 numerics oracle (`numerics`), C3 dispatch model
(`dispatch`), C4 weight compression (`compression`), C5 placement segmenter
(`segmenter`), C6 capability validator (`capability`), plus the per-target
HAL tables (`hal`) and the analytic cost model (`costmodel`).
"""
from repro.core import (  # noqa: F401
    capability,
    compression,
    costmodel,
    dispatch,
    hal,
    numerics,
    roofline,
    segmenter,
)
