"""Cost-driven placement segmenter (paper §5.3).

The model framework segments an op graph across backends by solving a
shortest path over a cost graph with one node per (operation, backend) pair:

    cost(op, backend) = max(flops / gflops_b, bytes / bw_b) + launch + transfer

A fixed launch penalty is charged at every new segment (backend change) and a
transfer penalty at every backend boundary (the tensor repack between the
engine's layout and the host's). The transfer cost is why minimum-cost
solutions favor long single-backend runs — we reproduce that property in
tests. An op a backend cannot accept simply has no node on that backend, so
the path routes around it (the framework's automatic fallback).

The TPU adaptation keeps the mechanism and swaps the backends: instead of
{CPU, GPU, ANE}, we place over {pallas-mxu, xla, host}, with transfer =
re-layout/resharding cost from the roofline's collective term.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Mapping, Sequence

from repro.core.costmodel import OpCost


@dataclasses.dataclass(frozen=True)
class Backend:
    """One placement backend with its two coarse anchors (paper:
    GetEngineGflopsPerS / GetEngineBwGbPerS) and a per-op validity check."""

    name: str
    flops_per_s: float
    bytes_per_s: float
    # ops this backend refuses (no node in the cost graph)
    rejects: frozenset[str] = frozenset()

    def op_cost(self, op: OpCost) -> float:
        return max(op.flops / self.flops_per_s, op.bytes / self.bytes_per_s)

    def accepts(self, op: OpCost) -> bool:
        return not any(tag in op.name for tag in self.rejects)


# The paper's three devices, with the M1 anchors (paper:T9.1/T9.2).
ANE_BACKENDS = (
    Backend("ane", 12e12, 51e9),                      # engine: fast, weight-stream bw
    Backend("gpu", 2.6e12, 230e9),                    # M1 GPU
    Backend("cpu", 0.2e12, 60e9),
)

# The TPU adaptation's backends.
TPU_BACKENDS = (
    Backend("pallas-mxu", 197e12, 819e9),
    Backend("xla", 160e12, 819e9),                    # default codegen, slightly off-peak
    Backend("host", 0.4e12, 40e9),
)


@dataclasses.dataclass
class Placement:
    ops: list[str]
    backend: list[str]
    cost: float

    @property
    def segments(self) -> list[tuple[str, int]]:
        """(backend, op_count) runs — the paper's 'fewer and larger segments'."""
        segs: list[tuple[str, int]] = []
        for b in self.backend:
            if segs and segs[-1][0] == b:
                segs[-1] = (b, segs[-1][1] + 1)
            else:
                segs.append((b, 1))
        return segs


def place(
    ops: Sequence[OpCost],
    backends: Sequence[Backend] = ANE_BACKENDS,
    *,
    launch_penalty: float = 0.23e-3,       # paper: the per-dispatch floor
    transfer_bytes_per_s: float = 24e9,    # repack at each boundary (paper: standalone act path)
) -> Placement:
    """Dijkstra over the (op index, backend) lattice.

    Node (i, b) = "op i runs on backend b". Edge (i, b) -> (i+1, b') costs
    op_cost(i+1, b') plus, when b != b', the launch penalty of the new segment
    and the transfer of op i's output across the boundary.
    """
    n = len(ops)
    if n == 0:
        return Placement([], [], 0.0)
    names = [b.name for b in backends]
    start: list[tuple[float, int]] = []
    dist: dict[tuple[int, int], float] = {}
    prev: dict[tuple[int, int], tuple[int, int] | None] = {}
    pq: list[tuple[float, int, int]] = []
    for bi, b in enumerate(backends):
        if b.accepts(ops[0]):
            c = b.op_cost(ops[0]) + launch_penalty
            dist[(0, bi)] = c
            prev[(0, bi)] = None
            heapq.heappush(pq, (c, 0, bi))
    while pq:
        d, i, bi = heapq.heappop(pq)
        if d > dist.get((i, bi), float("inf")):
            continue
        if i == n - 1:
            continue
        for bj, b2 in enumerate(backends):
            if not b2.accepts(ops[i + 1]):
                continue
            c = b2.op_cost(ops[i + 1])
            if bj != bi:
                c += launch_penalty
                c += ops[i].bytes / transfer_bytes_per_s   # boundary repack
            nd = d + c
            if nd < dist.get((i + 1, bj), float("inf")):
                dist[(i + 1, bj)] = nd
                prev[(i + 1, bj)] = (i, bi)
                heapq.heappush(pq, (nd, i + 1, bj))
    # best terminal
    best = min(((dist.get((n - 1, bi), float("inf")), bi)
                for bi in range(len(backends))), key=lambda t: t[0])
    if best[0] == float("inf"):
        raise ValueError("no feasible placement: some op rejected by every backend")
    # reconstruct
    path: list[int] = []
    node: tuple[int, int] | None = (n - 1, best[1])
    while node is not None:
        path.append(node[1])
        node = prev[node]
    path.reverse()
    return Placement([o.name for o in ops], [names[bi] for bi in path], best[0])


def brute_force(ops: Sequence[OpCost], backends: Sequence[Backend],
                **kw) -> Placement:
    """Exponential reference for tests (small graphs only)."""
    import itertools

    launch = kw.get("launch_penalty", 0.23e-3)
    xfer = kw.get("transfer_bytes_per_s", 24e9)
    names = [b.name for b in backends]
    best: Placement | None = None
    for assign in itertools.product(range(len(backends)), repeat=len(ops)):
        ok = all(backends[bi].accepts(op) for bi, op in zip(assign, ops))
        if not ok:
            continue
        cost = launch + backends[assign[0]].op_cost(ops[0])
        for i in range(1, len(ops)):
            cost += backends[assign[i]].op_cost(ops[i])
            if assign[i] != assign[i - 1]:
                cost += launch + ops[i - 1].bytes / xfer
        if best is None or cost < best.cost:
            best = Placement([o.name for o in ops],
                             [names[bi] for bi in assign], cost)
    assert best is not None
    return best
