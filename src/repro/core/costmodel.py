"""Analytic cost model: parameters, FLOPs, and bytes per (arch x shape).

This is the napkin-math layer the paper's placement planner runs on
(§5.3: cost = max(flops/gflops, bytes/bw) + launch + transfer) and the source
of MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) that the roofline
analysis compares against compiled HLO_FLOPs.

All counts are *global* (whole step across the mesh); divide by chip count
for per-chip figures.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# Parameter counts
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.use_mla:
        qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = d * cfg.q_lora_rank                       # W_q_a
        p += cfg.q_lora_rank * cfg.n_heads * qk_head  # W_q_b
        p += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)  # W_kv_a (+ shared rope key)
        p += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)  # W_kv_b
        p += cfg.n_heads * cfg.v_head_dim * d         # W_o
        return p
    dh, h, kv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    return d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d


def _mlp_params(d: int, f: int, act: str) -> int:
    # GLU MLPs (silu/gelu gate) carry 3 matrices; plain MLPs carry 2.
    return (3 if act != "gelu_mlp" else 2) * d * f


def _ssm_params(cfg: ModelConfig) -> int:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    g = cfg.ssm_groups
    p = d * (2 * di + 2 * g * n + cfg.ssm_heads)      # in_proj: z, x, B, C, dt
    p += cfg.ssm_conv_width * (di + 2 * g * n)        # conv1d over x,B,C
    p += cfg.ssm_heads * 2                            # A_log, D
    p += di * d                                       # out_proj
    p += di                                           # gated norm
    return p


def _rglru_params(cfg: ModelConfig) -> int:
    d, w = cfg.d_model, cfg.lru_width
    p = 2 * d * w                                     # linear_x, linear_y (in)
    p += w * d                                        # out proj
    p += cfg.ssm_conv_width * w if cfg.ssm_conv_width else 4 * w  # temporal conv
    p += 2 * w                                        # recurrent + input gates (diag) params: a_param, gates
    p += 2 * w * w // max(1, w // w)                  # gate projections (per-channel block): use w*w light
    return p


def layer_params(cfg: ModelConfig, layer_idx: int) -> int:
    """Parameters of one decoder layer (norms excluded; negligible)."""
    kind = cfg.block_kind(layer_idx)
    if kind == "ssm":
        return _ssm_params(cfg)
    p = 0
    if kind == "rglru":
        p += _rglru_params(cfg)
    else:
        p += _attn_params(cfg)
    # MLP / MoE
    if cfg.layer_is_moe(layer_idx):
        p += cfg.n_experts * _mlp_params(cfg.d_model, cfg.d_ff_expert, cfg.act)
        p += cfg.n_shared_experts * _mlp_params(cfg.d_model, cfg.d_ff_expert, cfg.act)
        p += cfg.d_model * cfg.n_experts              # router
    else:
        p += _mlp_params(cfg.d_model, cfg.d_ff, cfg.act)
    return p


def layer_active_params(cfg: ModelConfig, layer_idx: int) -> int:
    """Parameters touched per token (MoE: only routed-to experts)."""
    kind = cfg.block_kind(layer_idx)
    if kind == "ssm":
        return _ssm_params(cfg)
    p = _rglru_params(cfg) if kind == "rglru" else _attn_params(cfg)
    if cfg.layer_is_moe(layer_idx):
        p += cfg.experts_per_token * _mlp_params(cfg.d_model, cfg.d_ff_expert, cfg.act)
        p += cfg.n_shared_experts * _mlp_params(cfg.d_model, cfg.d_ff_expert, cfg.act)
        p += cfg.d_model * cfg.n_experts
    else:
        p += _mlp_params(cfg.d_model, cfg.d_ff, cfg.act)
    return p


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (embeddings + layers + head)."""
    p = cfg.padded_vocab * cfg.d_model                # embedding
    if not cfg.tie_embeddings:
        p += cfg.padded_vocab * cfg.d_model           # unembedding
    for i in range(cfg.n_layers):
        p += layer_params(cfg, i)
    if cfg.n_encoder_layers:
        for _ in range(cfg.n_encoder_layers):
            # encoder layer: self-attn + MLP; decoder layers above additionally
            # carry cross-attention.
            p += _attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff, cfg.act)
        p += cfg.n_layers * _attn_params(cfg)         # cross-attn in decoder
        if cfg.n_mels:                                # conv stem (+ biases)
            p += cfg.stem_width * (cfg.n_mels + cfg.d_model) * cfg.d_model
            p += 2 * cfg.d_model
    if cfg.mtp_depth:
        p += cfg.mtp_depth * (layer_params(cfg, cfg.n_layers - 1)
                              + 2 * cfg.d_model * cfg.d_model)
    return p


def active_param_count(cfg: ModelConfig) -> int:
    p = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        p += cfg.padded_vocab * cfg.d_model
    for i in range(cfg.n_layers):
        p += layer_active_params(cfg, i)
    if cfg.n_encoder_layers:
        p += cfg.n_encoder_layers * (_attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff, cfg.act))
        p += cfg.n_layers * _attn_params(cfg)
        if cfg.n_mels:
            p += cfg.stem_width * (cfg.n_mels + cfg.d_model) * cfg.d_model
            p += 2 * cfg.d_model
    return p


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the roofline's usefulness ratio.

    train: 6 * N_active * tokens (fwd 2x + bwd 4x), the assignment's formula.
    prefill: 2 * N_active * tokens.
    decode: 2 * N_active * tokens (one token per sequence).
    Attention score/value FLOPs are excluded here by convention (6ND), and
    reported separately by `attention_flops`.
    """
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Score+value matmul FLOPs (the part 6ND misses)."""
    if cfg.family == "ssm":
        return 0.0
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.block_kind(i) == "attn")
    dh = cfg.d_head if not cfg.use_mla else (cfg.qk_nope_dim + cfg.qk_rope_dim)
    h = cfg.n_heads
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        ctx = min(s, cfg.attn_window or s)
        fl = 2.0 * 2.0 * h * dh * ctx * b * n_attn    # scores + values per token
        return fl
    ctx = s if cfg.attn_window is None else min(s, cfg.attn_window)
    # causal: ~ S * ctx / 2 pairs
    pairs = b * s * ctx * (0.5 if cfg.attn_window is None else 1.0)
    fl = 2.0 * 2.0 * h * dh * pairs * n_attn
    if shape.kind == "train":
        fl *= 3.0                                     # bwd recompute ~2x fwd
    return fl


def weight_bytes(cfg: ModelConfig, bytes_per_param: float = 2.0) -> float:
    return param_count(cfg) * bytes_per_param


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig, dtype_bytes: int = 2) -> float:
    """Bytes of per-step recurrent state / KV cache read by one decode step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        per = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state + cfg.d_inner * cfg.ssm_conv_width
        return float(b * cfg.n_layers * per * dtype_bytes)
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.block_kind(i)
        if kind == "rglru":
            total += b * cfg.lru_width * dtype_bytes
        elif kind == "attn":
            ctx = min(s, cfg.attn_window or s)
            if cfg.use_mla:
                total += b * ctx * (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
            else:
                total += 2 * b * ctx * cfg.n_kv_heads * cfg.d_head * dtype_bytes
    if cfg.n_encoder_layers:
        total += 2 * b * cfg.encoder_len * cfg.n_kv_heads * cfg.d_head * dtype_bytes * cfg.n_layers
    return float(total)


# ---------------------------------------------------------------------------
# Coarse op graph for the segmenter (paper §5.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpCost:
    """One operation node in the placement graph."""

    name: str
    flops: float
    bytes: float          # activation + weight bytes moved at fp16


def op_graph(cfg: ModelConfig, shape: ShapeConfig) -> list[OpCost]:
    """A coarse per-op sequence (one layer unrolled per distinct kind +
    embed/head), enough for the Dijkstra segmenter to place realistically."""
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    tokens = b * s
    d = cfg.d_model
    ops: list[OpCost] = [OpCost("embed", 0.0, tokens * d * 2.0)]
    for i in range(min(cfg.n_layers, 6)):             # representative prefix
        kind = cfg.block_kind(i)
        act_bytes = tokens * d * 2.0
        if kind == "ssm":
            p = _ssm_params(cfg)
            ops.append(OpCost(f"L{i}.ssd", 2.0 * p * tokens, act_bytes + p * 2.0))
        elif kind == "rglru":
            p = _rglru_params(cfg)
            ops.append(OpCost(f"L{i}.rglru", 2.0 * p * tokens, act_bytes + p * 2.0))
        else:
            p = _attn_params(cfg)
            ctx = shape.seq_len if shape.kind == "decode" else s
            ctx = min(ctx, cfg.attn_window or ctx)
            dh = cfg.d_head if not cfg.use_mla else (cfg.qk_nope_dim + cfg.qk_rope_dim)
            score_fl = 4.0 * cfg.n_heads * dh * ctx * tokens
            ops.append(OpCost(f"L{i}.qkv", 2.0 * p * tokens, act_bytes + p * 2.0))
            ops.append(OpCost(f"L{i}.attn", score_fl,
                              act_bytes + 2.0 * b * ctx * cfg.n_kv_heads * max(dh, 1) * 2.0))
        if cfg.layer_is_moe(i):
            pe = cfg.experts_per_token * _mlp_params(d, cfg.d_ff_expert, cfg.act)
            stored = cfg.n_experts * _mlp_params(d, cfg.d_ff_expert, cfg.act)
            ops.append(OpCost(f"L{i}.moe", 2.0 * pe * tokens,
                              act_bytes + min(stored, pe * max(tokens, 1)) * 2.0))
        else:
            p = _mlp_params(d, cfg.d_ff, cfg.act)
            ops.append(OpCost(f"L{i}.mlp", 2.0 * p * tokens, act_bytes + p * 2.0))
        ops.append(OpCost(f"L{i}.norm", 10.0 * tokens * d, 2 * act_bytes))
    ops.append(OpCost("logits", 2.0 * tokens * d * cfg.padded_vocab,
                      tokens * d * 2.0 + d * cfg.padded_vocab * 2.0))
    return ops
