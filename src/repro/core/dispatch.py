"""Compile-once / dispatch-many: program cache, execution streams, residency.

The paper's execution model (ch. 2, 5, 6): work reaches the engine in two
phases whose costs are far apart. The compile phase lowers and lays out once,
keyed by a content hash (`model.anehash` is a double SHA-256 over the
program; two structurally identical compiles hit the cache). The dispatch
phase binds operands and posts one command; a buffer can stay resident across
dispatches so KV caches and optimizer state never round-trip the host.

The XLA mapping:
  * program cache          -> our ProgramCache keyed by double-SHA256 of the
                              (jaxpr text, shapes, shardings, options)
  * load_for_execution     -> lowered.compile()
  * resident buffers       -> donated arguments (the output aliases the input
                              buffer, XLA's form of output->input port binding)
  * execution stream       -> ExecutionStream with dispatch-floor accounting
  * op-by-device routing   -> KernelDispatcher over the kernel registry:
                              capability-gated Pallas kernel, oracle fallback
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
import time
from collections import deque
from typing import Any, Callable, Hashable

import jax
import jax.numpy as jnp

from repro.core import hal


def content_hash(fn: Callable, args_spec: Any, options: str = "") -> str:
    """Double SHA-256 over the traced program + shapes + options — the
    paper's cacheURLIdentifier/anehash scheme (§5.6): identical structure and
    options resolve to the same key; changing any shape, op, device mask, or
    option changes it."""
    try:
        jaxpr = jax.make_jaxpr(fn)(*args_spec)
        # custom_vjp params print closure objects with their memory address;
        # scrub addresses so structurally identical traces hash identically
        body = re.sub(r"0x[0-9a-f]+", "0x", str(jaxpr))
    except Exception:  # fall back to function identity + specs
        body = f"{getattr(fn, '__name__', repr(fn))}"
    spec_txt = str(jax.tree.map(
        lambda x: (getattr(x, "shape", None), str(getattr(x, "dtype", None))),
        args_spec))
    inner = hashlib.sha256((body + spec_txt + options).encode()).digest()
    return hashlib.sha256(inner).hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    compile_seconds: float = 0.0


class ProgramCache:
    """Content-addressed compiled-program cache (one per process, like the
    daemon's on-disk e5bundlecache; ours is in-memory, keyed the same way)."""

    def __init__(self) -> None:
        self._programs: dict[str, Any] = {}
        # (fn, treedef, avals) -> content hash: a warm-start lookup must not
        # pay the full retrace content_hash performs (the jaxpr of a repeat
        # call is determined by the function + arg structure/avals)
        self._hash_memo: dict[Any, tuple[str, Callable]] = {}
        self.stats = CacheStats()

    def _key(self, fn: Callable, args_spec, options: str) -> str:
        leaves, treedef = jax.tree.flatten(args_spec)
        specs = tuple((getattr(x, "shape", None), str(getattr(x, "dtype", None)))
                      for x in leaves)
        # bound methods are re-created per attribute access: key on the
        # underlying function + receiver id (the receiver is pinned in the
        # memo value, so the id cannot be recycled while the entry lives)
        fast = (getattr(fn, "__func__", fn), id(getattr(fn, "__self__", None)),
                treedef, specs, options)
        try:
            hit = self._hash_memo.get(fast)
        except TypeError:               # unhashable leaf/aux somewhere
            return content_hash(fn, args_spec, options)
        if hit is not None:
            return hit[0]
        key = content_hash(fn, args_spec, options)
        self._hash_memo[fast] = (key, fn)
        return key

    def compile(self, fn: Callable, *args_spec, options: str = "",
                force_recompilation: bool = False, jit_kwargs: dict | None = None):
        """compile-or-hit. `force_recompilation` defeats the warm start and
        rewrites the entry unconditionally (the paper's documented inverse of
        force_fetch_from_cache)."""
        key = self._key(fn, args_spec, options)
        if not force_recompilation and key in self._programs:
            self.stats.hits += 1
            return self._programs[key], key
        t0 = time.perf_counter()
        jitted = jax.jit(fn, **(jit_kwargs or {}))
        compiled = jitted.lower(*args_spec).compile()
        self.stats.compile_seconds += time.perf_counter() - t0
        self.stats.misses += 1
        self._programs[key] = compiled
        return compiled, key

    def is_new_compile_required(self, fn: Callable, *args_spec,
                                options: str = "") -> bool:
        return self._key(fn, args_spec, options) not in self._programs

    def purge(self) -> None:
        self._programs.clear()
        self._hash_memo.clear()


@dataclasses.dataclass
class DispatchRecord:
    key: str
    wall_s: float
    work_s: float          # wall minus the costmodel floor estimate, >= 0
    floor_s: float = 0.0   # the per-dispatch floor charged against this call
    queue_depth: int = 0   # ops already encoded ahead of this one at encode time
    batch: int = 1         # samples this dispatch carried (amortization denom)
    seq: int = 0           # submission index on this stream (total order)


class ExecutionStream:
    """One dispatch queue with per-call floor accounting (paper §2.3/§9.3).

    The engine keeps one command in flight (submissions serialize, §2.4);
    a jit stream behaves the same way per device. `execute_sync` measures the
    per-call wall time so the dispatch-floor benchmark can isolate t0 exactly
    the way the paper's slope method does. Each record carries the costmodel
    floor estimate of its target (`Target.dispatch_floor_s`), so
    `work_s = max(0, wall - floor)` splits every dispatch into fixed overhead
    and useful work — the split the batching scheduler amortizes (§9.4).
    """

    def __init__(self, cache: ProgramCache | None = None, *,
                 target: hal.Target | None = None,
                 floor_s: float | None = None) -> None:
        self.cache = cache or ProgramCache()
        self.target = target or hal.TPU_V5E
        self.floor_s = self.target.dispatch_floor_s if floor_s is None \
            else floor_s
        self.records: list[DispatchRecord] = []
        self._encoded: list[tuple[Any, tuple, dict, str, int, int]] = []
        self._seq = 0

    @property
    def queue_depth(self) -> int:
        """Ops encoded but not yet executed."""
        return len(self._encoded)

    def encode_operation(self, compiled, args: tuple, key: str = "",
                         kwargs: dict | None = None, *,
                         batch: int = 1) -> None:
        """Queue one compiled program. `batch` is the number of samples the
        dispatch carries — the denominator of per-sample floor amortization."""
        self._encoded.append((compiled, args, kwargs or {}, key, batch,
                              len(self._encoded)))

    def execute_sync(self) -> list:
        """Run everything encoded, in order, blocking (the sound default the
        paper recommends; overlapping streams is the unfinished path).
        Always returns a list of outputs, one per encoded op, in encode
        order — including for a single op."""
        outs = []
        for compiled, args, kwargs, key, batch, depth in self._encoded:
            t0 = time.perf_counter()
            out = compiled(*args, **kwargs)
            out = jax.block_until_ready(out)
            wall = time.perf_counter() - t0
            self.records.append(DispatchRecord(
                key, wall, max(0.0, wall - self.floor_s), self.floor_s,
                depth, batch, self._seq))
            self._seq += 1
            outs.append(out)
        self._encoded.clear()
        return outs

    # -- floor accounting over the record log -------------------------------
    def total_floor_s(self) -> float:
        """Fixed dispatch cost accumulated so far (#dispatches x floor)."""
        return sum(r.floor_s for r in self.records)

    def total_work_s(self) -> float:
        return sum(r.work_s for r in self.records)

    def reset(self) -> None:
        self._encoded.clear()


def resident(fn: Callable, state_argnums: int | tuple[int, ...]):
    """Mark state arguments resident: the output buffer aliases the input
    buffer across dispatches (paper §2.6 output->input port binding). In XLA
    this is argument donation; the held tensor never re-crosses the host."""
    if isinstance(state_argnums, int):
        state_argnums = (state_argnums,)
    return jax.jit(fn, donate_argnums=state_argnums)


# ---------------------------------------------------------------------------
# Registry-routed kernel dispatch (the paper's operation-by-device matrix)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelRoute:
    """One resolved cell of the operation-by-device matrix."""

    kernel: str
    target: str
    dtype: str
    backend: str           # "pallas" | "oracle"
    reason: str            # why the fallback fired ("" for the native path)

    @property
    def native(self) -> bool:
        return self.backend == "pallas"


class KernelDispatcher:
    """Route kernel calls through the registry with capability-gated fallback.

    The paper's rule (§4): an operation runs on the engine only when the
    layer that executes it accepts it — everything else falls back, silently,
    to the next backend. Here: a registered Pallas kernel runs natively when
    the target's op floor reaches its capability op, the weight form it
    streams actually streams on that target, and the activation dtype is one
    the kernel (and the target's datapath) carries. Any miss routes to the
    kernel's ref oracle — same arithmetic, dense bytes — and the route taken
    is recorded so `matrix()` can print the census.
    """

    # retained route records per dispatcher — enough for any census/debug
    # readout while keeping a serving-loop dispatcher O(1) in memory
    ROUTE_LOG_LIMIT = 4096

    def __init__(self, target: hal.Target | None = None) -> None:
        self.target = target or hal.TPU_V5E
        self.routes: deque[KernelRoute] = deque(maxlen=self.ROUTE_LOG_LIMIT)

    # -- routing decision ---------------------------------------------------
    def resolve(self, name: str, dtype: Any = jnp.float32) -> KernelRoute:
        from repro.kernels import registry   # lazy: keep core importable alone

        spec = registry.get(name)
        t = self.target
        dt = jnp.dtype(dtype).name
        reason = ""
        if dt not in {jnp.dtype(d).name for d in spec.dtypes}:
            reason = f"dtype {dt} outside kernel surface"
        elif not t.attests(spec.capability_op):
            reason = f"{spec.capability_op}: not in the {t.generation} op table"
        elif not t.reaches(spec.capability_op):
            reason = f"{spec.capability_op}: attested but fails lowering"
        elif spec.weight_form is not None and not t.streams(spec.weight_form):
            reason = f"{spec.weight_form.value}: folds on {t.generation}"
        elif not t.supports_dtype(dt):
            reason = (f"{dt} is not native on {t.generation} "
                      f"({t.native_dtype} datapath)")
        backend = "oracle" if reason else "pallas"
        return KernelRoute(name, t.name, dt, backend, reason)

    # -- execution ----------------------------------------------------------
    def __call__(self, name: str, inputs: dict) -> Any:
        """Run kernel `name` on `inputs` (the registry's input bundle),
        through the Pallas path when the target reaches it, else the oracle."""
        from repro.kernels import registry

        spec = registry.get(name)
        route = self.resolve(name, _bundle_dtype(inputs))
        self.routes.append(route)
        if route.native:
            return spec.run_kernel(inputs)
        return spec.run_oracle(inputs)

    # -- the census ---------------------------------------------------------
    def matrix(self, dtype: Any = jnp.float32) -> list[KernelRoute]:
        """One row per registered kernel: the op-by-device matrix column for
        this target (paper Appendix A shape, kernel-registry rows)."""
        from repro.kernels import registry

        return [self.resolve(n, dtype) for n in registry.names()]


def _bundle_dtype(inputs: dict) -> Any:
    """The activation dtype of a registry input bundle: the first floating
    jnp array wins (weights/selectors are integer side tables)."""
    for v in inputs.values():
        dt = getattr(v, "dtype", None)
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            return dt
    return jnp.float32


def kernel_matrix(targets: list[hal.Target] | None = None,
                  dtype: Any = jnp.float32) -> list[KernelRoute]:
    """The full operation-by-device matrix across targets — every registered
    kernel x every HAL target, each cell a capability-resolved route."""
    targets = targets or list(hal.TARGETS.values())
    rows: list[KernelRoute] = []
    for t in targets:
        rows.extend(KernelDispatcher(t).matrix(dtype))
    return rows


def measure_dispatch_floor(n: int = 200) -> dict[str, float]:
    """Isolate t0 on this host the way the paper does (§2.3): a tiny program
    in a hot loop; the floor is the wall time with negligible work. Returns
    the stage split we can observe from user space."""
    import jax.numpy as jnp

    x = jnp.ones((8, 8), jnp.float32)
    f = jax.jit(lambda a: (a * 1.0).sum())
    f(x).block_until_ready()                      # warm
    t0 = time.perf_counter()
    for _ in range(n):
        f(x).block_until_ready()
    per_call = (time.perf_counter() - t0) / n
    # trace-dispatch split: calling with donated/aot compiled skips tracing
    g = jax.jit(lambda a: (a * 1.0).sum()).lower(x).compile()
    g(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        g(x).block_until_ready()
    aot_call = (time.perf_counter() - t0) / n
    return {"per_call_s": per_call, "aot_call_s": aot_call,
            "python_overhead_s": per_call - aot_call}
