"""Compile-once / dispatch-many: program cache, execution streams, residency.

The paper's execution model (ch. 2, 5, 6): work reaches the engine in two
phases whose costs are far apart. The compile phase lowers and lays out once,
keyed by a content hash (`model.anehash` is a double SHA-256 over the
program; two structurally identical compiles hit the cache). The dispatch
phase binds operands and posts one command; a buffer can stay resident across
dispatches so KV caches and optimizer state never round-trip the host.

The XLA mapping:
  * program cache          -> our ProgramCache keyed by double-SHA256 of the
                              (jaxpr text, shapes, shardings, options)
  * load_for_execution     -> lowered.compile()
  * resident buffers       -> donated arguments (the output aliases the input
                              buffer, XLA's form of output->input port binding)
  * execution stream       -> ExecutionStream with dispatch-floor accounting
  * overlapping streams    -> AsyncExecutionStream: encode -> submit -> sync
                              with a bounded in-flight window (the firmware
                              drains command buffers while the host encodes)
  * op-by-device routing   -> KernelDispatcher over the kernel registry:
                              capability-gated Pallas kernel, oracle fallback
"""

from __future__ import annotations

import dataclasses
import hashlib
import queue as queue_mod
import re
import threading
import time
import weakref
from collections import deque
from typing import Any, Callable, Hashable

import jax
import jax.numpy as jnp

from repro.core import hal


def content_hash(fn: Callable, args_spec: Any, options: str = "") -> str:
    """Double SHA-256 over the traced program + shapes + options — the
    paper's cacheURLIdentifier/anehash scheme (§5.6): identical structure and
    options resolve to the same key; changing any shape, op, device mask, or
    option changes it."""
    try:
        jaxpr = jax.make_jaxpr(fn)(*args_spec)
        # custom_vjp params print closure objects with their memory address;
        # scrub addresses so structurally identical traces hash identically
        body = re.sub(r"0x[0-9a-f]+", "0x", str(jaxpr))
    except Exception:  # fall back to function identity + specs
        body = f"{getattr(fn, '__name__', repr(fn))}"
    spec_txt = str(jax.tree.map(
        lambda x: (getattr(x, "shape", None), str(getattr(x, "dtype", None))),
        args_spec))
    inner = hashlib.sha256((body + spec_txt + options).encode()).digest()
    return hashlib.sha256(inner).hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    compile_seconds: float = 0.0


class ProgramCache:
    """Content-addressed compiled-program cache (one per process, like the
    daemon's on-disk e5bundlecache; ours is in-memory, keyed the same way)."""

    def __init__(self) -> None:
        self._programs: dict[str, Any] = {}
        # (fn, treedef, avals) -> content hash: a warm-start lookup must not
        # pay the full retrace content_hash performs (the jaxpr of a repeat
        # call is determined by the function + arg structure/avals)
        self._hash_memo: dict[Any, tuple[str, Callable]] = {}
        self.stats = CacheStats()

    def _key(self, fn: Callable, args_spec, options: str) -> str:
        leaves, treedef = jax.tree.flatten(args_spec)
        specs = tuple((getattr(x, "shape", None), str(getattr(x, "dtype", None)))
                      for x in leaves)
        # bound methods are re-created per attribute access: key on the
        # underlying function + receiver id (the receiver is pinned in the
        # memo value, so the id cannot be recycled while the entry lives)
        fast = (getattr(fn, "__func__", fn), id(getattr(fn, "__self__", None)),
                treedef, specs, options)
        try:
            hit = self._hash_memo.get(fast)
        except TypeError:               # unhashable leaf/aux somewhere
            return content_hash(fn, args_spec, options)
        if hit is not None:
            return hit[0]
        key = content_hash(fn, args_spec, options)
        self._hash_memo[fast] = (key, fn)
        return key

    def compile(self, fn: Callable, *args_spec, options: str = "",
                force_recompilation: bool = False, jit_kwargs: dict | None = None):
        """compile-or-hit. `force_recompilation` defeats the warm start and
        rewrites the entry unconditionally (the paper's documented inverse of
        force_fetch_from_cache)."""
        key = self._key(fn, args_spec, options)
        if not force_recompilation and key in self._programs:
            self.stats.hits += 1
            return self._programs[key], key
        t0 = time.perf_counter()
        jitted = jax.jit(fn, **(jit_kwargs or {}))
        compiled = jitted.lower(*args_spec).compile()
        self.stats.compile_seconds += time.perf_counter() - t0
        self.stats.misses += 1
        self._programs[key] = compiled
        return compiled, key

    def is_new_compile_required(self, fn: Callable, *args_spec,
                                options: str = "") -> bool:
        return self._key(fn, args_spec, options) not in self._programs

    def purge(self) -> None:
        self._programs.clear()
        self._hash_memo.clear()


@dataclasses.dataclass
class DispatchRecord:
    key: str
    wall_s: float
    work_s: float          # wall minus the costmodel floor estimate, >= 0
    floor_s: float = 0.0   # the per-dispatch floor charged against this call
    queue_depth: int = 0   # ops already encoded ahead of this one at encode time
    batch: int = 1         # samples this dispatch carried (amortization denom)
    seq: int = 0           # submission index on this stream (total order)
    submit_ts: float = 0.0     # perf_counter at submission (host hand-off)
    complete_ts: float = 0.0   # perf_counter when the drain saw it complete
    inflight_depth: int = 0    # ops submitted and not yet complete at submit
                               # time: 0 on a sync stream, < window on async
    span: tuple[int, int] | None = None
                               # token span [lo, hi) this dispatch covered —
                               # set by chunked prefill so the bench can
                               # audit that chunks tile the prompt and each
                               # one was floor-charged; None elsewhere


class ExecutionStream:
    """One dispatch queue with per-call floor accounting (paper §2.3/§9.3).

    The engine keeps one command in flight (submissions serialize, §2.4);
    a jit stream behaves the same way per device. `execute_sync` measures the
    per-call wall time so the dispatch-floor benchmark can isolate t0 exactly
    the way the paper's slope method does. Each record carries the costmodel
    floor estimate of its target (`Target.dispatch_floor_s`), so
    `work_s = max(0, wall - floor)` splits every dispatch into fixed overhead
    and useful work — the split the batching scheduler amortizes (§9.4).
    """

    def __init__(self, cache: ProgramCache | None = None, *,
                 target: hal.Target | None = None,
                 floor_s: float | None = None) -> None:
        self.cache = cache or ProgramCache()
        self.target = target or hal.TPU_V5E
        self.floor_s = self.target.dispatch_floor_s if floor_s is None \
            else floor_s
        self.records: list[DispatchRecord] = []
        self._encoded: list[tuple[Any, tuple, dict, str, int, int, Any]] = []
        self._seq = 0

    @property
    def queue_depth(self) -> int:
        """Ops encoded but not yet executed."""
        return len(self._encoded)

    def encode_operation(self, compiled, args: tuple, key: str = "",
                         kwargs: dict | None = None, *,
                         batch: int = 1,
                         span: tuple[int, int] | None = None) -> None:
        """Queue one compiled program. `batch` is the number of samples the
        dispatch carries — the denominator of per-sample floor amortization.
        `span` tags the token range a chunked-prefill dispatch covers."""
        self._encoded.append((compiled, args, kwargs or {}, key, batch,
                              len(self._encoded), span))

    def execute_sync(self) -> list:
        """Run everything encoded, in order, blocking (the sound default the
        paper recommends; overlapping streams is the unfinished path).
        Always returns a list of outputs, one per encoded op, in encode
        order — including for a single op."""
        outs = []
        for compiled, args, kwargs, key, batch, depth, span in self._encoded:
            t0 = time.perf_counter()
            out = compiled(*args, **kwargs)
            out = jax.block_until_ready(out)
            t1 = time.perf_counter()
            wall = t1 - t0
            self.records.append(DispatchRecord(
                key, wall, max(0.0, wall - self.floor_s), self.floor_s,
                depth, batch, self._seq, submit_ts=t0, complete_ts=t1,
                span=span))
            self._seq += 1
            outs.append(out)
        self._encoded.clear()
        return outs

    # -- floor accounting over the record log -------------------------------
    def total_floor_s(self) -> float:
        """Fixed dispatch cost accumulated so far (#dispatches x floor)."""
        return sum(r.floor_s for r in self.records)

    def total_work_s(self) -> float:
        return sum(r.work_s for r in self.records)

    def reset(self) -> None:
        self._encoded.clear()


@dataclasses.dataclass
class _Inflight:
    """One submitted-but-unconfirmed dispatch: the record being timed, the
    (possibly still executing) outputs, and the completion latch."""

    record: DispatchRecord
    out: Any
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    error: BaseException | None = None


def _drain_loop(stream_ref, drain_q) -> None:
    """Background drain: confirm in-flight dispatches in submission order via
    `jax.block_until_ready`, stamp completion, and retire them to the record
    log. Runs as a daemon thread holding only a weakref to the stream so a
    dropped stream (plus its finalizer sentinel) shuts the thread down.

    A leaf that was donated forward into the *next* submission raises
    "deleted or donated buffer" on sync — completion of the consumer implies
    completion of the producer, so those leaves are skipped and the
    non-donated leaves (tokens, logits, scalars) carry the timestamp."""
    while True:
        h = drain_q.get()
        if h is None:
            return
        try:
            for leaf in jax.tree.leaves(h.out):
                try:
                    if hasattr(leaf, "block_until_ready"):
                        leaf.block_until_ready()
                except Exception as e:
                    msg = str(e).lower()
                    if "donated" not in msg and "deleted" not in msg:
                        raise
        except BaseException as e:  # surface on the next sync()
            h.error = e
        t = time.perf_counter()
        stream = stream_ref()
        if stream is None:
            h.done.set()
            return
        r = h.record
        r.complete_ts = t
        r.wall_s = t - r.submit_ts
        r.work_s = max(0.0, r.wall_s - r.floor_s)
        with stream._lock:
            stream.records.append(r)
            if h.error is not None:
                stream._errors.append(h.error)
            try:                      # FIFO: h is the leftmost entry
                stream._pending.remove(h)
            except ValueError:        # pragma: no cover - defensive
                pass
        h.done.set()
        del stream, h, r   # no strong refs held while parked on the queue


class AsyncExecutionStream(ExecutionStream):
    """Overlapped dispatch: encode -> submit -> sync with a bounded in-flight
    window (paper §2.4's open overlapping-streams path).

    The sound default (`ExecutionStream.execute_sync`) serializes: every
    dispatch pays its floor with the host idle in between. This stream keeps
    the host encoding while the device drains, the way the firmware drains
    command buffers while the host keeps encoding:

      * **double-buffered submission queues** — `encode_operation` fills the
        encode queue; `submit` hands each op to the device without blocking
        and moves it to the in-flight queue. With the default window of 2
        the device executes one submission while the host encodes the next.
      * **bounded in-flight window** — `submit` throttles when
        `max_in_flight` submissions are unconfirmed, so run-ahead (and
        resident-buffer lifetime) stays bounded.
      * **background drain** — a daemon thread confirms completions in
        submission order via `jax.block_until_ready`, stamping
        `complete_ts` and retiring the `DispatchRecord`. Floor accounting
        stays truthful: every dispatch still charges the costmodel floor
        once, and `wall_s = complete_ts - submit_ts` now *includes* the
        overlap (two overlapped dispatches show overlapping [submit,
        complete] intervals instead of summed walls).

    Outputs returned by `submit` are live JAX arrays (async futures): they
    can be fed straight into the next encoded op to chain device work
    without a host round-trip. `sync` is the barrier; `execute_sync`
    degenerates to submit-then-sync so the base contract holds.
    """

    def __init__(self, cache: ProgramCache | None = None, *,
                 target: hal.Target | None = None,
                 floor_s: float | None = None,
                 max_in_flight: int = 2) -> None:
        super().__init__(cache, target=target, floor_s=floor_s)
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        self.max_in_flight = max_in_flight
        self._pending: deque[_Inflight] = deque()
        self._errors: list[BaseException] = []
        self._lock = threading.Lock()
        self._drain_q: queue_mod.SimpleQueue = queue_mod.SimpleQueue()
        self._drainer: threading.Thread | None = None

    # -- window state -------------------------------------------------------
    @property
    def in_flight_depth(self) -> int:
        """Submissions handed to the device and not yet confirmed complete."""
        with self._lock:
            return len(self._pending)

    def _ensure_drainer(self) -> None:
        if self._drainer is None or not self._drainer.is_alive():
            self._drainer = threading.Thread(
                target=_drain_loop, args=(weakref.ref(self), self._drain_q),
                name="stream-drain", daemon=True)
            # a dropped stream must not strand the drain thread
            weakref.finalize(self, self._drain_q.put, None)
            self._drainer.start()

    def _throttle(self) -> None:
        """Block until the in-flight window has a free slot."""
        while True:
            with self._lock:
                if len(self._pending) < self.max_in_flight:
                    return
                oldest = self._pending[0]
            oldest.done.wait()

    # -- encode -> submit -> sync -------------------------------------------
    def submit(self) -> list:
        """Hand every encoded op to the device without waiting for results.
        Returns the per-op outputs in encode order — live async values,
        usable immediately as inputs of the next encoded op."""
        self._ensure_drainer()
        outs = []
        for compiled, args, kwargs, key, batch, depth, span in self._encoded:
            self._throttle()
            with self._lock:
                depth_now = len(self._pending)
            t_sub = time.perf_counter()
            out = compiled(*args, **kwargs)     # async dispatch: returns now
            rec = DispatchRecord(
                key, 0.0, 0.0, self.floor_s, depth, batch, self._seq,
                submit_ts=t_sub, inflight_depth=depth_now, span=span)
            self._seq += 1
            h = _Inflight(rec, out)
            with self._lock:
                self._pending.append(h)
            self._drain_q.put(h)
            outs.append(out)
        self._encoded.clear()
        return outs

    def sync(self) -> list:
        """Barrier: wait until every in-flight submission is confirmed.
        Returns the outputs of the ops that were still in flight, in
        submission order; re-raises any execution error the drain saw."""
        with self._lock:
            handles = list(self._pending)
        for h in handles:
            h.done.wait()
        with self._lock:
            errors, self._errors = list(self._errors), []
        if errors:
            raise errors[0]
        return [h.out for h in handles]

    def execute_sync(self) -> list:
        """The base contract: run everything encoded, in order, blocking.
        Drains the in-flight window first so the record order stays total,
        then runs inline — a barrier gains nothing from the drain thread,
        and skipping it keeps per-dispatch admissions off the wakeup path."""
        self.sync()
        return super().execute_sync()

    def close(self) -> None:
        """Drain outstanding work and stop the background thread."""
        self.sync()
        if self._drainer is not None and self._drainer.is_alive():
            self._drain_q.put(None)
            self._drainer.join(timeout=5.0)
            self._drainer = None


def resident(fn: Callable, state_argnums: int | tuple[int, ...]):
    """Mark state arguments resident: the output buffer aliases the input
    buffer across dispatches (paper §2.6 output->input port binding). In XLA
    this is argument donation; the held tensor never re-crosses the host."""
    if isinstance(state_argnums, int):
        state_argnums = (state_argnums,)
    return jax.jit(fn, donate_argnums=state_argnums)


# ---------------------------------------------------------------------------
# Registry-routed kernel dispatch (the paper's operation-by-device matrix)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelRoute:
    """One resolved cell of the operation-by-device matrix."""

    kernel: str
    target: str
    dtype: str
    backend: str           # "pallas" | "oracle"
    reason: str            # why the fallback fired ("" for the native path)

    @property
    def native(self) -> bool:
        return self.backend == "pallas"


class KernelDispatcher:
    """Route kernel calls through the registry with capability-gated fallback.

    The paper's rule (§4): an operation runs on the engine only when the
    layer that executes it accepts it — everything else falls back, silently,
    to the next backend. Here: a registered Pallas kernel runs natively when
    the target's op floor reaches its capability op, the weight form it
    streams actually streams on that target, and the activation dtype is one
    the kernel (and the target's datapath) carries. Any miss routes to the
    kernel's ref oracle — same arithmetic, dense bytes — and the route taken
    is recorded so `matrix()` can print the census.
    """

    # retained route records per dispatcher — enough for any census/debug
    # readout while keeping a serving-loop dispatcher O(1) in memory
    ROUTE_LOG_LIMIT = 4096

    def __init__(self, target: hal.Target | None = None) -> None:
        self.target = target or hal.TPU_V5E
        self.routes: deque[KernelRoute] = deque(maxlen=self.ROUTE_LOG_LIMIT)

    # -- routing decision ---------------------------------------------------
    def resolve(self, name: str, dtype: Any = jnp.float32) -> KernelRoute:
        from repro.kernels import registry   # lazy: keep core importable alone

        spec = registry.get(name)
        t = self.target
        dt = jnp.dtype(dtype).name
        reason = ""
        if dt not in {jnp.dtype(d).name for d in spec.dtypes}:
            reason = f"dtype {dt} outside kernel surface"
        elif not t.attests(spec.capability_op):
            reason = f"{spec.capability_op}: not in the {t.generation} op table"
        elif not t.reaches(spec.capability_op):
            reason = f"{spec.capability_op}: attested but fails lowering"
        elif spec.weight_form is not None and not t.streams(spec.weight_form):
            reason = f"{spec.weight_form.value}: folds on {t.generation}"
        elif not t.supports_dtype(dt):
            reason = (f"{dt} is not native on {t.generation} "
                      f"({t.native_dtype} datapath)")
        backend = "oracle" if reason else "pallas"
        return KernelRoute(name, t.name, dt, backend, reason)

    # -- execution ----------------------------------------------------------
    def __call__(self, name: str, inputs: dict) -> Any:
        """Run kernel `name` on `inputs` (the registry's input bundle),
        through the Pallas path when the target reaches it, else the oracle."""
        from repro.kernels import registry

        spec = registry.get(name)
        route = self.resolve(name, _bundle_dtype(inputs))
        self.routes.append(route)
        if route.native:
            return spec.run_kernel(inputs)
        return spec.run_oracle(inputs)

    # -- the census ---------------------------------------------------------
    def matrix(self, dtype: Any = jnp.float32) -> list[KernelRoute]:
        """One row per registered kernel: the op-by-device matrix column for
        this target (paper Appendix A shape, kernel-registry rows)."""
        from repro.kernels import registry

        return [self.resolve(n, dtype) for n in registry.names()]


def _bundle_dtype(inputs: dict) -> Any:
    """The activation dtype of a registry input bundle: the first floating
    jnp array wins (weights/selectors are integer side tables)."""
    for v in inputs.values():
        dt = getattr(v, "dtype", None)
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            return dt
    return jnp.float32


def kernel_matrix(targets: list[hal.Target] | None = None,
                  dtype: Any = jnp.float32) -> list[KernelRoute]:
    """The full operation-by-device matrix across targets — every registered
    kernel x every HAL target, each cell a capability-resolved route."""
    targets = targets or list(hal.TARGETS.values())
    rows: list[KernelRoute] = []
    for t in targets:
        rows.extend(KernelDispatcher(t).matrix(dtype))
    return rows


def measure_dispatch_floor(n: int = 200) -> dict[str, float]:
    """Isolate t0 on this host the way the paper does (§2.3): a tiny program
    in a hot loop; the floor is the wall time with negligible work. Returns
    the stage split we can observe from user space."""
    import jax.numpy as jnp

    x = jnp.ones((8, 8), jnp.float32)
    f = jax.jit(lambda a: (a * 1.0).sum())
    f(x).block_until_ready()                      # warm
    t0 = time.perf_counter()
    for _ in range(n):
        f(x).block_until_ready()
    per_call = (time.perf_counter() - t0) / n
    # trace-dispatch split: calling with donated/aot compiled skips tracing
    g = jax.jit(lambda a: (a * 1.0).sum()).lower(x).compile()
    g(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        g(x).block_until_ready()
    aot_call = (time.perf_counter() - t0) / n
    return {"per_call_s": per_call, "aot_call_s": aot_call,
            "python_overhead_s": per_call - aot_call}
