"""Hardware abstraction layer (HAL): per-target capability and roofline tables.

The paper reads the ANE's per-chip behavior out of a hardware-abstraction-layer
table: feature bytes that gate operations and compressed-weight streaming, shape
limits, core counts, and the roofline constants (ch. 1, 4, 7, 9, 12). This module
is that table, for two families of targets:

  * The ANE generations the paper decodes (H13/M1 ... H17s/M5) — used by the
    paper-faithful reproduction, the numerics oracle, and the compression gates.
  * The TPU targets we actually compile for (v5e, v5p) — used by the three-term
    roofline of the dry-run and the perf loop.

Every number carries its provenance in a comment: `paper:<table>` for values the
paper measures/decodes, `public` for public TPU datasheet values, `assignment`
for the constants fixed by the task statement.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Mapping


class WeightForm(enum.Enum):
    """Compressed-weight forms the datapath reconstructs (paper ch. 7)."""

    FP16 = "fp16"
    INT8 = "int8"                # per-tensor / per-channel affine
    INT4_PALETTE = "int4_palette"  # 16-entry fp16 codebook, 4-bit indices
    SPARSE = "sparse"            # keep-mask + packed fp16 nonzeros
    BLOCKWISE = "blockwise"      # per-block affine scales


# Stored bytes per weight element, including side tables, relative to fp16=2.0.
# paper:T7.4 (sparse 0.43x dense at ~63% zeros; int8 0.5x; int4 = 4 bit + codebook)
BYTES_PER_ELEMENT: Mapping[WeightForm, float] = {
    WeightForm.FP16: 2.0,
    WeightForm.INT8: 1.0,
    WeightForm.INT4_PALETTE: 0.5,     # + 32B codebook per channel group (amortized)
    WeightForm.SPARSE: 0.86,          # 0.43 x dense fp16 bytes (paper:T7.4), vs 2.0
    WeightForm.BLOCKWISE: 1.0625,     # int8 + per-32-block fp16 scale
}


@dataclasses.dataclass(frozen=True)
class ShapeLimits:
    """Per-generation kernel/tensor shape limits (paper:T4.3)."""

    max_kernel_width_default: int
    max_kernel_width_fp16: int
    max_tensor_extent: int        # per-axis cap (2^14 on M1; 2^16 on A16+)
    max_tensor_batch: int
    max_rank: int
    matmul_working_set_bytes: int  # the on-chip working-set threshold


@dataclasses.dataclass(frozen=True)
class Target:
    """One hardware target: roofline constants + capability surface."""

    name: str
    family: str                   # "ane" | "tpu"
    generation: str               # e.g. "H13", "v5e"
    # --- roofline constants ---
    peak_flops: float             # FLOP/s at the native wide-multiply dtype
    hbm_bandwidth: float          # bytes/s, DRAM/HBM roof (B)
    link_bandwidth: float         # bytes/s per ICI link (0 for single-chip ANE)
    num_links: int
    onchip_bytes: int             # ANE working set / TPU VMEM budget per core
    dispatch_floor_s: float       # per-dispatch fixed cost t0
    energy_pj_per_flop: float     # at the compute optimum
    energy_pj_per_flop_sustained: float
    native_dtype: str             # multiply dtype: fp16 (ANE) / bf16 (TPU)
    cores: int                    # architectural core count (HAL 0x238 on ANE)
    # --- capability surface ---
    feature_bytes: Mapping[str, int]      # named HAL gate bytes -> 0/1
    weight_streams: Mapping[WeightForm, bool]  # stream (True) vs fold (False)
    op_floor: Mapping[str, bool]          # op name -> reachable on this target
    limits: ShapeLimits

    # ------------------------------------------------------------------
    @property
    def ridge_flop_per_byte(self) -> float:
        """I* = P / B (paper:§9.1)."""
        return self.peak_flops / self.hbm_bandwidth

    @property
    def collective_bandwidth(self) -> float:
        """Aggregate per-chip ICI bytes/s (all links)."""
        return self.link_bandwidth * max(self.num_links, 1)

    def streams(self, form: WeightForm) -> bool:
        """Does `form` stream compressed bytes (vs fold to dense fp16)?

        paper:T7.1/T7.2 — the stream-vs-fold split is a HAL decision read from
        the per-chip feature bytes, not a property of the reconstruction op.
        """
        return self.weight_streams.get(form, False)

    def supports_dtype(self, dtype_name: str) -> bool:
        """Does this target's datapath carry `dtype_name` activations?

        fp32 is universal (every engine widens); narrow dtypes must match
        the native multiply dtype on single-dtype engines (the ANE's fp16
        datapath has no bf16 path — paper §3.1), while the TPU MXU takes
        both 16-bit forms."""
        if dtype_name == "float32":
            return True
        if self.family == "tpu":
            return dtype_name in ("bfloat16", "float16")
        return dtype_name == self.native_dtype

    def attests(self, op: str) -> bool:
        """Capability *attestation* — a claim about one layer (paper §4.4).

        Deliberately includes ops that are attested but NOT reachable
        (conv3d on every ANE family); `core.capability.confirm_op` is the
        compile-and-run check that tells them apart.
        """
        return op in self.op_floor

    def reaches(self, op: str) -> bool:
        """Ground truth the validator should agree with after confirm_op."""
        return self.op_floor.get(op, False)


# ----------------------------------------------------------------------------
# ANE generations (paper-faithful). All constants paper:T1.3/T3.3/T4.3/T7.1/T9.2.
# ----------------------------------------------------------------------------

_ANE_LIMITS_H13 = ShapeLimits(
    max_kernel_width_default=29, max_kernel_width_fp16=13,
    max_tensor_extent=16384, max_tensor_batch=65536, max_rank=5,
    matmul_working_set_bytes=2 * 1024 * 1024,
)
_ANE_LIMITS_H14 = dataclasses.replace(_ANE_LIMITS_H13, max_kernel_width_default=32,
                                      max_kernel_width_fp16=16)
_ANE_LIMITS_H16 = dataclasses.replace(_ANE_LIMITS_H14, max_tensor_extent=65536)

# Ops used for the attested-vs-reachable census (paper ch.4 + Appendix A shape).
# True = compiles and runs; a key that is PRESENT but False is "attested only".
_ANE_OPS_COMMON = {
    "conv2d": True, "conv2d_transpose": True, "depthwise_conv2d": True,
    "matmul": True, "linear": True, "attention_fused": True,
    "layer_norm": True, "instance_norm": True, "group_norm": True,
    "batch_norm_folded": True, "l2_norm": True,
    "avg_pool": True, "max_pool": True,
    "relu": True, "sigmoid": True, "tanh": True, "gelu": True, "swish": True,
    "softmax": True, "erf": True, "exp": True, "log": True,
    "argmax": True,   # hw argmax port, gated by feature byte 0x4f2_argmax_hw
    "reshape": True, "transpose": True, "concat": True, "split": True,
    "pad": True, "slice": True, "cumsum": True,
    # attested-but-unreachable (paper §4.4: capability byte set, lowering fails)
    "conv3d": False,
    # no hardware path on any family (paper §4.2)
    "reduce_prod": False, "scatter": False, "one_hot": False, "non_zero": False,
    "band_part": False, "reverse_sequence": False, "shape_op": False,
    "logical_and": False, "logical_or": False, "logical_xor": False,
    "gru": False, "lstm": False, "rnn": False,
    "asin": False, "sinh": False, "atanh": False, "mod": False,
}

_H13_OPS = dict(_ANE_OPS_COMMON)
_H13_OPS.update({
    # family-gated: not yet on M1 (paper:T4.1)
    "resize_texture": False, "crop_resize": False, "sin": False, "cos": False,
    "gather": False,  # only a tiny software envelope on M1; treat as unreachable
})
_H14_OPS = dict(_H13_OPS)
_H14_OPS.update({"resize_texture": True, "crop_resize": True})
_H15_OPS = dict(_H14_OPS)
_H15_OPS.update({"sin": True, "cos": True, "gather": True})
_H17_OPS = dict(_H15_OPS)

ANE_M1 = Target(
    name="ane-m1", family="ane", generation="H13",
    peak_flops=12e12,              # paper:T9.2 overhead-isolated slope
    hbm_bandwidth=85e9,            # paper:T9.2
    link_bandwidth=0.0, num_links=0,
    onchip_bytes=2 * 1024 * 1024,  # paper:T9.2 working set
    dispatch_floor_s=0.23e-3,      # paper:T9.2
    energy_pj_per_flop=0.37, energy_pj_per_flop_sustained=0.5,  # paper:T1.3
    native_dtype="float16", cores=4,  # paper:§1.3 HAL 0x238
    feature_bytes={
        "0x48f_kernel_stream_master": 1,  # paper:T7.2
        "0x529_palette_gate": 1,
        "0x528_int8_stream": 0, "0x520_blockwise_stream": 0,
        "0x815_softmax": 1, "0x816_instance_norm": 1, "0x4f2_argmax_hw": 1,
        "0x494_square_after_reduce": 0, "0x81d_texture_engine": 0,
        "0x4a9_dropout_random": 0,
    },
    weight_streams={
        WeightForm.FP16: True, WeightForm.INT4_PALETTE: True,   # paper:T7.1
        WeightForm.SPARSE: True, WeightForm.INT8: False,
        WeightForm.BLOCKWISE: False,
    },
    op_floor=_H13_OPS, limits=_ANE_LIMITS_H13,
)

ANE_M2 = dataclasses.replace(
    ANE_M1, name="ane-m2", generation="H14",
    feature_bytes={**ANE_M1.feature_bytes, "0x528_int8_stream": 1,
                   "0x81d_texture_engine": 1, "0x494_square_after_reduce": 1},
    weight_streams={**ANE_M1.weight_streams, WeightForm.INT8: True},
    op_floor=_H14_OPS, limits=_ANE_LIMITS_H14,
)

ANE_M3 = dataclasses.replace(
    ANE_M2, name="ane-m3", generation="H15",
    feature_bytes={**ANE_M2.feature_bytes, "0x520_blockwise_stream": 1,
                   "0x4a9_dropout_random": 1},
    weight_streams={**ANE_M2.weight_streams, WeightForm.BLOCKWISE: True},
    op_floor=_H15_OPS,
)

ANE_M5 = dataclasses.replace(
    ANE_M3, name="ane-m5", generation="H17s",
    peak_flops=48e12,              # paper:§1.3 — 16 cores vs 4, same form
    hbm_bandwidth=153e9,           # scaled per paper ch.12 family scaling
    onchip_bytes=int(4.72 * 1024 * 1024),  # paper:§9.2 (M5 working set)
    cores=16, op_floor=_H17_OPS, limits=_ANE_LIMITS_H16,
)

# ----------------------------------------------------------------------------
# TPU targets (the machine we compile the framework for).
# ----------------------------------------------------------------------------

_TPU_OPS = {k: True for k, v in _ANE_OPS_COMMON.items()}
_TPU_OPS.update({"sin": True, "cos": True, "gather": True, "scatter": True,
                 "one_hot": True, "conv3d": True, "resize_texture": True,
                 "crop_resize": True, "reduce_prod": True,
                 "logical_and": True, "logical_or": True, "logical_xor": True})
# TPU MXU has no native data-dependent recurrence either: recurrent cells are
# lowered to scans, but as *ops* they are reachable.
_TPU_OPS.update({"gru": True, "lstm": True, "rnn": True})

_TPU_LIMITS = ShapeLimits(
    max_kernel_width_default=2**16, max_kernel_width_fp16=2**16,
    max_tensor_extent=2**31 - 1, max_tensor_batch=2**31 - 1, max_rank=32,
    matmul_working_set_bytes=16 * 1024 * 1024,   # VMEM budget guideline
)

TPU_V5E = Target(
    name="tpu-v5e", family="tpu", generation="v5e",
    peak_flops=197e12,             # assignment: 197 TFLOP/s bf16 per chip
    hbm_bandwidth=819e9,           # assignment: 819 GB/s
    link_bandwidth=50e9,           # assignment: ~50 GB/s/link ICI
    num_links=4,
    onchip_bytes=16 * 1024 * 1024,  # VMEM per core (Pallas budget)
    dispatch_floor_s=30e-6,        # typical per-step launch overhead (modeled)
    energy_pj_per_flop=0.9, energy_pj_per_flop_sustained=1.4,  # modeled
    native_dtype="bfloat16", cores=1,
    feature_bytes={"mxu_int8_double_rate": 1, "mxu_int4_double_rate": 0},
    # On TPU, "streams" == our Pallas kernel dequantizes in-kernel (HBM bytes
    # stay compressed); every form we implement a kernel for streams.
    weight_streams={
        WeightForm.FP16: True, WeightForm.INT4_PALETTE: True,
        WeightForm.SPARSE: True, WeightForm.INT8: True,
        WeightForm.BLOCKWISE: True,
    },
    op_floor=_TPU_OPS, limits=_TPU_LIMITS,
)

TPU_V5P = dataclasses.replace(
    TPU_V5E, name="tpu-v5p", generation="v5p",
    peak_flops=459e12, hbm_bandwidth=2765e9, link_bandwidth=100e9, num_links=6,
)

TARGETS: Mapping[str, Target] = {
    t.name: t for t in (ANE_M1, ANE_M2, ANE_M3, ANE_M5, TPU_V5E, TPU_V5P)
}


def get_target(name: str) -> Target:
    if name not in TARGETS:
        raise KeyError(f"unknown target {name!r}; have {sorted(TARGETS)}")
    return TARGETS[name]


# ----------------------------------------------------------------------------
# ANE numeric constants (paper:T3.3) — shared by numerics oracle and kernels.
# ----------------------------------------------------------------------------

FP16_MAX = 65504.0                    # paper:T3.3
ACCUM_OUT_CEILING = 32768.0           # 2^15 multiply-accumulate output port ceiling
WIDTH_SLICE_GAIN = 16.0               # crop-DMA fixed gain on width-axis offset slice
WIDTH_SLICE_FINITE_FILL = 4094.0      # 4094*16 == 65504 passes
WIDTH_SLICE_OVERFLOW_FILL = 4096.0    # 4096*16 == 65536 -> inf
FIRST_STAGE_TILE = 4                  # first reduction-stage lane tile width
LUT_KNOTS = 33                        # activation table knot count
EXP_OVERFLOW_INPUT = 11.094           # ln(65504)
SIGMOID_DOMAIN = (-9.938, 8.320)      # paper:T3.3 table domain clamp
