"""Three-term roofline analysis from compiled XLA artifacts (paper ch. 9).

The paper's machine obeys R(I) = min(P, I·B) with a ridge at I* = P/B, a hard
on-chip working-set threshold, and a per-dispatch floor t0 (§9). On a pod the
same discipline adds a third, collective term (the single-chip ANE's
"transfer penalty" generalized to ICI):

    compute_s    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_s     = HLO_bytes / (chips * HBM_bw)
    collective_s = collective_bytes / (chips * link_bw)

`compiled.cost_analysis()` supplies FLOPs/bytes; collective bytes are parsed
from the post-SPMD optimized HLO text (`compiled.as_text()`), summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, per the assignment.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Mapping

from repro.core import hal
from repro.core.hal import Target

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# matches e.g.  bf16[16,1024,512]{2,1,0}   or  f32[] (scalar)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind operand bytes of the collectives in one compiled module."""

    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]<=[...]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum *operand* sizes of every collective op in optimized HLO text.

    Post-optimization HLO prints operands as bare names (no shapes), so the
    operand size is derived from the RESULT shape on the definition line plus
    the op's semantics: an all-gather's operand is result/group_size, a
    reduce-scatter's is result*group_size, and all-reduce / all-to-all /
    collective-permute move operand == result. Async `-start/-done` pairs
    count once (the `-start` line).
    """
    bytes_by: dict[str, float] = {k: 0.0 for k in _COLLECTIVE_OPS}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+((?:\([^)]*\)|\S+))\s+([a-z0-9-]+)\(", stripped)
        if not m:
            continue
        opcode = m.group(2)
        kind = None
        for c in _COLLECTIVE_OPS:
            if opcode == c or opcode == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        result = m.group(1)
        total = 0.0
        for sm in _SHAPE_RE.finditer(result):
            total += _shape_bytes(sm.group(1), sm.group(2))
        g = _group_size(stripped)
        if kind == "all-gather" and g > 0:
            total /= g
        elif kind == "reduce-scatter":
            total *= g
        bytes_by[kind] += total
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineReport:
    """The three terms for one (arch x shape x mesh) cell, in seconds."""

    arch: str
    shape: str
    mesh: str
    chips: int
    target: str
    # raw artifact numbers (per-chip, as reported by the SPMD module)
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    peak_memory_per_chip: float
    # the three terms
    compute_s: float
    memory_s: float
    collective_s: float
    # usefulness
    model_flops: float            # 6·N_active·D convention, global
    useful_ratio: float           # model_flops / (hlo_flops_per_chip * chips)
    collectives: Mapping[str, float] = dataclasses.field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-bound step estimate: overlapped terms -> max()."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roof the step achieves if it runs at the
        roofline bound: useful compute time / bound time."""
        if self.step_time_s == 0:
            return 0.0
        useful_compute_s = (self.model_flops / max(self.chips, 1)) / _peak(self.target)
        return useful_compute_s / self.step_time_s

    @property
    def mfu(self) -> float:
        return self.roofline_fraction

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_time_s,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "coll_bytes_per_chip": self.collective_bytes_per_chip,
            "peak_mem_gb": self.peak_memory_per_chip / 2**30,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def _peak(target_name: str) -> float:
    return hal.get_target(target_name).peak_flops


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost_analysis: Mapping[str, float],
    hlo_text: str,
    memory_analysis=None,
    model_flops: float = 0.0,
    target: Target = hal.TPU_V5E,
) -> RooflineReport:
    """Build the three-term report for one compiled cell.

    `cost_analysis` and `hlo_text` describe the per-chip SPMD module, so the
    terms divide by per-chip roofs directly (equivalent to the assignment's
    global/(chips*roof) form).
    """
    flops = float(cost_analysis.get("flops", 0.0))
    byt = float(cost_analysis.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    peak_mem = 0.0
    if memory_analysis is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            peak_mem += float(getattr(memory_analysis, attr, 0.0) or 0.0)
        alias = float(getattr(memory_analysis, "alias_size_in_bytes", 0.0) or 0.0)
        peak_mem -= alias
    compute_s = flops / target.peak_flops
    memory_s = byt / target.hbm_bandwidth
    collective_s = coll.total_bytes / target.collective_bandwidth
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips, target=target.name,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=byt,
        collective_bytes_per_chip=coll.total_bytes,
        peak_memory_per_chip=peak_mem,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model_flops, useful_ratio=useful,
        collectives=dict(coll.bytes_by_kind),
    )


# ---------------------------------------------------------------------------
# The paper's single-chip roofline + energy model (ch. 9 / 10), reused by the
# benchmarks to reproduce Table 9.2 / 10.4.
# ---------------------------------------------------------------------------


def attainable_rate(intensity: float, target: Target) -> float:
    """R(I) = min(P, I*B)."""
    return min(target.peak_flops, intensity * target.hbm_bandwidth)


def dispatch_time(flops: float, bytes_moved: float,
                  target: Target) -> tuple[float, float]:
    """t = t0 + work/R (§9.3). Returns (seconds, attainable FLOP/s).

    Callers model fusion by charging t0 once for a fused chain instead of
    once per op (paper §9.4)."""
    intensity = flops / max(bytes_moved, 1.0)
    r = attainable_rate(intensity, target)
    return target.dispatch_floor_s + flops / max(r, 1.0), r


def energy_joules(flops: float, seconds: float, target: Target,
                  utilization: float | None = None) -> float:
    """Paper §10.5: draw scales with utilization between a dispatch-floor
    wattage and the compute-bound peak; energy = power * time."""
    p_floor = 0.9 if target.family == "ane" else 60.0     # W (paper / modeled)
    p_peak = 4.3 if target.family == "ane" else 170.0     # W
    if utilization is None:
        peak_time = flops / target.peak_flops
        utilization = min(1.0, peak_time / max(seconds, 1e-12))
    watts = p_floor + (p_peak - p_floor) * utilization
    return watts * seconds
