"""Weight compression: forms, stream-vs-fold gates, and the §7.6 chooser.

The paper's result: compression on the direct route is a *bandwidth* feature.
A form either **streams** (compressed bytes cross DRAM, dequantized at the
multiplier input) or **folds** (expanded to dense fp16 in DRAM first — a
stored-size saving only). Which outcome applies is a HAL decision per target
(`hal.Target.streams`), not a property of the reconstruction op.

Encode/decode here are the reference implementations; the Pallas kernels in
`repro/kernels/{palette,sparse}` are the streaming datapath (dequant happens
inside the kernel, after the HBM->VMEM move, so HBM traffic stays compressed —
the TPU-native equivalent of the ANE's multiplier-input reconstruction).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hal
from repro.core.hal import Target, WeightForm

# ---------------------------------------------------------------------------
# Encoders / decoders (reference; pure jnp so they jit and differentiate-thru
# via straight-through where needed)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedWeight:
    """A weight in one of the compressed forms, plus its side tables."""

    form: WeightForm
    shape: tuple[int, ...]
    payload: dict[str, Any]          # form-specific arrays

    @property
    def stored_bytes(self) -> int:
        total = 0
        for v in jax.tree.leaves(self.payload):
            total += v.size * v.dtype.itemsize
        return total

    @property
    def dense_bytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n * 2                  # fp16/bf16 dense reference


def encode_int8(w: np.ndarray, per_channel: bool = True) -> PackedWeight:
    """Affine int8, symmetric on the M1 generation: w = s*q, q=round(w/s).

    paper:§7.2 — zero point folds to 0 on H13; scale is per output channel.
    """
    w = np.asarray(w, dtype=np.float32)
    axis = tuple(range(w.ndim - 1))
    if per_channel:
        s = np.max(np.abs(w), axis=axis, keepdims=True) / 127.0
    else:
        s = np.full((1,) * w.ndim, np.max(np.abs(w)) / 127.0)
    s = np.maximum(s, 1e-12)
    q = np.clip(np.round(w / s), -127, 127).astype(np.int8)
    return PackedWeight(WeightForm.INT8, w.shape,
                        {"q": q, "scale": s.astype(np.float16)})


def decode_int8(p: PackedWeight) -> jnp.ndarray:
    q = jnp.asarray(p.payload["q"], jnp.float32)
    s = jnp.asarray(p.payload["scale"], jnp.float32)
    return (q * s).astype(jnp.float16)


def encode_int4_palette(w: np.ndarray, iters: int = 12) -> PackedWeight:
    """int4 palette lookup table: 4-bit index into a 16-entry fp16 codebook,
    two indices packed per byte, low nibble first (paper §7.2 worked example).

    Codebook fit: k-means (Lloyd) per tensor, initialized at quantiles.
    """
    w = np.asarray(w, dtype=np.float32)
    flat = w.reshape(-1)
    # init codebook at quantiles, then Lloyd iterations
    qs = np.linspace(0, 1, 16)
    code = np.quantile(flat, qs).astype(np.float32)
    for _ in range(iters):
        idx = np.argmin(np.abs(flat[:, None] - code[None, :]), axis=1)
        for k in range(16):
            sel = flat[idx == k]
            if sel.size:
                code[k] = sel.mean()
    code = np.sort(code)
    idx = np.argmin(np.abs(flat[:, None] - code[None, :]), axis=1).astype(np.uint8)
    if idx.size % 2:
        idx = np.concatenate([idx, np.zeros(1, np.uint8)])
    packed = (idx[0::2] | (idx[1::2] << 4)).astype(np.uint8)   # low nibble first
    return PackedWeight(WeightForm.INT4_PALETTE, w.shape,
                        {"packed": packed, "lut": code.astype(np.float16)})


def decode_int4_palette(p: PackedWeight) -> jnp.ndarray:
    packed = jnp.asarray(p.payload["packed"])
    lut = jnp.asarray(p.payload["lut"], jnp.float16)
    lo = packed & 0xF
    hi = packed >> 4
    idx = jnp.stack([lo, hi], axis=1).reshape(-1)
    n = int(np.prod(p.shape))
    return lut[idx[:n]].reshape(p.shape)


def encode_sparse(w: np.ndarray, target_density: float = 0.5) -> PackedWeight:
    """Pair-structured sparsity (TPU adaptation of the paper's mask+values).

    The ANE stores a 1-bit keep mask + packed fp16 nonzeros (paper §7.2). A
    TPU kernel wants structure, so we keep exactly one of every two adjacent
    elements along the contraction axis (50% structured, like GPU 2:4):
    values (K/2, N) fp16 + selector bits packed 8-per-byte along K:
    stored bytes = 0.5 + 1/16 ~ 0.53x dense (the paper's unstructured form
    reaches 0.43x at 63% zeros — recorded in DESIGN.md). Magnitude-based:
    the larger |.| of each pair survives.
    """
    w = np.asarray(w, dtype=np.float32)
    assert w.ndim == 2 and w.shape[0] % 2 == 0, "sparse form wants (K, N), K even"
    k, n = w.shape
    pairs = w.reshape(k // 2, 2, n)
    sel = (np.abs(pairs[:, 1, :]) > np.abs(pairs[:, 0, :])).astype(np.uint8)
    vals = np.where(sel, pairs[:, 1, :], pairs[:, 0, :]).astype(np.float16)
    k2 = k // 2
    pad = (-k2) % 8
    sel_p = np.concatenate([sel, np.zeros((pad, n), np.uint8)]) if pad else sel
    bits = sel_p.reshape(-1, 8, n)
    weights_of_bit = (1 << np.arange(8, dtype=np.uint8))[None, :, None]
    packed = (bits * weights_of_bit).sum(axis=1).astype(np.uint8)   # (k2/8, n)
    return PackedWeight(WeightForm.SPARSE, w.shape,
                        {"values": vals, "selector_packed": packed})


def unpack_selector(packed: jnp.ndarray, k2: int) -> jnp.ndarray:
    """(k2/8, N) uint8 -> (k2, N) 0/1 — shared with the Pallas kernel."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[:, None, :] >> shifts[None, :, None]) & 1
    return bits.reshape(-1, packed.shape[-1])[:k2]


def decode_sparse(p: PackedWeight) -> jnp.ndarray:
    vals = jnp.asarray(p.payload["values"], jnp.float16)
    k2, n = vals.shape
    sel = unpack_selector(jnp.asarray(p.payload["selector_packed"]), k2)
    out = jnp.zeros((k2, 2, n), jnp.float16)
    out = out.at[:, 0, :].set(jnp.where(sel == 0, vals, 0))
    out = out.at[:, 1, :].set(jnp.where(sel == 1, vals, 0))
    return out.reshape(p.shape)


def encode_blockwise(w: np.ndarray, block: int = 32) -> PackedWeight:
    """Blockwise affine: one fp16 scale per contiguous block of `block`
    elements along the contraction axis — finer than per-channel (paper §7.2).
    """
    w = np.asarray(w, dtype=np.float32)
    assert w.ndim == 2 and w.shape[0] % block == 0
    k, n = w.shape
    blocks = w.reshape(k // block, block, n)
    s = np.maximum(np.max(np.abs(blocks), axis=1, keepdims=True) / 127.0, 1e-12)
    q = np.clip(np.round(blocks / s), -127, 127).astype(np.int8)
    return PackedWeight(WeightForm.BLOCKWISE, w.shape,
                        {"q": q.reshape(k, n), "scale": s.astype(np.float16),
                         "block": np.asarray(block)})


def decode_blockwise(p: PackedWeight) -> jnp.ndarray:
    block = int(p.payload["block"])
    k, n = p.shape
    q = jnp.asarray(p.payload["q"], jnp.float32).reshape(k // block, block, n)
    s = jnp.asarray(p.payload["scale"], jnp.float32)
    return (q * s).reshape(p.shape).astype(jnp.float16)


_ENCODERS = {
    WeightForm.INT8: encode_int8,
    WeightForm.INT4_PALETTE: encode_int4_palette,
    WeightForm.SPARSE: encode_sparse,
    WeightForm.BLOCKWISE: encode_blockwise,
}
_DECODERS = {
    WeightForm.INT8: decode_int8,
    WeightForm.INT4_PALETTE: decode_int4_palette,
    WeightForm.SPARSE: decode_sparse,
    WeightForm.BLOCKWISE: decode_blockwise,
}


def encode(form: WeightForm, w: np.ndarray) -> PackedWeight:
    if form == WeightForm.FP16:
        return PackedWeight(WeightForm.FP16, w.shape,
                            {"w": np.asarray(w, np.float16)})
    return _ENCODERS[form](w)


def decode(p: PackedWeight) -> jnp.ndarray:
    if p.form == WeightForm.FP16:
        return jnp.asarray(p.payload["w"], jnp.float16)
    return _DECODERS[p.form](p)


# ---------------------------------------------------------------------------
# Stream-vs-fold semantics + the §7.6 chooser
# ---------------------------------------------------------------------------


def dram_bytes(p: PackedWeight, target: Target) -> float:
    """Bytes that cross the DRAM/HBM boundary per use of this weight.

    A form that streams moves its stored (compressed) bytes; a form that
    folds is expanded to dense fp16 in DRAM first and moves dense bytes
    (paper §7.3: the int8 fold on M1 is a stored-size saving only).
    """
    if target.streams(p.form):
        return float(p.stored_bytes)
    return float(p.dense_bytes)


def accuracy_error(form: WeightForm, w: np.ndarray,
                   probe: np.ndarray | None = None) -> float:
    """Relative output error of a linear layer with the round-tripped weight
    against an fp32 reference (the paper's tolerance check)."""
    w = np.asarray(w, dtype=np.float32)
    if probe is None:
        rng = np.random.default_rng(0)
        probe = rng.normal(size=(16, w.shape[0])).astype(np.float32)
    ref = probe @ w
    wd = np.asarray(decode(encode(form, w)), dtype=np.float32)
    out = probe @ wd
    return float(np.linalg.norm(out - ref) / (np.linalg.norm(ref) + 1e-30))


def is_bandwidth_bound(flops: float, weight_dense_bytes: float,
                       act_bytes: float, target: Target) -> bool:
    """Roofline classification of one layer (paper §9.1)."""
    intensity = flops / max(weight_dense_bytes + act_bytes, 1.0)
    return intensity < target.ridge_flop_per_byte


def fraction_zero(w: np.ndarray, tol: float = 0.0) -> float:
    w = np.asarray(w)
    return float(np.mean(np.abs(w) <= tol))


def choose_weight_form(
    w: np.ndarray,
    target: Target,
    *,
    flops: float,
    act_bytes: float,
    tolerance: float = 0.01,
    sparsity_threshold: float = 0.5,
) -> WeightForm:
    """The paper's §7.6 procedure, verbatim in structure:

    1. Keep fp16 if the layer is compute-bound (a stream cannot help).
    2. Otherwise try the native-streaming forms smallest-bytes-first
       (int4 -> sparse -> int8 -> blockwise), keeping the first that clears
       the accuracy tolerance against an fp32 reference.
    3. Sparsity is a candidate only when >= half the weight is zero.
    4. A folding form is never chosen for bandwidth (it moves dense bytes).
    """
    w = np.asarray(w, dtype=np.float32)
    dense_bytes = w.size * 2.0
    if not is_bandwidth_bound(flops, dense_bytes, act_bytes, target):
        return WeightForm.FP16
    candidates = [f for f in (WeightForm.INT4_PALETTE, WeightForm.SPARSE,
                              WeightForm.INT8, WeightForm.BLOCKWISE)
                  if target.streams(f)]
    if fraction_zero(w) < sparsity_threshold and WeightForm.SPARSE in candidates:
        candidates.remove(WeightForm.SPARSE)
    candidates.sort(key=lambda f: hal.BYTES_PER_ELEMENT[f])
    for form in candidates:
        if w.ndim != 2 and form in (WeightForm.SPARSE, WeightForm.BLOCKWISE):
            continue
        try:
            if accuracy_error(form, w) <= tolerance:
                return form
        except AssertionError:
            continue
    return WeightForm.FP16


def stream_speedup(p: PackedWeight, target: Target, act_bytes: float = 0.0) -> float:
    """Predicted bandwidth-bound speedup of the compressed stream vs fp16:
    dense_bytes / dram_bytes (per paper: int4 on M1 -> ~4x fewer weight bytes
    -> measured 2.37x once activations and overhead are included)."""
    dense = p.dense_bytes + act_bytes
    moved = dram_bytes(p, target) + act_bytes
    return dense / max(moved, 1.0)
