"""ANE numerics oracle: the fp16 datapath with a wide accumulator (paper ch. 3).

The paper's single most load-bearing fact is that the engine multiplies in fp16
end to end while accumulating in a wide (fp32-class) register, with exactly two
rounding points bracketing the reduction (inputs in, outputs out), plus a set of
measured edge behaviors a bit-exact oracle must model (§3.6):

  * NaN coerces to +inf at the input boundary; the engine never emits NaN.
  * IEEE-indeterminate forms flush to +0 (inf-inf, 0*inf, sqrt(-1), log(-1)).
  * log(0) returns the finite sentinel -45440.
  * The multiply-accumulate *output port* saturates at 2^15 = 32768, one bit
    below the fp16 storage ceiling of 65504 (§3.7).
  * A width-axis slice with a nonzero begin offset applies a fixed x16 gain.
  * Output rounding is round-half-to-even on the fp16 grid (M1).
  * The first reduction stage groups lanes into tiles of four before the wide
    accumulator (Table 3.1 survivor sweep).
  * Activations evaluate through 33-knot piecewise-linear tables with end-knot
    clamps and small origin biases (gelu -0.000543, swish -0.001259).

This module is the *reference* model (numpy, float64 carried as "wide"), used
by tests, by the Pallas kernels' ANE mode as the oracle, and by the
paper-validation benchmarks. Where the paper leaves a behavior unresolved
(the in-tile rounding tie mode, §3.6 "2049 rounds to 2048 vs 2050"), the model
is parameterized and the ambiguity is documented in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Literal

import numpy as np

from repro.core import hal

TieMode = Literal["even", "away"]

# ---------------------------------------------------------------------------
# fp16 grid rounding with explicit tie control
# ---------------------------------------------------------------------------


def round_fp16(x: np.ndarray | float, tie: TieMode = "even") -> np.ndarray:
    """Round float64 values onto the fp16 grid with the given tie mode.

    numpy's float16 cast is IEEE round-half-to-even; the half-away mode is
    synthesized by nudging exact ties away from zero before the cast.
    """
    x = np.asarray(x, dtype=np.float64)
    with np.errstate(over="ignore", invalid="ignore"):
        f16 = np.float16(x).astype(np.float64)        # IEEE RTNE result
    if tie == "even":
        return f16
    # half-away: find the two fp16 neighbours bracketing x, detect exact ties,
    # and on a tie pick the larger-magnitude neighbour.
    up = np.nextafter(np.float16(f16), np.float16(np.inf)).astype(np.float64)
    dn = np.nextafter(np.float16(f16), np.float16(-np.inf)).astype(np.float64)
    lo = np.where(f16 <= x, f16, dn)
    hi = np.where(f16 <= x, up, f16)
    is_tie = np.isfinite(x) & (lo != hi) & ((x - lo) == (hi - x))
    away = np.where(x > 0, hi, lo)
    return np.where(is_tie, away, f16)


def saturate_fp16(x: np.ndarray) -> np.ndarray:
    """fp16 storage saturation: past 65504 the value overflows to inf."""
    x = np.asarray(x, dtype=np.float64)
    out = x.copy()
    out = np.where(x > hal.FP16_MAX, np.inf, out)
    out = np.where(x < -hal.FP16_MAX, -np.inf, out)
    return out


def coerce_input(x: np.ndarray) -> np.ndarray:
    """Input-boundary behavior: NaN -> +inf; values round onto the fp16 grid.

    paper:§3.6 — "The engine coerces a NaN to positive infinity at the input
    boundary, and never produces a NaN anywhere."
    """
    x = np.asarray(x, dtype=np.float64)
    x = np.where(np.isnan(x), np.inf, x)
    # -0.0 echoes as +0.0 through x+0 (the engine drops a zero's sign bit on
    # several paths); we keep the sign for elementwise but note reciprocal.
    return saturate_fp16(round_fp16(x))


# ---------------------------------------------------------------------------
# Wide accumulator with the 4-lane first reduction stage (Table 3.1)
# ---------------------------------------------------------------------------

InTileMode = Literal["sequential", "exact"]


def wide_reduce(
    v: np.ndarray,
    *,
    tile: int = hal.FIRST_STAGE_TILE,
    in_tile: InTileMode = "sequential",
    tie: TieMode = "even",
) -> float:
    """Model of the engine's vector reduction (one wide accumulator).

    Stage 1 groups adjacent lanes into tiles of `tile` (4 on every measured
    part); the tile partial is formed in fp16 (sequentially by default, which
    is the only mode that reproduces the paper's hard floor of exactly four
    survivors at and above the 4096 threshold), then tile partials accumulate
    exactly in the wide register. Inputs are first coerced/rounded as at the
    real input port.
    """
    v = coerce_input(np.asarray(v, dtype=np.float64).ravel())
    n = v.size
    pad = (-n) % tile
    if pad:
        v = np.concatenate([v, np.zeros(pad)])
    tiles = v.reshape(-1, tile)
    if in_tile == "sequential":
        partials = np.zeros(tiles.shape[0])
        for j in range(tile):
            partials = round_fp16(partials + tiles[:, j], tie=tie)
    else:
        partials = round_fp16(tiles.sum(axis=1), tie=tie)
    # The wide register: fp32-class. float64 here stands in for "wide enough
    # that representable partial sums are exact" (true for fp32 at these
    # magnitudes, and for the probes the paper runs).
    return float(partials.sum())


def survivor_sweep(magnitudes, repeats: int = 16, **kw) -> list[int]:
    """Reproduce the paper's cancellation-threshold sweep (Table 3.1).

    For each magnitude b, reduce [b, -b, 1] * repeats and report how many of
    the `repeats` ones survive (the reduction result, since the bigs cancel).
    """
    out = []
    for b in magnitudes:
        v = np.array([b, -b, 1.0] * repeats)
        out.append(int(round(wide_reduce(v, **kw))))
    return out


# ---------------------------------------------------------------------------
# The multiply-accumulate datapath (matmul / linear / multi-tap conv)
# ---------------------------------------------------------------------------


def accum_port_saturate(x: np.ndarray) -> np.ndarray:
    """The MAC output-port ceiling: |result| >= 2^15 -> inf (paper §3.7).

    Pinned to the bit: 32752 (largest fp16 below 2^15) passes, 32768 -> inf.
    Applies to matmul, linear, and any convolution accumulating >= 2 taps;
    NOT to dedicated reductions or single elementwise multiplies.
    """
    x = np.asarray(x, dtype=np.float64)
    out = np.where(x >= hal.ACCUM_OUT_CEILING, np.inf, x)
    out = np.where(x <= -hal.ACCUM_OUT_CEILING, -np.inf, out)
    return out


def ane_matmul(
    a: np.ndarray,
    b: np.ndarray,
    *,
    scale: np.ndarray | None = None,
    bias: np.ndarray | None = None,
    tie: TieMode = "even",
) -> np.ndarray:
    """Oracle for the engine's matmul: fp16 in, wide accumulate, fp16 out.

    Order of rounding per §3.1: inputs round to fp16, products accumulate
    wide, optional per-channel scale and bias apply in fp16, the output port
    saturates at 2^15, and the store rounds to fp16 (RTNE on M1).

    The port ceiling tracks the *running* partial (§3.7): an interior
    partial that exceeds 2^15 overflows to infinity even when a later
    cancellation would have brought the final result back into range.
    """
    a = coerce_input(a)
    b = coerce_input(b)
    # running partials along the contraction (the lowered accumulation order)
    partials = np.cumsum(a[..., :, None] * b[None, ...], axis=-2)
    acc = partials[..., -1, :]
    hit_hi = np.any(partials >= hal.ACCUM_OUT_CEILING, axis=-2)
    hit_lo = np.any(partials <= -hal.ACCUM_OUT_CEILING, axis=-2)
    if scale is not None:
        acc = round_fp16(acc * coerce_input(scale), tie=tie)
    if bias is not None:
        acc = round_fp16(acc + coerce_input(bias), tie=tie)
    acc = np.where(hit_hi, np.inf, acc)
    acc = np.where(hit_lo & ~hit_hi, -np.inf, acc)
    acc = accum_port_saturate(acc)
    return saturate_fp16(round_fp16(acc, tie=tie))


def width_slice(x: np.ndarray, begin: int, size: int, axis: int = -1) -> np.ndarray:
    """Width-axis slice. A nonzero begin offset routes through the crop DMA,
    which applies a fixed x16 gain (paper §3.7): fills <= 4094 stay bit-exact
    after the compensating rescale; 4095+ saturate to inf on the way.

    The model applies gain, stores through the fp16 port (saturating), and
    removes the gain — matching the observed "4094 passes, 4096 -> inf".
    """
    x = np.asarray(x, dtype=np.float64)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(begin, begin + size)
    out = x[tuple(sl)]
    if begin != 0:
        gained = saturate_fp16(round_fp16(out * hal.WIDTH_SLICE_GAIN))
        out = np.where(np.isinf(gained), gained, gained / hal.WIDTH_SLICE_GAIN)
    return out


# ---------------------------------------------------------------------------
# Elementwise edge semantics (§3.6)
# ---------------------------------------------------------------------------

LOG_ZERO_SENTINEL = -45440.0   # paper:§3.6 log(+0) returns a finite sentinel


def _flush_indeterminate(x: np.ndarray) -> np.ndarray:
    """All IEEE-indeterminate (NaN-producing) forms flush to +0."""
    return np.where(np.isnan(x), 0.0, x)


def ane_add(a, b):
    a, b = coerce_input(a), coerce_input(b)
    with np.errstate(invalid="ignore"):
        return saturate_fp16(round_fp16(_flush_indeterminate(a + b)))  # inf-inf -> +0


def ane_mul(a, b):
    a, b = coerce_input(a), coerce_input(b)
    with np.errstate(invalid="ignore"):
        return saturate_fp16(round_fp16(_flush_indeterminate(a * b)))  # 0*inf -> +0


def ane_sqrt(x):
    x = coerce_input(x)
    out = np.sqrt(np.where(x < 0, 0.0, x))       # sqrt(-1) -> +0
    return round_fp16(out)


def ane_log(x):
    x = coerce_input(x)
    out = np.where(x < 0, 0.0,                    # log(-1) -> +0
                   np.where(x == 0, LOG_ZERO_SENTINEL, np.log(np.maximum(x, 1e-300))))
    return saturate_fp16(round_fp16(out))


def ane_reciprocal(x):
    x = coerce_input(x)
    x = np.where(x == 0.0, 0.0, x)               # signed zero loses its sign
    with np.errstate(divide="ignore"):
        out = np.where(x == 0.0, np.inf, 1.0 / x)   # recip(+-0) -> +inf
    return saturate_fp16(round_fp16(out))


def ane_rsqrt(x):
    x = coerce_input(x)
    x = np.abs(np.where(x == 0.0, 0.0, x))       # rsqrt(-0) -> +inf per paper
    with np.errstate(divide="ignore"):
        out = np.where(x == 0.0, np.inf, 1.0 / np.sqrt(x))
    return saturate_fp16(round_fp16(out))


def ane_relu(x):
    x = coerce_input(x)                           # NaN -> +inf -> relu -> +inf
    return np.maximum(x, 0.0)


def ane_max(a, b):
    a, b = coerce_input(a), coerce_input(b)       # NaN -> +inf wins the max
    return np.maximum(a, b)


def ane_softmax(x, axis: int = -1):
    """Fused softmax subtracts a hardware max first, so it never overflows
    (paper §3.6: softmax([1000,1,2,3]) == [1,0,0,0]); a NaN lane coerces to
    +inf and takes all the mass."""
    x = coerce_input(x)
    m = np.max(x, axis=axis, keepdims=True)
    # +inf lanes: exp(inf - inf) would be indeterminate -> the engine puts the
    # mass on the max lane(s).
    with np.errstate(invalid="ignore"):
        shifted = x - m
    shifted = np.where(np.isnan(shifted), 0.0, shifted)   # inf - inf -> 0
    e = np.exp(shifted)
    out = e / np.sum(e, axis=axis, keepdims=True)
    return round_fp16(out)


def ane_exp(x):
    """Bare exp overflows at ln(65504) ~ 11.094 — no max-subtraction."""
    x = coerce_input(x)
    with np.errstate(over="ignore"):
        return saturate_fp16(round_fp16(np.exp(x)))


# ---------------------------------------------------------------------------
# 33-knot piecewise-linear activation tables (§3.5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LutTable:
    """One decoded activation table: 33 knots, 32 linear segments, end clamps."""

    name: str
    xs: np.ndarray          # (33,) knot abscissae, ascending
    ys: np.ndarray          # (33,) knot ordinates (fp16-rounded, as stored)
    lo_clamp: float         # asymptote value left of the domain
    hi_clamp: float         # asymptote value right of the domain

    @property
    def slopes(self) -> np.ndarray:
        return (self.ys[1:] - self.ys[:-1]) / (self.xs[1:] - self.xs[:-1])

    @property
    def intercepts(self) -> np.ndarray:
        return self.ys[:-1] - self.slopes * self.xs[:-1]

    def __call__(self, x: np.ndarray) -> np.ndarray:
        """Evaluate: NaN coerces to the hi clamp (the +inf coercion), values
        past the table domain clamp to the end-knot asymptote, in-domain
        values evaluate as slope*x + intercept in fp16."""
        x = np.asarray(x, dtype=np.float64)
        x = np.where(np.isnan(x), np.inf, x)
        idx = np.clip(np.searchsorted(self.xs, x, side="right") - 1, 0, 31)
        s, c = self.slopes[idx], self.intercepts[idx]
        val = round_fp16(s * x + c)
        val = np.where(x < self.xs[0], self.lo_clamp, val)
        val = np.where(x > self.xs[-1], self.hi_clamp, val)
        return val


def _optimal_knots(fn: Callable, lo: float, hi: float, n: int) -> np.ndarray:
    """Knot placement with density ~ |f''|^(1/2), the optimal rate for PWL
    interpolation — this is how a fixed 33-knot table reaches the sub-0.4%%
    worst errors the paper measures (§3.5: accuracy comes from the piecewise
    fit and the per-function domain, not sample density)."""
    grid = np.linspace(lo, hi, 4097)
    h = grid[1] - grid[0]
    f = fn(grid)
    f2 = np.abs(np.gradient(np.gradient(f, h), h))
    density = np.sqrt(f2) + 1e-4 * np.max(np.sqrt(f2) + 1e-30)
    cdf = np.cumsum(density)
    cdf = (cdf - cdf[0]) / (cdf[-1] - cdf[0])
    qs = np.linspace(0.0, 1.0, n)
    xs = np.interp(qs, cdf, grid)
    xs[0], xs[-1] = lo, hi
    # Lloyd-style refinement: redistribute knots so per-segment PWL error
    # equalizes (a few iterations suffice to reach the paper's error floor).
    for _ in range(6):
        seg_err = np.empty(xs.size - 1)
        for i in range(xs.size - 1):
            g = np.linspace(xs[i], xs[i + 1], 65)
            lin = f_at(fn, xs[i], xs[i + 1], g)
            seg_err[i] = np.max(np.abs(fn(g) - lin))
        w = np.repeat(np.power(seg_err + 1e-12, 0.5), 1)
        cdf = np.concatenate([[0.0], np.cumsum(w)])
        cdf = cdf / cdf[-1]
        xs = np.interp(np.linspace(0, 1, n), cdf, xs)
        xs[0], xs[-1] = lo, hi
    return xs


def f_at(fn, x0, x1, g):
    """Chord of fn between x0 and x1, evaluated at grid g."""
    y0 = fn(np.asarray(x0, dtype=np.float64))
    y1 = fn(np.asarray(x1, dtype=np.float64))
    t = (g - x0) / (x1 - x0)
    return y0 + t * (y1 - y0)


_LUT_SPECS: dict[str, tuple[Callable, float, float, float, float]] = {
    # name: (fn, lo, hi, lo_clamp, hi_clamp)
    "sigmoid": (lambda x: 1 / (1 + np.exp(-x)), *hal.SIGMOID_DOMAIN, 0.0, 1.0),
    "tanh": (np.tanh, -3.6, 3.6, -1.0, 1.0),
    "gelu": (lambda x: x * 0.5 * (1 + _erf_np(x / math.sqrt(2))), -6.0, 6.0, 0.0, np.inf),
    "swish": (lambda x: x / (1 + np.exp(-x)), -9.0, 9.0, 0.0, np.inf),
    "erf": (lambda x: _erf_np(x), -3.9, 3.9, -1.0, 1.0),
    "exp": (np.exp, -11.1, 11.05, 0.0, np.inf),
    # exp's hi clamp stays +inf: past ln(65504) ~ 11.094 a bare exp overflows
    # to infinity (paper:T3.3), which the table reproduces via the clamp.
    "softplus": (lambda x: np.logaddexp(0.0, x), -10.0, 10.0, 0.0, 0.0),
    # softplus(+inf) -> +0 is a measured table collapse (§3.6), hence hi_clamp=0
    "softsign": (lambda x: x / (1 + np.abs(x)), -16.0, 16.0, -1.0, 0.0),
    "sin": (np.sin, -math.pi, math.pi, 0.0, 0.0),
    "cos": (np.cos, -math.pi, math.pi, 0.0, 0.0),
}


def _erf_np(x):
    # vectorized erf without scipy
    return np.vectorize(math.erf)(np.asarray(x, dtype=np.float64))


_ORIGIN_BIAS = {"gelu": -0.000543, "swish": -0.001259}   # paper:T3.3


def build_lut(name: str, knots: int = hal.LUT_KNOTS) -> LutTable:
    """Fit the 33-knot table for one activation; gelu/swish carry the decoded
    constant origin bias the paper reports (a bit-exact oracle must hold it)."""
    fn, lo, hi, lo_clamp, hi_clamp = _LUT_SPECS[name]
    xs = _optimal_knots(fn, lo, hi, knots)
    ys = fn(xs)
    if name in _ORIGIN_BIAS:
        # shift the whole table by the decoded origin bias so eval(0) matches
        i = np.argmin(np.abs(xs))
        xs[i] = 0.0
        ys = fn(xs) + _ORIGIN_BIAS[name]
    ys = round_fp16(ys)
    if hi_clamp == np.inf and name != "exp":
        hi_clamp = float(ys[-1])
    return LutTable(name=name, xs=xs, ys=ys, lo_clamp=float(lo_clamp),
                    hi_clamp=float(hi_clamp))


def lut_worst_error(table: LutTable, n: int = 20001) -> float:
    """Worst absolute error of the table against the exact function over its
    domain (the paper's per-function figures: sigmoid 0.0034, tanh 0.0017,
    gelu 0.0059)."""
    fn = _LUT_SPECS[table.name][0]
    xs = np.linspace(table.xs[0], table.xs[-1], n)
    exact = fn(xs)
    if table.name in _ORIGIN_BIAS:
        exact = exact + _ORIGIN_BIAS[table.name]
    err = np.abs(table(xs) - exact)
    return float(np.max(err[np.isfinite(err)]))
