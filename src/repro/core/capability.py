"""Attested vs reachable: the compile-and-run capability validator (paper §4).

The paper's rule: "a capability advertised in a table, recognized by a
frontend, or validated by a checker is a claim about one layer; only a
compile-and-run on the target confirms the operation at the layer that
executes it." Three-dimensional convolution carries a capability byte on every
ANE family yet fails backend lowering everywhere — attested, not reachable.

`confirm_op` is the paper's listing 4.2 carried over to XLA: build the
smallest legal graph containing only the op under test, lower+compile it
against the target, and report NATIVE or REJECTED(layer, message). The
40-cell dry-run is this same check applied to whole (arch x shape x mesh)
programs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hal
from repro.core.hal import Target


@dataclasses.dataclass(frozen=True)
class Verdict:
    op: str
    target: str
    status: str            # "NATIVE" | "REJECTED"
    layer: str             # which layer refused: "frontend" | "lowering" | "execute" | ""
    message: str = ""

    @property
    def reachable(self) -> bool:
        return self.status == "NATIVE"


def _one_op_graph(op: str) -> tuple[Callable, tuple]:
    """Smallest legal single-op graph + dummy args (paper listing 4.2)."""
    x = jnp.ones((4, 8), jnp.float32)
    idx = jnp.array([0, 2, 1, 3], jnp.int32)
    graphs: dict[str, tuple[Callable, tuple]] = {
        "matmul": (lambda a: a @ a.T, (x,)),
        "conv2d": (lambda a: jax.lax.conv_general_dilated(
            a.reshape(1, 1, 4, 8), jnp.ones((1, 1, 3, 3), jnp.float32),
            (1, 1), "SAME"), (x,)),
        "conv3d": (lambda a: jax.lax.conv_general_dilated(
            a.reshape(1, 1, 1, 4, 8), jnp.ones((1, 1, 1, 3, 3), jnp.float32),
            (1, 1, 1), "SAME"), (x,)),
        "conv2d_transpose": (lambda a: jax.lax.conv_transpose(
            a.reshape(1, 4, 8, 1), jnp.ones((3, 3, 1, 1), jnp.float32),
            (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")),
            (x,)),
        "depthwise_conv2d": (lambda a: jax.lax.conv_general_dilated(
            a.reshape(1, 2, 4, 4), jnp.ones((2, 1, 3, 3), jnp.float32),
            (1, 1), "SAME", feature_group_count=2), (x,)),
        "softmax": (lambda a: jax.nn.softmax(a, axis=-1), (x,)),
        "avg_pool": (lambda a: jax.lax.reduce_window(
            a.reshape(1, 1, 4, 8), 0.0, jax.lax.add, (1, 1, 2, 2),
            (1, 1, 2, 2), "VALID") / 4.0, (x,)),
        "max_pool": (lambda a: jax.lax.reduce_window(
            a.reshape(1, 1, 4, 8), -jnp.inf, jax.lax.max, (1, 1, 2, 2),
            (1, 1, 2, 2), "VALID"), (x,)),
        "layer_norm": (lambda a: (a - a.mean(-1, keepdims=True))
                       / (a.std(-1, keepdims=True) + 1e-5), (x,)),
        "relu": (jax.nn.relu, (x,)),
        "sigmoid": (jax.nn.sigmoid, (x,)),
        "tanh": (jnp.tanh, (x,)),
        "gelu": (jax.nn.gelu, (x,)),
        "exp": (jnp.exp, (x,)),
        "log": (lambda a: jnp.log(jnp.abs(a) + 1), (x,)),
        "sin": (jnp.sin, (x,)),
        "cos": (jnp.cos, (x,)),
        "erf": (jax.scipy.special.erf, (x,)),
        "reduce_prod": (lambda a: jnp.prod(a, axis=-1), (x,)),
        "cumsum": (lambda a: jnp.cumsum(a, axis=-1), (x,)),
        "scatter": (lambda a: a.at[idx].add(1.0), (x,)),
        "gather": (lambda a: a[idx], (x,)),
        "one_hot": (lambda a: jax.nn.one_hot(idx, 8), (x,)),
        "transpose": (lambda a: a.T, (x,)),
        "reshape": (lambda a: a.reshape(8, 4), (x,)),
        "concat": (lambda a: jnp.concatenate([a, a], axis=0), (x,)),
        "slice": (lambda a: a[:, 1:5], (x,)),
        "pad": (lambda a: jnp.pad(a, ((1, 1), (2, 2))), (x,)),
        "attention_fused": (lambda a: jax.nn.softmax(
            (a @ a.T) / np.sqrt(8.0), axis=-1) @ a, (x,)),
        "logical_and": (lambda a: jnp.logical_and(a > 0, a < 1), (x,)),
        "mod": (lambda a: jnp.mod(a, 2.0), (x,)),
        "non_zero": (lambda a: jnp.nonzero(a, size=8)[0], (x,)),
        "sort": (lambda a: jnp.sort(a, axis=-1), (x,)),
        "top_k": (lambda a: jax.lax.top_k(a, 2)[0], (x,)),
        "argmax": (lambda a: jnp.argmax(a, axis=-1), (x,)),
    }
    if op not in graphs:
        raise KeyError(f"no single-op probe graph for {op!r}")
    return graphs[op]


def confirm_op(op: str, target: Target, *, backend: str | None = None,
               mesh: jax.sharding.Mesh | None = None) -> Verdict:
    """Lower + compile (+ run when executable) the single-op graph.

    For ANE targets the 'frontend' is the HAL op-floor emulation (we cannot
    run Apple silicon here); for TPU/CPU targets the real XLA pipeline rules.
    The point the census makes is the *method*: the verdict comes from the
    layer that runs the work, never from the attestation bit.
    """
    if target.family == "ane":
        # Emulated ANE pipeline: frontend accepts anything attested; backend
        # lowering succeeds only for genuinely reachable ops (paper's split).
        if not target.attests(op):
            return Verdict(op, target.name, "REJECTED", "frontend",
                           f"{op}: not in the {target.generation} op table")
        if not target.reaches(op):
            return Verdict(op, target.name, "REJECTED", "lowering",
                           "Some ops are not supported on any of the "
                           "specified backends")
        return Verdict(op, target.name, "NATIVE", "")
    # Real XLA path.
    try:
        fn, args = _one_op_graph(op)
    except KeyError as e:
        return Verdict(op, target.name, "REJECTED", "frontend", str(e))
    try:
        lowered = jax.jit(fn).lower(*args)
    except Exception as e:  # noqa: BLE001 — the reject string IS the signal
        return Verdict(op, target.name, "REJECTED", "frontend", repr(e)[:200])
    try:
        compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001
        return Verdict(op, target.name, "REJECTED", "lowering", repr(e)[:200])
    try:
        out = compiled(*args)
        jax.block_until_ready(out)
    except Exception as e:  # noqa: BLE001
        return Verdict(op, target.name, "REJECTED", "execute", repr(e)[:200])
    return Verdict(op, target.name, "NATIVE", "")


def census(target: Target, ops: list[str] | None = None) -> list[Verdict]:
    """The operation-by-device matrix (paper Appendix A) for one target."""
    if ops is None:
        ops = sorted(set(target.op_floor) & set(_probe_ops()))
    return [confirm_op(op, target) for op in ops]


def _probe_ops() -> list[str]:
    x = jnp.ones((4, 8), jnp.float32)  # noqa: F841 — keep import-side-effect free
    return ["matmul", "conv2d", "conv3d", "conv2d_transpose",
            "depthwise_conv2d", "softmax", "layer_norm", "relu",
            "sigmoid", "tanh", "gelu", "exp", "log", "sin", "cos", "erf",
            "reduce_prod", "cumsum", "scatter", "gather", "one_hot",
            "transpose", "reshape", "concat", "slice", "pad",
            "attention_fused", "logical_and", "mod", "non_zero",
            "avg_pool", "max_pool", "argmax"]


def attested_vs_reachable(target: Target) -> list[tuple[str, bool, bool]]:
    """(op, attested, reachable) triples — the gap is the paper's point."""
    rows = []
    for op in sorted(target.op_floor):
        rows.append((op, target.attests(op), target.reaches(op)))
    return rows
