"""Analytic per-step roofline terms for one (arch x shape x mesh) cell.

Why this exists alongside the compiled-artifact numbers: XLA's
`cost_analysis()` counts a while-loop body ONCE, so any scanned-layer model
under-reports FLOPs/bytes by ~n_layers and in-loop collectives likewise
(documented in EXPERIMENTS.md §Dry-run). The dry-run therefore records both:
the raw artifact numbers (ground truth for *structure*: which collectives,
does memory fit) and these analytic numbers (ground truth for *magnitude*),
cross-checked against each other in tests on unscanned single-layer programs
where the two must agree.

This module is also the §Perf napkin-math engine: every hillclimb hypothesis
("sequence-parallel residuals cut the memory term by X", "int4 streaming
cuts decode weight bytes 4x") is priced here before it is implemented.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import costmodel, hal
from repro.core.hal import Target


@dataclasses.dataclass(frozen=True)
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


POD = MeshShape(1, 16, 16)
MULTIPOD = MeshShape(2, 16, 16)


@dataclasses.dataclass
class AnalyticTerms:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    # breakdown for the perf loop
    detail: dict

    def seconds(self, target: Target) -> dict:
        return {
            "compute_s": self.flops_per_chip / target.peak_flops,
            "memory_s": self.hbm_bytes_per_chip / target.hbm_bandwidth,
            "collective_s": self.coll_bytes_per_chip / target.collective_bandwidth,
        }

    def dominant(self, target: Target) -> str:
        s = self.seconds(target)
        return max(s, key=s.get).replace("_s", "")


def _ring(n: int) -> float:
    """Ring-collective byte multiplier: 2(n-1)/n for all-reduce."""
    return 2.0 * (n - 1) / n if n > 1 else 0.0


def analyze_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: MeshShape,
    *,
    target: Target = hal.TPU_V5E,
    weight_stream_bytes_per_param: float = 2.0,   # int4 streaming -> 0.5
    seq_parallel_residuals: bool = False,         # SP hillclimb lever
    remat: str = "full",
) -> AnalyticTerms:
    p_total = costmodel.param_count(cfg)
    p_active = costmodel.active_param_count(cfg)
    d, l, v = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    tokens_loc = tokens / mesh.dp
    p_shard = p_total / mesh.model                # TP/EP-sharded, DP-replicated
    bpe = weight_stream_bytes_per_param

    # ---------------- FLOPs ----------------
    mf = costmodel.model_flops(cfg, shape) + costmodel.attention_flops(cfg, shape)
    flops_per_chip = mf / mesh.chips

    # ---------------- HBM bytes ----------------
    detail: dict = {}
    if shape.kind == "train":
        w_traffic = p_shard * 2.0 * 3.0           # fwd read, bwd read, grad write
        opt_traffic = (p_shard / max(mesh.dp, 1)) * 20.0 if True else 0.0
        resid_dtype = 2.0
        resid_shard = mesh.model if seq_parallel_residuals else 1
        act_traffic = (l * tokens_loc * d * resid_dtype * 3.0) / resid_shard
        logits_traffic = tokens_loc * (v / mesh.model) * 4.0 * 2.0
        hbm = w_traffic + opt_traffic + act_traffic + logits_traffic
        detail.update(weights=w_traffic, optimizer=opt_traffic,
                      activations=act_traffic, logits=logits_traffic)
    # how many model-axis ways the KV cache actually shards: by KV heads
    # when divisible, by sequence under context-parallel decode, else not
    kv_div = cfg.n_kv_heads > 0 and not cfg.use_mla \
        and cfg.n_kv_heads % mesh.model == 0
    cache_model_shards = mesh.model if (kv_div or cfg.shard_cache_seq) else 1
    if shape.kind == "prefill":
        w_traffic = p_shard * bpe
        act_traffic = l * tokens_loc * d * 2.0 * 2.0
        cache_traffic = (costmodel.kv_cache_bytes(cfg, shape)
                         / (mesh.dp * cache_model_shards))
        logits_traffic = shape.global_batch / mesh.dp * (v / mesh.model) * 4.0
        hbm = w_traffic + act_traffic + cache_traffic + logits_traffic
        detail.update(weights=w_traffic, activations=act_traffic,
                      cache=cache_traffic, logits=logits_traffic,
                      cache_model_shards=cache_model_shards)
    elif shape.kind == "decode":  # one token/seq — weight + cache streaming
        p_active_shard = p_active / mesh.model
        w_traffic = p_active_shard * bpe
        cache_traffic = (costmodel.kv_cache_bytes(cfg, shape)
                         / (mesh.dp * cache_model_shards))
        act_traffic = l * tokens_loc * d * 2.0 * 4.0
        logits_traffic = tokens_loc * (v / mesh.model) * 4.0
        hbm = w_traffic + cache_traffic + act_traffic + logits_traffic
        detail.update(weights=w_traffic, cache=cache_traffic,
                      activations=act_traffic, logits=logits_traffic,
                      cache_model_shards=cache_model_shards)

    # ---------------- collective bytes ----------------
    coll = 0.0
    n_attn_tp = sum(1 for i in range(cfg.n_layers)
                    if cfg.block_kind(i) in ("attn", "rglru", "ssm"))
    if shape.kind == "train":
        # DP gradient reduction (ring over pod*data), bf16 grads
        coll_dp = _ring(mesh.dp) * p_shard * 2.0
        # TP: 2 partial-sum all-reduces per layer on the activation block
        coll_tp = (2.0 * l * tokens_loc * d * 2.0 * _ring(mesh.model) / 2.0
                   if mesh.model > 1 else 0.0)
        coll_ep = 0.0
        if cfg.n_experts:
            n_moe = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_moe(i))
            per_layer = (tokens_loc / mesh.model) * cfg.experts_per_token * d * 2.0
            # fwd: 2 a2a + 1 output all-gather; bwd mirrors it. The EP+SP
            # fusion (seq-sharded residuals) removes the all-gather entirely.
            gather = 0.0 if seq_parallel_residuals else tokens_loc * d * 2.0
            coll_ep = n_moe * (2 * per_layer * cfg.moe_capacity_factor
                               + gather) * 2.0
        coll = coll_dp + coll_tp + coll_ep
        detail.update(coll_dp=coll_dp, coll_tp=coll_tp, coll_ep=coll_ep)
    else:
        coll_tp = (2.0 * l * tokens_loc * d * 2.0 * _ring(mesh.model) / 2.0
                   if mesh.model > 1 else 0.0)
        coll_ep = 0.0
        if cfg.n_experts and shape.kind == "prefill":
            n_moe = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_moe(i))
            per_layer = (tokens_loc / mesh.model) * cfg.experts_per_token * d * 2.0
            coll_ep = n_moe * (2 * per_layer * cfg.moe_capacity_factor
                               + tokens_loc * d * 2.0)
        coll = coll_tp + coll_ep
        detail.update(coll_tp=coll_tp, coll_ep=coll_ep)

    return AnalyticTerms(flops_per_chip=flops_per_chip,
                         hbm_bytes_per_chip=hbm,
                         coll_bytes_per_chip=coll,
                         detail=detail)


def mesh_of(kind: str) -> MeshShape:
    return MULTIPOD if kind == "multipod" else POD
