"""Data pipeline: deterministic synthetic token streams, host-sharded,
double-buffered prefetch.

Production shape without production data: the pipeline produces packed
next-token batches from a seeded generator (a mixture of Zipf-distributed
unigrams and short Markov motifs so the loss has real structure to learn),
shards each batch by host the way a multi-host loader would, and prefetches
one step ahead on a background thread. Determinism: batch t is a pure
function of (seed, t), so a restart resumes bit-identically — the property
checkpoint/restart tests rely on.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    n_motifs: int = 64


class SyntheticLM:
    """Seeded synthetic language: Zipf unigrams + repeated motifs. The motifs
    make next-token prediction learnable (loss drops well below ln(V))."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))

    def _tokens(self, rng: np.random.Generator, b: int,
                length: int) -> np.ndarray:
        """(b, length) Zipf background with planted motifs — the one token
        distribution both the training batches and the serving prompts draw
        from."""
        cfg = self.cfg
        z = rng.zipf(cfg.zipf_a, size=(b, length)) - 1
        toks = np.minimum(z, cfg.vocab - 1).astype(np.int32)
        # plant motifs: ~half the positions covered by repeated motifs
        n_plant = max(1, length // (2 * cfg.motif_len))
        for i in range(b):
            ids = rng.integers(0, cfg.n_motifs, size=n_plant)
            starts = rng.integers(0, length - cfg.motif_len, size=n_plant)
            for m, st in zip(ids, starts):
                toks[i, st: st + cfg.motif_len] = self._motifs[m]
        return toks

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step): restart-safe."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        toks = self._tokens(rng, cfg.global_batch, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def prompt_batch(self, step: int, n: int, length: int) -> np.ndarray:
        """(n, length) serving prompts from the SAME motif distribution the
        model trains on — a distinct stream from `batch` (the step space is
        keyed apart), so held-out prompts never replay a training batch.
        This is what makes drafter acceptance measurable: on uniform-random
        prompts a teacher and its student agree only by luck; on in-
        distribution prompts agreement reflects the distillation."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 0x9E37))
        return self._tokens(rng, n, max(length, cfg.motif_len))[:, :length]

    def host_shard(self, batch: dict[str, np.ndarray], host_id: int,
                   n_hosts: int) -> dict[str, np.ndarray]:
        """What a multi-host loader gives each host: its batch slice."""
        out = {}
        for k, v in batch.items():
            per = v.shape[0] // n_hosts
            out[k] = v[host_id * per: (host_id + 1) * per]
        return out


class Prefetcher:
    """One-step-ahead background prefetch: the host prepares batch t+1 while
    the device runs batch t (paper §2.2 — once the command is posted the host
    is idle with respect to that work and prepares the next operands)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._source.batch(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)


def make_pipeline(cfg: ModelConfig, seq_len: int, global_batch: int,
                  seed: int = 0, start_step: int = 0) -> Prefetcher:
    return Prefetcher(SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed)), start_step=start_step)
