"""Decoder assembly: residual blocks, scan-over-layers, remat, caches.

Layers are grouped by structural signature (temporal-mixing kind x MoE-ness)
and each group runs as one `lax.scan` over stacked parameters — the
compile-once discipline (paper ch. 2) applied to HLO size: a 61-layer model
lowers to one layer body walked 61 times, exactly the "walked graph" shape
the engine executes, and what keeps the 512-device dry-run compilable.

Remat policy per config: "full" (save only layer boundaries), "dots"
(save matmul outputs), "none".
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import Params, apply_mlp, apply_norm, init_mlp, init_norm
from repro.parallel.ctx import ParallelContext

# ---------------------------------------------------------------------------
# One residual block
# ---------------------------------------------------------------------------


def layer_signature(cfg: ModelConfig, idx: int) -> tuple[str, bool]:
    return (cfg.block_kind(idx), cfg.layer_is_moe(idx))


def init_layer(key, cfg: ModelConfig, sig: tuple[str, bool], dtype) -> Params:
    kind, is_moe = sig
    k1, k2, k3, _ = jax.random.split(key, 4)
    p: Params = {"ln1": init_norm(cfg, cfg.d_model)}
    if kind == "ssm":
        p["mix"] = ssm_lib.init_ssm(k1, cfg, dtype)
        return p  # mamba blocks: norm + mixer only (no separate MLP)
    p["ln2"] = init_norm(cfg, cfg.d_model)
    if kind == "rglru":
        p["mix"] = rglru_lib.init_rglru(k1, cfg, dtype)
    else:
        p["mix"] = attn_lib.init_attention(k1, cfg, dtype)
    if is_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k3, cfg, cfg.d_model, cfg.d_ff, dtype)
    return p


def apply_layer(
    cfg: ModelConfig,
    sig: tuple[str, bool],
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    ctx: ParallelContext,
    *,
    mode: str,
    cache: Params | None,
) -> tuple[jnp.ndarray, Params | None, jnp.ndarray]:
    kind, is_moe = sig
    seq_axis = "model" if (cfg.seq_shard and mode == "train"
                           and x.shape[1] % max(ctx.axis_size("model"), 1) == 0) \
        else None
    x = ctx.constrain(x, ("pod", "data"), seq_axis, None)
    aux = jnp.zeros((), jnp.float32)

    h = apply_norm(cfg, p["ln1"], x)
    if kind == "ssm":
        out, new_cache = ssm_lib.ssm_forward(cfg, p["mix"], h, mode=mode,
                                             cache=cache, ctx=ctx)
        return x + out, new_cache, aux
    if kind == "rglru":
        out, new_cache = rglru_lib.rglru_forward(cfg, p["mix"], h, mode=mode,
                                                 cache=cache)
    else:
        out, new_cache = attn_lib.attention_forward(
            cfg, p["mix"], h, positions, mode=mode, cache=cache, ctx=ctx)
    x = x + out

    h = apply_norm(cfg, p["ln2"], x)
    if is_moe:
        out, aux = moe_lib.moe_forward(cfg, p["moe"], h, ctx)
    else:
        out = apply_mlp(cfg, p["mlp"], h)
    return x + out, new_cache, aux


def init_layer_cache(cfg: ModelConfig, sig: tuple[str, bool], batch: int,
                     max_len: int, dtype) -> Params | None:
    kind, _ = sig
    if kind == "ssm":
        return ssm_lib.init_ssm_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_lib.init_rglru_cache(cfg, batch, dtype)
    return attn_lib.init_kv_cache(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
# Layer groups: (signature tuple, count) runs of identical structure
# ---------------------------------------------------------------------------


def layer_groups(cfg: ModelConfig) -> list[tuple[tuple[tuple[str, bool], ...], int]]:
    """Group layers into scannable runs. For patterned hybrids the unit is
    one whole pattern period; for uniform stacks it is a single layer."""
    if cfg.block_pattern:
        period = tuple(layer_signature(cfg, i)
                       for i in range(len(cfg.block_pattern)))
        n_periods = cfg.n_layers // len(cfg.block_pattern)
        groups = [(period, n_periods)]
        rem = cfg.n_layers - n_periods * len(cfg.block_pattern)
        for i in range(rem):
            li = n_periods * len(cfg.block_pattern) + i
            groups.append(((layer_signature(cfg, li),), 1))
        return groups
    groups: list[tuple[tuple[tuple[str, bool], ...], int]] = []
    i = 0
    while i < cfg.n_layers:
        sig = layer_signature(cfg, i)
        j = i
        while j < cfg.n_layers and layer_signature(cfg, j) == sig:
            j += 1
        groups.append((((sig),), j - i))
        i = j
    return groups


def init_stack(key, cfg: ModelConfig, dtype) -> list[Params]:
    """One stacked-param pytree per group (leading dim = group length)."""
    out = []
    for gi, (sigs, count) in enumerate(layer_groups(cfg)):
        gkey = jax.random.fold_in(key, gi)
        keys = jax.random.split(gkey, count)

        def init_unit(k, sigs=sigs):
            ks = jax.random.split(k, len(sigs))
            return {f"sub{i}": init_layer(ks[i], cfg, sigs[i], dtype)
                    for i in range(len(sigs))}

        if count == 1:
            unit = init_unit(keys[0])
            out.append(jax.tree.map(lambda a: a[None], unit))
        else:
            out.append(jax.vmap(init_unit)(keys))
    return out


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype) -> list[Params]:
    out = []
    for sigs, count in layer_groups(cfg):
        unit = {f"sub{i}": init_layer_cache(cfg, sigs[i], batch, max_len, dtype)
                for i in range(len(sigs))}
        out.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape).copy(), unit))
    return out


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def apply_stack(
    cfg: ModelConfig,
    stacks: list[Params],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    ctx: ParallelContext,
    *,
    mode: str,
    caches: list[Params] | None = None,
) -> tuple[jnp.ndarray, list[Params] | None, jnp.ndarray]:
    """Run all layer groups. train: no caches. prefill: builds and returns
    caches. decode: consumes `caches`, returns the updated ones."""
    groups = layer_groups(cfg)
    collect = mode in ("prefill", "decode")
    new_caches: list[Params] | None = [] if collect else None
    aux_total = jnp.zeros((), jnp.float32)
    for gi, (sigs, count) in enumerate(groups):
        stacked = stacks[gi]
        gcache = caches[gi] if caches is not None else None

        def unit_fn(x, unit_p, unit_cache, sigs=sigs):
            aux = jnp.zeros((), jnp.float32)
            ncache = {}
            for i, sig in enumerate(sigs):
                sub = unit_cache[f"sub{i}"] if unit_cache is not None else None
                x, nc, a = apply_layer(cfg, sig, unit_p[f"sub{i}"], x,
                                       positions, ctx, mode=mode, cache=sub)
                aux = aux + a
                ncache[f"sub{i}"] = nc
            return x, ncache, aux

        if mode == "train":
            unit_fn = _remat(unit_fn, cfg.remat)

        if count == 1:
            unit_p = jax.tree.map(lambda a: a[0], stacked)
            unit_c = (jax.tree.map(lambda a: a[0], gcache)
                      if gcache is not None else None)
            x, ncache, aux = unit_fn(x, unit_p, unit_c)
            aux_total = aux_total + aux
            if collect:
                new_caches.append(jax.tree.map(lambda a: a[None], ncache))
        elif mode == "train":
            def body_t(carry, unit_p):
                y, _, aux = unit_fn(carry, unit_p, None)
                return y, aux
            x, auxs = jax.lax.scan(body_t, x, stacked)
            aux_total = aux_total + auxs.sum()
        elif mode == "prefill":
            def body_p(carry, unit_p):
                y, ncache, aux = unit_fn(carry, unit_p, None)
                return y, (ncache, aux)
            x, (ncaches, auxs) = jax.lax.scan(body_p, x, stacked)
            aux_total = aux_total + auxs.sum()
            new_caches.append(ncaches)
        else:  # decode
            def body_d(carry, xs):
                unit_p, unit_c = xs
                y, ncache, aux = unit_fn(carry, unit_p, unit_c)
                return y, (ncache, aux)
            x, (ncaches, auxs) = jax.lax.scan(body_d, x, (stacked, gcache))
            aux_total = aux_total + auxs.sum()
            new_caches.append(ncaches)
    return x, new_caches, aux_total
