"""Common layers: norms, RoPE, GLU MLPs, embeddings — pure JAX, functional.

Params are plain dict pytrees; every layer is (init, apply) pair style.
Initialization is truncated-normal / scaled per standard LM practice.

Numerics discipline from the paper threads through here:
  * matmuls accumulate in fp32 (`preferred_element_type`) — the wide
    accumulator (paper §3.2) is non-negotiable;
  * the logits head computes in fp32 — the "wider anchor" rule for the
    cancellation-heavy step (paper §3.4/§3.9).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import dispatched as dsp

Params = dict[str, Any]


def dot(x: jnp.ndarray, w: jnp.ndarray, dims=None) -> jnp.ndarray:
    """Matmul with a wide (fp32) accumulator, output in x.dtype.

    The `dims=None` form is one routed linear (dense `anemm` row, or the
    packed `palette`/`sparse` row for a tagged weight); explicit `dims`
    callers (SSM/RG-LRU internals) keep the raw dot_general."""
    if dims is None:
        return dsp.linear(x, w)
    out = jax.lax.dot_general(x, w, dims, preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def einsum32(subscript: str, *args) -> jnp.ndarray:
    out = jnp.einsum(subscript, *args, preferred_element_type=jnp.float32)
    return out.astype(args[0].dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: int) -> Params:
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p.get("bias", 0.0)
    else:  # rmsnorm
        ms = (x32 * x32).mean(-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


def rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """Per-head RMS norm used by QK-norm (chameleon stability recipe)."""
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps) * scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(key, cfg: ModelConfig, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d ** -0.5
    std_out = f ** -0.5
    if cfg.act == "gelu_mlp":                           # plain 2-matrix MLP
        p = {"wi": jax.random.normal(k1, (d, f), dtype) * std_in,
             "wo": jax.random.normal(k2, (f, d), dtype) * std_out}
        if cfg.use_bias:
            p["bi"] = jnp.zeros((f,), dtype)
            p["bo"] = jnp.zeros((d,), dtype)
        return p
    return {"wg": jax.random.normal(k1, (d, f), dtype) * std_in,
            "wu": jax.random.normal(k2, (d, f), dtype) * std_in,
            "wd": jax.random.normal(k3, (f, d), dtype) * std_out}


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "wi" in p:                                       # plain MLP
        h = dsp.linear(x, p["wi"], bias=p.get("bi"))
        h = jax.nn.gelu(h)
        return dsp.linear(h, p["wo"], bias=p.get("bo"))
    act = _ACTS.get(cfg.act, jax.nn.silu)
    g = act(dsp.linear(x, p["wg"]))
    u = dsp.linear(x, p["wu"])
    return dsp.linear(g * u, p["wd"])


# ---------------------------------------------------------------------------
# Embedding / logits head
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig, dtype) -> Params:
    v = cfg.padded_vocab
    p = {"table": jax.random.normal(key, (v, cfg.d_model), dtype) * 0.02}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = jax.random.normal(
            k2, (cfg.d_model, v), dtype) * (cfg.d_model ** -0.5)
    return p


def embed_tokens(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def logits(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Always fp32 out: the cancellation-heavy step gets the wide anchor."""
    if cfg.tie_embeddings:
        w = p["table"].T
    else:
        w = p["unembed"]
    if isinstance(w, dsp.DispatchedWeight) or dsp.active_dispatcher() is not None:
        # routed head: run the whole matmul in fp32 so the anchor holds even
        # when the kernel stores in the activation dtype
        return dsp.linear(x.astype(jnp.float32), w)
    out = jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out  # fp32


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings for the encoder frames."""
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)
