"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

The real-gated linear recurrent unit:

    r_t = sigmoid(W_r x_t)          (recurrence gate)
    i_t = sigmoid(W_i x_t)          (input gate)
    a_t = a ^ (c * r_t)             with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill: `associative_scan` over the sequence (log-depth — the diagonal
recurrence is exactly the associative form). Decode: O(1) update; together
with the sliding-window attention blocks this makes the hybrid sub-quadratic
(the `long_500k` cell).

Block structure (Griffin residual block): temporal conv1d -> RG-LRU on one
branch, gelu gate on the other, merged by an output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dot
from repro.models.ssm import _causal_conv

_C = 8.0
_LOG2 = 0.6931471805599453


def init_rglru(key, cfg: ModelConfig, dtype) -> Params:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    # Lambda init so a = sigmoid(Lambda) in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    return {
        "linear_x": jax.random.normal(ks[1], (d, w), dtype) * std,
        "linear_y": jax.random.normal(ks[2], (d, w), dtype) * std,
        "conv_w": jax.random.normal(ks[3], (4, w), dtype) * 0.5,
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": jax.random.normal(ks[4], (w, w), dtype) * w ** -0.5,
        "w_i": jax.random.normal(ks[5], (w, w), dtype) * w ** -0.5,
        "lam": jnp.log(u) - jnp.log1p(-u),
        "out": jax.random.normal(jax.random.fold_in(key, 9), (w, d), dtype)
               * w ** -0.5,
    }


def _gates(p: Params, xi: jnp.ndarray):
    r = jax.nn.sigmoid(dot(xi, p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dot(xi, p["w_i"]).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(p["lam"])            # log a, negative
    log_a = _C * r * log_a_base                          # (…, w)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xi.astype(jnp.float32)


def rglru_forward(
    cfg: ModelConfig,
    p: Params,
    xin: jnp.ndarray,             # (B, S, D)
    *,
    mode: str = "train",
    cache: Params | None = None,
) -> tuple[jnp.ndarray, Params | None]:
    bsz, s, _ = xin.shape
    gate = jax.nn.gelu(dot(xin, p["linear_y"]))
    xi = dot(xin, p["linear_x"])

    if mode in ("train", "prefill"):
        xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"])
        a, b = _gates(p, xi)                              # (B,S,w) each
        # h_t = a_t h_{t-1} + b_t  — diagonal linear recurrence
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
        if mode == "prefill":
            new_cache = {"h": h[:, -1].astype(xin.dtype), "conv": conv_state}
    elif s == 1:  # decode
        assert cache is not None
        xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                      state=cache["conv"])
        a, b = _gates(p, xi)                              # (B,1,w)
        h = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
        new_cache = {"h": h.astype(xin.dtype), "conv": conv_state}
        h = h[:, None]
    else:  # prefill chunk: scan resumed from the carried hidden state
        assert cache is not None
        xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                      state=cache["conv"])
        a, b = _gates(p, xi)                              # (B,S,w)
        # fold h_{-1} into the first scan element: h_0 = a_0 h_{-1} + b_0
        b = b.at[:, 0].add(a[:, 0] * cache["h"].astype(jnp.float32))
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = {"h": h[:, -1].astype(xin.dtype), "conv": conv_state}
    out = dot((h.astype(xin.dtype) * gate), p["out"])
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "h": jnp.zeros((batch, cfg.lru_width), dtype),
        "conv": jnp.zeros((batch, 3, cfg.lru_width), dtype),
    }
