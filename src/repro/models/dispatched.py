"""DispatchedLinear: the model stack's route into `core.dispatch`.

The paper's execution story (ch. 4/5/7) is op-by-device routing for the
*whole graph*: every matmul an application runs is resolved against the
target's capability surface, and compressed weights (palettized or sparse)
are consumed in their packed form — dequantized at the multiplier input —
rather than folded to dense on the host. This module is that route for our
model stack:

  * `DispatchedWeight` — a pytree node carrying a packed weight (palette
    nibbles + codebook, or 1:2 sparse values + selector bits) together with
    its static `WeightForm` tag. The tag rides in the pytree aux data, so it
    survives jit tracing, `lax.scan` stacking/slicing over layers, expert
    indexing, and checkpoint round trips (`checkpoint/` knows the node).
  * `linear(x, w)` — the single matmul entry point the layers call. Every
    projection, MLP matrix, MoE expert bank, and logits head resolves here:
    with a dispatcher in scope the call is routed through
    `core.dispatch.KernelDispatcher` (`anemm` for dense, `palette`/`sparse`
    for packed forms) with oracle fallback when the configured HAL target
    gates the kernel; with no dispatcher and a plain dense weight it lowers
    to the exact `dot_general` the seed emitted (bit-stable default path).
  * `flash_route` / `decode_route` — the attention matmuls, routed through
    the `flash` and `decode_attention` registry rows the same way.

Scope is managed with `use_dispatcher(d)`; `launch/serve.py`, the examples,
and the parity harness (`tests/test_model_dispatch_parity.py`) activate it.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import KernelDispatcher
from repro.core.hal import WeightForm

# ---------------------------------------------------------------------------
# Weight-form-tagged packed weights
# ---------------------------------------------------------------------------

# WeightForm -> kernel-registry row that streams it
FORM_KERNELS: dict[WeightForm, str] = {
    WeightForm.INT4_PALETTE: "palette",
    WeightForm.SPARSE: "sparse",
}


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class DispatchedWeight:
    """A packed weight + its static routing tag, as one pytree node.

    `payload` holds the form-specific arrays (children); everything else is
    aux data, so tree ops that stack or slice the payload (scan over layers,
    per-expert indexing, vmap) keep the tag intact.

    The payload is packed over the 2-D matmul view (K = prod of contracted
    dims, N = prod of output dims); `contract_shape`/`out_shape` remember the
    logical dense layout and `dtype_name` the dense dtype, so `dense()` can
    reconstruct exactly what the kernel streams.
    """

    form: WeightForm
    contract_shape: tuple[int, ...]
    out_shape: tuple[int, ...]
    dtype_name: str
    payload: dict[str, Any]

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        keys = tuple(sorted(self.payload))
        children = [(jax.tree_util.DictKey(k), self.payload[k]) for k in keys]
        aux = (self.form, self.contract_shape, self.out_shape,
               self.dtype_name, keys)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        form, contract_shape, out_shape, dtype_name, keys = aux
        return cls(form, contract_shape, out_shape, dtype_name,
                   dict(zip(keys, children)))

    # -- views --------------------------------------------------------------
    @property
    def kernel(self) -> str:
        return FORM_KERNELS[self.form]

    @property
    def n_stack(self) -> int:
        """Leading stack dims still carried by the payload (layer-scan /
        expert dims); 0 once scan slicing has reached the 2-D matmul view."""
        ref = self.payload["packed" if self.form == WeightForm.INT4_PALETTE
                           else "values"]
        return ref.ndim - 2

    def index(self, i) -> "DispatchedWeight":
        """Slice one leading stack dim (expert banks inside the MoE loop)."""
        return jax.tree.map(lambda a: a[i], self)

    def stack_specs(self, *axes) -> "DispatchedWeight":
        """PartitionSpec pytree (same structure, one spec per payload leaf)
        assigning `axes[i]` to leading stack dim `i`. Only stack dims are
        addressable for sharding: the packed 2-D matmul view interleaves
        logical K/N into nibble planes / codebooks / selector bits, so
        whole-bank (layer/expert) partitioning is the sole meaningful cut.
        Every payload leaf carries the same leading stack dims, so one
        prefix spec serves them all (trailing dims replicate). The result
        is valid as a `shard_map` in_spec or `NamedSharding` spec tree."""
        if len(axes) > self.n_stack:
            raise ValueError(f"{len(axes)} spec axes for {self.n_stack} "
                             "stack dims; packed matmul dims cannot shard")
        spec = jax.sharding.PartitionSpec(*axes)
        return jax.tree.map(lambda _: spec, self)

    def dense(self) -> jnp.ndarray:
        """Decode the 2-D packed payload back to the logical dense weight —
        the FOLD path the oracle and the parity reference multiply against."""
        if self.n_stack:
            raise ValueError("dense() wants the 2-D matmul view; "
                             "slice stack dims first")
        if self.form == WeightForm.INT4_PALETTE:
            from repro.kernels.palette.palette_matmul import unpack_dense
            w2 = unpack_dense(self.payload["packed"],
                              self.payload["lut"].astype(jnp.float32))
        else:
            from repro.kernels.sparse.sparse_matmul import unpack_dense
            w2 = unpack_dense(self.payload["values"],
                              self.payload["selector"])
        return w2.reshape(self.contract_shape + self.out_shape).astype(
            jnp.dtype(self.dtype_name))


def pack_linear_weight(w: np.ndarray, form: WeightForm, *,
                       n_contract: int, n_out: int,
                       palette_iters: int = 4) -> DispatchedWeight:
    """Pack one logical weight (stack dims + contract dims + out dims) into
    `form`. Stack dims (layer-scan, expert) are preserved as leading payload
    dims: `lax.scan`/`index()` slice them back to the 2-D matmul view."""
    from repro.kernels.palette.palette_matmul import pack_kn
    from repro.kernels.sparse.sparse_matmul import pack_pair_sparse

    w = np.asarray(w)
    dtype_name = jnp.dtype(w.dtype).name
    n_stack = w.ndim - n_contract - n_out
    if n_stack < 0:
        raise ValueError(f"weight rank {w.ndim} < contract {n_contract} + "
                         f"out {n_out}")
    contract_shape = w.shape[n_stack:n_stack + n_contract]
    out_shape = w.shape[n_stack + n_contract:]
    k = int(np.prod(contract_shape))
    n = int(np.prod(out_shape))
    lead = w.shape[:n_stack]
    w2 = np.asarray(w, np.float32).reshape(lead + (k, n))

    def pack2d(mat: np.ndarray) -> dict[str, np.ndarray]:
        if form == WeightForm.INT4_PALETTE:
            packed, lut = pack_kn(mat, iters=palette_iters)
            return {"packed": packed, "lut": lut}
        vals, sel = pack_pair_sparse(mat)
        return {"values": vals, "selector": sel}

    if not lead:
        payload = {k_: jnp.asarray(v) for k_, v in pack2d(w2).items()}
    else:
        slices = [pack2d(w2[idx]) for idx in np.ndindex(*lead)]
        payload = {
            k_: jnp.asarray(
                np.stack([s[k_] for s in slices]).reshape(
                    lead + slices[0][k_].shape))
            for k_ in slices[0]
        }
    return DispatchedWeight(form, contract_shape, out_shape, dtype_name,
                            payload)


def packable(form: WeightForm, k: int) -> bool:
    """Can a matmul view with contraction extent `k` pack into `form`?"""
    if form == WeightForm.INT4_PALETTE:
        return k % 2 == 0
    if form == WeightForm.SPARSE:
        return k % 16 == 0
    return False


# ---------------------------------------------------------------------------
# Dispatcher scope
# ---------------------------------------------------------------------------

_SCOPE: list[KernelDispatcher] = []
_DEFAULT: KernelDispatcher | None = None


@contextlib.contextmanager
def use_dispatcher(dispatcher: KernelDispatcher | None) -> Iterator[None]:
    """Route every `linear`/attention matmul traced inside through
    `dispatcher`. `None` is a no-op scope (keeps call sites unconditional)."""
    if dispatcher is None:
        yield
        return
    _SCOPE.append(dispatcher)
    try:
        yield
    finally:
        _SCOPE.pop()


def active_dispatcher() -> KernelDispatcher | None:
    return _SCOPE[-1] if _SCOPE else None


_FUSION: list[bool] = []


@contextlib.contextmanager
def fuse_epilogues(on: bool) -> Iterator[None]:
    """Scope the conv/matmul LUT-epilogue fusion choice. Fused (the default)
    runs the activation at the producing kernel's output port — one engine
    dispatch; unfused routes a separate `act_lut` op afterwards — the
    two-dispatch pipeline `bench_encoder` measures against."""
    _FUSION.append(on)
    try:
        yield
    finally:
        _FUSION.pop()


def epilogue_fusion_active() -> bool:
    return _FUSION[-1] if _FUSION else True


def _dispatcher_for(w: Any) -> KernelDispatcher | None:
    """The dispatcher a call must use: the scoped one, or — for a packed
    weight that *cannot* run undispatched — a default TPU-target one."""
    d = active_dispatcher()
    if d is None and isinstance(w, DispatchedWeight):
        global _DEFAULT
        if _DEFAULT is None:
            _DEFAULT = KernelDispatcher()
        return _DEFAULT
    return d


# ---------------------------------------------------------------------------
# Routed execution
# ---------------------------------------------------------------------------


def route_and_run(disp: KernelDispatcher, name: str, dtype,
                  native: Callable[[], Any], oracle: Callable[[], Any]):
    """One op-by-device cell: resolve through the dispatcher's capability
    gates, record the route, run the winning backend. Unlike
    `KernelDispatcher.__call__` the two legs are callables, so call sites
    can pass extra kwargs (window, causal) or differentiable wrappers."""
    route = disp.resolve(name, dtype)
    disp.routes.append(route)
    return native() if route.native else oracle()


def _matmul_dense(disp: KernelDispatcher, a2: jnp.ndarray,
                  w2: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.anemm import ops as anemm_ops
    from repro.kernels.anemm.ref import anemm_ref

    return route_and_run(
        disp, "anemm", a2.dtype,
        lambda: anemm_ops.matmul(a2, w2.astype(a2.dtype)),
        lambda: anemm_ref(a2, w2.astype(a2.dtype)))


def _matmul_packed(disp: KernelDispatcher, a2: jnp.ndarray,
                   w: DispatchedWeight) -> jnp.ndarray:
    # "a" first: KernelDispatcher resolves the route off the bundle's first
    # floating leaf (the activation dtype).
    if w.form == WeightForm.INT4_PALETTE:
        bundle = {"a": a2, "packed": w.payload["packed"],
                  "lut": w.payload["lut"]}
    elif w.form == WeightForm.SPARSE:
        bundle = {"a": a2, "values": w.payload["values"],
                  "selector": w.payload["selector"]}
    else:
        raise ValueError(f"no streaming kernel for {w.form}")
    return disp(w.kernel, bundle)


def linear(x: jnp.ndarray, w: Any, *, n_contract: int = 1,
           bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """The matmul every layer calls: contract the trailing `n_contract` dims
    of `x` with the leading dims of `w`.

    * packed weight -> `palette`/`sparse` kernel through the dispatcher
      (oracle fallback when the HAL gates the form/op/dtype);
    * dense weight + dispatcher in scope -> the `anemm` row, same gates;
    * dense weight, no dispatcher -> the seed's exact wide-accumulator
      `dot_general` (train-time default; numerically unchanged).
    """
    disp = _dispatcher_for(w)
    if isinstance(w, DispatchedWeight):
        if w.n_stack:
            raise ValueError("packed weight still carries stack dims "
                             f"{w.n_stack}; slice before linear()")
        k = int(np.prod(x.shape[x.ndim - n_contract:]))
        out2 = _matmul_packed(disp, x.reshape(-1, k), w)
        out = out2.reshape(x.shape[:x.ndim - n_contract] + w.out_shape)
    elif disp is not None:
        k = int(np.prod(x.shape[x.ndim - n_contract:]))
        out2 = _matmul_dense(disp, x.reshape(-1, k), w.reshape(k, -1))
        out = out2.reshape(x.shape[:x.ndim - n_contract] + w.shape[n_contract:])
    else:
        dims = ((tuple(range(x.ndim - n_contract, x.ndim)),
                 tuple(range(n_contract))), ((), ()))
        out = jax.lax.dot_general(
            x, w, dims, preferred_element_type=jnp.float32).astype(x.dtype)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Attention routes (flash prefill/train, one-token decode)
# ---------------------------------------------------------------------------


def flash_route(disp: KernelDispatcher, q: jnp.ndarray, k: jnp.ndarray,
                v: jnp.ndarray, *, causal: bool = True) -> jnp.ndarray:
    """Fused-attention cell for (B, S, H, dh)-layout q/k/v. Native = the
    Pallas flash kernel (recompute backward, so the train path
    differentiates); gated = the chunked online-softmax reference."""
    def native():
        from repro.kernels.flash import ops as flash_ops
        out = flash_ops.attention(q.transpose(0, 2, 1, 3),
                                  k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3), causal, None)
        return out.transpose(0, 2, 1, 3)

    def oracle():
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal)

    return route_and_run(disp, "flash", q.dtype, native, oracle)


def decode_route(disp: KernelDispatcher, q: jnp.ndarray,
                 k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 positions: jnp.ndarray, current: jnp.ndarray, *,
                 window: int | None = None) -> jnp.ndarray:
    """One-token decode cell: q (B, H, dh) against a (B, S, KV, dh) cache.
    Gated on `gather` (H13/M1 has none), so the op-by-device matrix sends
    this to the oracle on early ANE targets — the paper's cell, live."""
    def native():
        from repro.kernels.flash.decode_attention import decode_attention
        return decode_attention(q, k_cache, v_cache, positions, current,
                                window=window)

    def oracle():
        from repro.kernels.flash.decode_attention import decode_attention_ref
        return decode_attention_ref(q, k_cache, v_cache, positions, current,
                                    window=window)

    return route_and_run(disp, "decode_attention", q.dtype, native, oracle)


# ---------------------------------------------------------------------------
# Conv-family routes (encoder stems, vision front ends)
# ---------------------------------------------------------------------------


def conv2d(x: jnp.ndarray, w: jnp.ndarray,
           bias: jnp.ndarray | None = None, *,
           stride: tuple[int, int] = (1, 1), padding: str = "SAME",
           act: str | None = None) -> jnp.ndarray:
    """The conv every encoder stem calls (NHWC x, HWIO w).

    * dispatcher in scope + `act` + fusion on (default): ONE routed `conv2d`
      dispatch with the LUT activation fused at the output port;
    * dispatcher + `act` + fusion off: a routed `conv2d` followed by a
      routed `act_lut` — the separate-op pipeline, bit-identical output;
    * no dispatcher: the differentiable jnp reference with the same LUT
      numerics, so dispatched-vs-reference parity differs only by the conv
      kernel's accumulation order.
    """
    from repro.kernels.conv.ref import conv2d_ref

    disp = active_dispatcher()
    if disp is None:
        return conv2d_ref(x, w, bias, stride=stride, padding=padding,
                          epilogue=act)

    from repro.kernels.conv import ops as conv_ops

    if act is not None and epilogue_fusion_active():
        return route_and_run(
            disp, "conv2d", x.dtype,
            lambda: conv_ops.conv2d(x, w, bias, stride=stride,
                                    padding=padding, epilogue=act),
            lambda: conv2d_ref(x, w, bias, stride=stride, padding=padding,
                               epilogue=act))
    out = route_and_run(
        disp, "conv2d", x.dtype,
        lambda: conv_ops.conv2d(x, w, bias, stride=stride, padding=padding),
        lambda: conv2d_ref(x, w, bias, stride=stride, padding=padding))
    if act is None:
        return out

    from repro.kernels.act_lut.ops import lut_activation, lut_apply_ref

    return route_and_run(
        disp, "act_lut", out.dtype,
        lambda: lut_activation(act)(out),
        lambda: lut_apply_ref(out, act))


def _pool(x: jnp.ndarray, *, window, stride, padding, kind: str):
    from repro.kernels.conv import ops as conv_ops
    from repro.kernels.conv import ref as conv_ref

    native = conv_ops.avg_pool if kind == "avg_pool" else conv_ops.max_pool
    oracle = (conv_ref.avg_pool_ref if kind == "avg_pool"
              else conv_ref.max_pool_ref)
    disp = active_dispatcher()
    if disp is None:
        return oracle(x, window=window, stride=stride, padding=padding)
    return route_and_run(
        disp, kind, x.dtype,
        lambda: native(x, window=window, stride=stride, padding=padding),
        lambda: oracle(x, window=window, stride=stride, padding=padding))


def avg_pool(x: jnp.ndarray, *, window: tuple[int, int],
             stride: tuple[int, int] | None = None,
             padding: str = "VALID") -> jnp.ndarray:
    """Routed NHWC average pooling (count-include-pad)."""
    return _pool(x, window=window, stride=stride or window, padding=padding,
                 kind="avg_pool")


def max_pool(x: jnp.ndarray, *, window: tuple[int, int],
             stride: tuple[int, int] | None = None,
             padding: str = "VALID") -> jnp.ndarray:
    """Routed NHWC max pooling."""
    return _pool(x, window=window, stride=stride or window, padding=padding,
                 kind="max_pool")
