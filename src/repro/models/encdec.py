"""Encoder-decoder backbone (Whisper-small) with a real conv stem.

When `cfg.n_mels > 0`, `input_specs()` supplies log-mel frames
(B, stem_stride * encoder_len, n_mels) and the encoder opens with Whisper's
two-conv stem: two width-`stem_width` time convs with GELU, the second
downsampling time by `stem_stride`, projecting mels to d_model. The stem
routes through `dispatched.conv2d` — the conv2d kernel row with the LUT-GELU
epilogue fused at the output port when a dispatcher is in scope (and the
bit-identical jnp reference when not). With `n_mels == 0` the frontend stays
the seed's stub: pre-projected (B, encoder_len, d_model) embeddings.

From there it is the transformer backbone: a bidirectional encoder and a
causal decoder with cross-attention. The decoder carries two caches: its own
self-attention KV cache and the cross-attention K/V computed once at prefill
(the resident-state pattern of paper §2.6 — the encoder output never
re-crosses the host).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import dispatched as dsp
from repro.models.layers import (Params, apply_mlp, apply_norm, init_mlp,
                                 init_norm, sinusoidal_positions)
from repro.parallel.ctx import ParallelContext


def init_encdec_stacks(key, cfg: ModelConfig, dtype) -> Params:
    ke, kd = jax.random.split(key)

    def enc_unit(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm(cfg, cfg.d_model),
                "attn": attn_lib.init_attention(k1, cfg, dtype),
                "ln2": init_norm(cfg, cfg.d_model),
                "mlp": init_mlp(k2, cfg, cfg.d_model, cfg.d_ff, dtype)}

    def dec_unit(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_norm(cfg, cfg.d_model),
                "self_attn": attn_lib.init_attention(k1, cfg, dtype),
                "lnx": init_norm(cfg, cfg.d_model),
                "cross_attn": attn_lib.init_attention(k2, cfg, dtype, cross=True),
                "ln2": init_norm(cfg, cfg.d_model),
                "mlp": init_mlp(k3, cfg, cfg.d_model, cfg.d_ff, dtype)}

    p = {
        "enc": jax.vmap(enc_unit)(jax.random.split(ke, cfg.n_encoder_layers)),
        "enc_ln": init_norm(cfg, cfg.d_model),
        "dec": jax.vmap(dec_unit)(jax.random.split(kd, cfg.n_layers)),
    }
    if cfg.n_mels:
        ks1, ks2 = jax.random.split(jax.random.fold_in(key, 7))
        kw, d = cfg.stem_width, cfg.d_model
        p["stem"] = {
            "w1": jax.random.normal(ks1, (1, kw, cfg.n_mels, d), dtype)
            * (kw * cfg.n_mels) ** -0.5,
            "b1": jnp.zeros((d,), dtype),
            "w2": jax.random.normal(ks2, (1, kw, d, d), dtype)
            * (kw * d) ** -0.5,
            "b2": jnp.zeros((d,), dtype),
        }
    return p


def conv_stem(cfg: ModelConfig, stem: Params,
              frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper's mel frontend: (B, stem_stride*enc_len, n_mels) log-mel
    frames -> (B, enc_len, d_model). Two width-`stem_width` time convs with
    GELU; the second downsamples time by `stem_stride`. Runs as NHWC conv2d
    with a unit height axis, activations fused as LUT epilogues."""
    x = frames[:, None]                              # (B, 1, T, n_mels)
    x = dsp.conv2d(x, stem["w1"], stem["b1"], stride=(1, 1),
                   padding="SAME", act="gelu")
    x = dsp.conv2d(x, stem["w2"], stem["b2"], stride=(1, cfg.stem_stride),
                   padding="SAME", act="gelu")
    return x[:, 0]                                   # (B, enc_len, d_model)


def encode(cfg: ModelConfig, p: Params, frames: jnp.ndarray,
           ctx: ParallelContext) -> jnp.ndarray:
    """frames: `cfg.frame_shape` per request — mel frames through the conv
    stem when present, else stub (B, enc_len, d_model) embeddings."""
    if cfg.n_mels:
        frames = conv_stem(cfg, p["stem"], frames)
    x = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)
    x = ctx.constrain(x, ("pod", "data"), None, None)

    def body(carry, unit):
        h = apply_norm(cfg, unit["ln1"], carry)
        q = dsp.linear(h, unit["attn"]["wq"], bias=unit["attn"].get("bq"))
        k = dsp.linear(h, unit["attn"]["wk"], bias=unit["attn"].get("bk"))
        v = dsp.linear(h, unit["attn"]["wv"], bias=unit["attn"].get("bv"))
        disp = dsp.active_dispatcher()
        if disp is not None:
            out = dsp.flash_route(disp, q, k, v, causal=False)
        else:
            out = attn_lib.chunked_attention(q, k, v, causal=False)
        out = dsp.linear(out, unit["attn"]["wo"], n_contract=2,
                         bias=unit["attn"].get("bo"))
        x = carry + out
        h = apply_norm(cfg, unit["ln2"], x)
        return x + apply_mlp(cfg, unit["mlp"], h), None

    x, _ = jax.lax.scan(body, x, p["enc"])
    return apply_norm(cfg, p["enc_ln"], x)


def build_cross_cache(cfg: ModelConfig, p: Params,
                      enc_out: jnp.ndarray) -> Params:
    """Per-layer cross K/V, stacked (L, B, enc_len, KV, dh) — computed once."""
    def per_layer(unit):
        k, v = attn_lib.encode_cross_kv(cfg, unit["cross_attn"], enc_out)
        return {"k": k, "v": v}
    return jax.vmap(per_layer)(p["dec"])


def decoder_stack(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    ctx: ParallelContext,
    *,
    mode: str,
    cross: Params,                 # stacked cross K/V
    caches: Params | None = None,  # stacked self-attn caches (decode)
) -> tuple[jnp.ndarray, Params | None]:
    collect = mode in ("prefill", "decode")

    def unit_fn(x, unit, cross_kv, cache):
        h = apply_norm(cfg, unit["ln1"], x)
        out, ncache = attn_lib.attention_forward(
            cfg, unit["self_attn"], h, positions, mode=mode, cache=cache)
        x = x + out
        h = apply_norm(cfg, unit["lnx"], x)
        x = x + attn_lib.cross_attention_forward(
            cfg, unit["cross_attn"], h, (cross_kv["k"], cross_kv["v"]))
        h = apply_norm(cfg, unit["ln2"], x)
        return x + apply_mlp(cfg, unit["mlp"], h), ncache

    if mode == "train":
        def body(carry, xs):
            unit, cross_kv = xs
            y, _ = jax.checkpoint(unit_fn)(carry, unit, cross_kv, None)
            return y, None
        x, _ = jax.lax.scan(body, x, (p["dec"], cross))
        return x, None
    if mode == "prefill":
        def body_p(carry, xs):
            unit, cross_kv = xs
            y, nc = unit_fn(carry, unit, cross_kv, None)
            return y, nc
        x, ncaches = jax.lax.scan(body_p, x, (p["dec"], cross))
        return x, ncaches
    def body_d(carry, xs):
        unit, cross_kv, cache = xs
        y, nc = unit_fn(carry, unit, cross_kv, cache)
        return y, nc
    x, ncaches = jax.lax.scan(body_d, x, (p["dec"], cross, caches))
    return x, ncaches


def init_decoder_cache(cfg: ModelConfig, batch: int, max_len: int,
                       dtype) -> Params:
    unit = attn_lib.init_kv_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(),
        unit)
