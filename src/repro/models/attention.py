"""Attention: GQA (full/causal/sliding-window), MLA, KV caches, decode steps.

Three compute paths:
  * `chunked_attention` — double-blocked online-softmax attention in pure
    jax.lax (flash-style): Q blocks x KV blocks with running (max, denom,
    acc) carried through a scan. This keeps the live working set to one
    (q_block x kv_block) score tile — the paper's working-set rule (§9.2)
    applied to the TPU: never materialize an (S x S) score matrix. The Pallas
    `kernels/flash` kernel is the TPU-optimized form; this is the portable
    default the dry-run lowers.
  * decode: one-token attention against a (possibly rolling-window) cache.
  * MLA (DeepSeek): latent KV cache; prefill expands from the latent, decode
    uses the absorbed-matmul form so per-step work is O(S * (kv_lora + rope))
    instead of O(S * H * dh).

Caches are plain pytrees so they donate cleanly (the paper's resident-state
rule, §2.6: the held tensor never re-crosses the host boundary).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dispatched as dsp
from repro.models.layers import Params, apply_rope, einsum32, rms_head_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    if cfg.use_mla and not cross:
        qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wq_a": jax.random.normal(ks[0], (d, cfg.q_lora_rank), dtype) * std,
            "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.float32),
            "wq_b": jax.random.normal(
                ks[1], (cfg.q_lora_rank, h, qk_head), dtype) * cfg.q_lora_rank ** -0.5,
            "wkv_a": jax.random.normal(
                ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype) * std,
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
            "wkv_b": jax.random.normal(
                ks[3], (cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim),
                dtype) * cfg.kv_lora_rank ** -0.5,
            "wo": jax.random.normal(
                ks[4], (h, cfg.v_head_dim, d), dtype) * (h * cfg.v_head_dim) ** -0.5,
        }
        return p
    p = {
        "wq": jax.random.normal(ks[0], (d, h, dh), dtype) * std,
        "wk": jax.random.normal(ks[1], (d, kv, dh), dtype) * std,
        "wv": jax.random.normal(ks[2], (d, kv, dh), dtype) * std,
        "wo": jax.random.normal(ks[3], (h, dh, d), dtype) * (h * dh) ** -0.5,
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), jnp.float32)
        p["k_scale"] = jnp.ones((dh,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mask_for(qpos, kpos, causal, window, skv):
    allow = kpos[None, :] <= qpos[:, None] if causal else \
        jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if window is not None:
        allow &= (qpos[:, None] - kpos[None, :]) < window
    allow &= (kpos < skv)[None, :]
    return allow


def _attn_fwd_impl(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                   scale, skv):
    """Returns (out (B,Sq_pad,KV,G,dh), lse (B,Sq_pad,KV,G)) — the flash
    forward; lse is the per-row log-sum-exp the backward needs."""
    b, sq_pad, kvh, g, dh = q.shape
    nq = sq_pad // q_chunk
    nk = k.shape[1] // kv_chunk
    qb = q.reshape(b, nq, q_chunk, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, nk, kv_chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, kv_chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(nq * q_chunk).reshape(nq, q_chunk)
    k_pos = jnp.arange(nk * kv_chunk).reshape(nk, kv_chunk)

    def q_step(_, qi):
        qblk, qpos = qi
        m0 = jnp.full((b, q_chunk, kvh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kvh, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kvh, g, dh), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kpos = ki
            s = jnp.einsum("bqkgd,bckd->bqkgc", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            allow = _mask_for(qpos, kpos, causal, window, skv)
            s = jnp.where(allow[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, k_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(q.dtype), lse)

    _, (blocks, lses) = jax.lax.scan(q_step, None, (qb, q_pos))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_chunk, kvh, g, dh)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, kvh, g)
    return out, lse


def _attn_bwd_impl(q, k, v, out, lse, dout, causal, window, q_offset,
                   q_chunk, kv_chunk, scale, skv):
    """Flash-style backward: recomputes each (q,kv) tile from (q,k,v,lse);
    nothing quadratic is ever saved. dk/dv accumulate into full-size carries
    updated slice-by-slice."""
    b, sq_pad, kvh, g, dh = q.shape
    nq = sq_pad // q_chunk
    nk = k.shape[1] // kv_chunk
    qb = q.reshape(b, nq, q_chunk, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    ob = out.reshape(b, nq, q_chunk, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    dob = dout.reshape(b, nq, q_chunk, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    lseb = lse.reshape(b, nq, q_chunk, kvh, g).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(b, nk, kv_chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, kv_chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(nq * q_chunk).reshape(nq, q_chunk)
    k_pos = jnp.arange(nk * kv_chunk).reshape(nk, kv_chunk)

    dk0 = jnp.zeros((nk, b, kv_chunk, kvh, dh), jnp.float32)
    dv0 = jnp.zeros_like(dk0)

    def q_step(carry, qi):
        dk_all, dv_all = carry
        qblk, oblk, doblk, lseblk, qpos = qi
        q32 = qblk.astype(jnp.float32)
        do32 = doblk.astype(jnp.float32)
        # D_i = rowsum(dO * O)
        delta = jnp.sum(do32 * oblk.astype(jnp.float32), axis=-1)  # (b,qc,kv,g)

        def kv_step(inner, ki_idx):
            dq_acc, dk_all, dv_all = inner
            kblk = kb[ki_idx]
            vblk = vb[ki_idx]
            kpos = k_pos[ki_idx]
            k32 = kblk.astype(jnp.float32)
            v32 = vblk.astype(jnp.float32)
            s = jnp.einsum("bqkgd,bckd->bqkgc", q32, k32) * scale
            allow = _mask_for(qpos, kpos, causal, window, skv)
            s = jnp.where(allow[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lseblk[..., None])                    # (b,qc,kv,g,c)
            dv_blk = jnp.einsum("bqkgc,bqkgd->bckd", p, do32)
            dp = jnp.einsum("bqkgd,bckd->bqkgc", do32, v32)
            ds = p * (dp - delta[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bqkgc,bckd->bqkgd", ds, k32)
            dk_blk = jnp.einsum("bqkgc,bqkgd->bckd", ds, q32)
            dk_all = dk_all.at[ki_idx].add(dk_blk)
            dv_all = dv_all.at[ki_idx].add(dv_blk)
            return (dq_acc, dk_all, dv_all), None

        dq0 = jnp.zeros((b, q_chunk, kvh, g, dh), jnp.float32)
        (dq, dk_all, dv_all), _ = jax.lax.scan(
            kv_step, (dq0, dk_all, dv_all), jnp.arange(nk))
        return (dk_all, dv_all), dq

    (dk_all, dv_all), dqs = jax.lax.scan(
        q_step, (dk0, dv0), (qb, ob, dob, lseb, q_pos))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_pad, kvh, g, dh)
    dk = dk_all.transpose(1, 0, 2, 3, 4).reshape(b, nk * kv_chunk, kvh, dh)
    dv = dv_all.transpose(1, 0, 2, 3, 4).reshape(b, nk * kv_chunk, kvh, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _attn_core(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, scale,
               skv):
    out, _ = _attn_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                            kv_chunk, scale, skv)
    return out


def _attn_core_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                   scale, skv):
    out, lse = _attn_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                              kv_chunk, scale, skv)
    return out, (q, k, v, out, lse)


def _attn_core_bwd(causal, window, q_offset, q_chunk, kv_chunk, scale, skv,
                   res, dout):
    q, k, v, out, lse = res
    return _attn_bwd_impl(q, k, v, out, lse, dout, causal, window, q_offset,
                          q_chunk, kv_chunk, scale, skv)


_attn_core.defvjp(_attn_core_fwd, _attn_core_bwd)


def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, H, dh)
    k: jnp.ndarray,            # (B, Skv, KV, dh)
    v: jnp.ndarray,            # (B, Skv, KV, dh)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    """Flash-style attention: the score tile is the only live buffer, in the
    forward AND the backward (custom_vjp recomputes tiles from (q,k,v,lse)
    rather than letting scan save per-step quadratic residuals)."""
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else dh ** -0.5
    q_chunk = min(q_chunk, max(sq, 1))
    kv_chunk = min(kv_chunk, max(skv, 1))

    qp = _pad_to(q, 1, q_chunk).reshape(b, -1, kvh, g, dh)
    kp = _pad_to(k, 1, kv_chunk)
    vp = _pad_to(v, 1, kv_chunk)
    out = _attn_core(qp, kp, vp, causal, window, q_offset, q_chunk, kv_chunk,
                     scale, skv)
    return out.reshape(b, -1, h, dh)[:, :sq]


# ---------------------------------------------------------------------------
# GQA forward: train / prefill / decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    """One layer's cache. Sliding-window layers keep a rolling buffer of the
    window only (this is what makes the hybrid sub-quadratic at 500k)."""
    size = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((batch, size, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, size, cfg.qk_rope_dim), dtype),
            "pos": jnp.full((batch, size), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.d_head), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def _qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions: jnp.ndarray):
    q = dsp.linear(x, p["wq"], bias=p.get("bq"))
    k = dsp.linear(x, p["wk"], bias=p.get("bk"))
    v = dsp.linear(x, p["wv"], bias=p.get("bv"))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_scale"])
        k = rms_head_norm(k, p["k_scale"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_forward(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,               # (B, S, D)
    positions: jnp.ndarray,       # (B, S) absolute positions
    *,
    mode: str = "train",          # train | prefill | decode
    cache: Params | None = None,
    ctx=None,                     # ParallelContext: ring-prefill routing
) -> tuple[jnp.ndarray, Params | None]:
    if cfg.use_mla:
        return _mla_forward(cfg, p, x, positions, mode=mode, cache=cache)
    b, s, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    disp = dsp.active_dispatcher()

    if mode in ("train", "prefill"):
        if _ring_routed(cfg, ctx, mode, s):
            # context-parallel prefill: KV blocks rotate around the mesh
            from repro.parallel.ring_attention import ring_prefill
            out = ring_prefill(q, k, v, ctx, causal=True)
        elif disp is not None and cfg.attn_window is None:
            # the fused-attention cell of the op-by-device matrix
            out = dsp.flash_route(disp, q, k, v, causal=True)
        else:
            out = chunked_attention(q, k, v, causal=True,
                                    window=cfg.attn_window)
        new_cache = None
        if mode == "prefill":
            new_cache = _write_prefill_cache(cfg, k, v, positions)
    else:  # decode: one step (s == 1) or a prefill chunk (s == C)
        assert cache is not None
        cache = _append_cache(cfg, cache, {"k": k, "v": v}, positions)
        if s == 1 and disp is not None:
            out = dsp.decode_route(
                disp, q[:, 0], cache["k"], cache["v"], cache["pos"],
                positions[:, 0], window=cfg.attn_window)[:, None]
        else:
            out = _decode_attention(cfg, q, cache, positions)
        new_cache = cache
    out = dsp.linear(out, p["wo"], n_contract=2, bias=p.get("bo"))
    return out, new_cache


def _ring_routed(cfg, ctx, mode: str, s: int) -> bool:
    """Whether this prefill routes through ring attention: opt-in via
    `ParallelContext.ring_prefill_min`, full-causal layers only (window
    layers keep the local path — their KV never exceeds one slab), and only
    when the model axis actually has ranks to rotate KV around."""
    return (mode == "prefill" and ctx is not None
            and getattr(ctx, "ring_prefill_min", None) is not None
            and cfg.attn_window is None
            and ctx.axis_size("model") > 1
            and s >= ctx.ring_prefill_min)


def _write_prefill_cache(cfg, k, v, positions):
    b, s = positions.shape
    if cfg.attn_window and s > cfg.attn_window:
        w = cfg.attn_window
        k, v, positions = k[:, -w:], v[:, -w:], positions[:, -w:]
        # ring-buffer invariant: position p lives at slot p % w, so decode's
        # next write (pos s -> slot s % w) replaces the OLDEST entry
        shift = (s - w) % w
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        positions = jnp.roll(positions, shift, axis=1)
    return {"k": k, "v": v, "pos": positions}


def _append_cache(cfg, cache, kv_new, positions):
    """Write the new tokens at slot pos % size (rolling for window layers).

    s == 1 is the decode step. s > 1 is a prefill chunk: the writes run as a
    sequential fori_loop so a chunk longer than a ring window wraps exactly
    like s decode steps would (later positions overwrite the oldest slots)."""
    size = cache["pos"].shape[1]
    b, s = positions.shape
    if s == 1:
        pos = positions[:, 0]                   # (B,)
        slot = pos % size
        bidx = jnp.arange(pos.shape[0])
        out = dict(cache)
        for name in kv_new:
            out[name] = cache[name].at[bidx, slot].set(
                kv_new[name][:, 0].astype(cache[name].dtype))
        out["pos"] = cache["pos"].at[bidx, slot].set(pos)
        return out

    names = sorted(kv_new)

    def write(i, cur):
        pos_i = jax.lax.dynamic_index_in_dim(positions, i, 1, False)  # (B,)
        slot = pos_i % size
        out = dict(cur)
        for name in names:
            row = jax.lax.dynamic_index_in_dim(kv_new[name], i, 1, False)
            out[name] = jax.vmap(
                lambda c, r, sl: jax.lax.dynamic_update_index_in_dim(
                    c, r, sl, 0))(cur[name], row.astype(cur[name].dtype), slot)
        out["pos"] = jax.vmap(
            lambda c, pz, sl: jax.lax.dynamic_update_index_in_dim(
                c, pz, sl, 0))(cur["pos"], pos_i, slot)
        return out

    return jax.lax.fori_loop(0, s, write, dict(cache))


def _decode_attention(cfg, q, cache, positions):
    """q: (B, S, H, dh) against cache (B, Smax, KV, dh) with validity mask.
    S == 1 is the decode step (kept on its exact historical path); S > 1 is
    a prefill chunk, each query masked to its own causal horizon."""
    b, sq, h, dh = q.shape
    kvh = cache["k"].shape[2]
    g = h // kvh
    if sq == 1:
        qg = q.reshape(b, 1, kvh, g, dh)
        s = jnp.einsum("bqkgd,bckd->bkgc", qg.astype(jnp.float32),
                       cache["k"].astype(jnp.float32)) * dh ** -0.5
        cur = positions[:, 0][:, None]          # (B,1)
        valid = (cache["pos"] >= 0) & (cache["pos"] <= cur)
        if cfg.attn_window:
            valid &= (cur - cache["pos"]) < cfg.attn_window
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgc,bckd->bkgd", w, cache["v"].astype(jnp.float32))
        return out.reshape(b, 1, h, dh).astype(q.dtype)
    qg = q.reshape(b, sq, kvh, g, dh)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg.astype(jnp.float32),
                   cache["k"].astype(jnp.float32)) * dh ** -0.5
    cpos = cache["pos"][:, None, :]             # (B,1,Smax)
    cur = positions[:, :, None]                 # (B,S,1)
    valid = (cpos >= 0) & (cpos <= cur)
    if cfg.attn_window:
        valid &= (cur - cpos) < cfg.attn_window
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", w, cache["v"].astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): latent cache, absorbed decode
# ---------------------------------------------------------------------------


def _mla_qkv_latent(cfg, p, x, positions):
    b, s, _ = x.shape
    cq = dsp.linear(x, p["wq_a"])
    cq = rms_head_norm(cq, p["q_norm"])
    q = dsp.linear(cq, p["wq_b"])                           # (B,S,H,nope+rope)
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    ckv_full = dsp.linear(x, p["wkv_a"])                    # (B,S,lora+rope)
    c_kv = rms_head_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(ckv_full[..., None, cfg.kv_lora_rank:],
                        positions, cfg.rope_theta)[..., 0, :]   # (B,S,rope)
    return q_nope, q_rope, c_kv, k_rope


def _mla_forward(cfg, p, x, positions, *, mode, cache):
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(cfg, p, x, positions)

    if mode in ("train", "prefill"):
        # expand k,v from the latent; standard attention over full heads
        kv = dsp.linear(c_kv, p["wkv_b"])
        k_nope = kv[..., : cfg.qk_nope_dim]
        v = kv[..., cfg.qk_nope_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, cfg.qk_rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                            (0, k.shape[-1] - v.shape[-1])))
        out = chunked_attention(q, k, v_pad, causal=True, scale=scale)
        out = out[..., : cfg.v_head_dim]
        new_cache = None
        if mode == "prefill":
            new_cache = {"c_kv": c_kv, "k_rope": k_rope, "pos": positions}
    else:
        assert cache is not None
        cache = _append_cache(cfg, cache, {"c_kv": c_kv, "k_rope": k_rope},
                              positions)
        # absorbed decode: scores in latent space (paper-grade MLA serving)
        w_k = p["wkv_b"][..., : cfg.qk_nope_dim]            # (L, H, nope)
        w_v = p["wkv_b"][..., cfg.qk_nope_dim:]             # (L, H, v)
        q_lat = einsum32("bqhn,lhn->bqhl", q_nope, w_k)     # (B,S,H,L)
        if s == 1:
            pos = positions[:, 0]
            s_lat = jnp.einsum("bqhl,bcl->bhc", q_lat.astype(jnp.float32),
                               cache["c_kv"].astype(jnp.float32))
            s_rope = jnp.einsum("bqhr,bcr->bhc", q_rope.astype(jnp.float32),
                                cache["k_rope"].astype(jnp.float32))
            sc = (s_lat + s_rope) * scale
            valid = (cache["pos"] >= 0) & (cache["pos"] <= pos[:, None])
            sc = jnp.where(valid[:, None, :], sc, NEG_INF)
            w = jax.nn.softmax(sc, axis=-1)
            ctx = jnp.einsum("bhc,bcl->bhl", w,
                             cache["c_kv"].astype(jnp.float32)).astype(x.dtype)
            out = einsum32("bhl,lhv->bhv", ctx, w_v)[:, None]  # (B,1,H,v)
        else:  # prefill chunk: S queries, each masked to its own horizon
            s_lat = jnp.einsum("bqhl,bcl->bqhc", q_lat.astype(jnp.float32),
                               cache["c_kv"].astype(jnp.float32))
            s_rope = jnp.einsum("bqhr,bcr->bqhc", q_rope.astype(jnp.float32),
                                cache["k_rope"].astype(jnp.float32))
            sc = (s_lat + s_rope) * scale
            cpos = cache["pos"][:, None, :]                 # (B,1,Smax)
            valid = (cpos >= 0) & (cpos <= positions[:, :, None])
            sc = jnp.where(valid[:, :, None, :], sc, NEG_INF)
            w = jax.nn.softmax(sc, axis=-1)
            ctx = jnp.einsum("bqhc,bcl->bqhl", w,
                             cache["c_kv"].astype(jnp.float32)).astype(x.dtype)
            out = einsum32("bqhl,lhv->bqhv", ctx, w_v)      # (B,S,H,v)
        new_cache = cache
    out = dsp.linear(out, p["wo"], n_contract=2)
    return out, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def cross_attention_forward(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,               # decoder stream (B, S, D)
    enc_kv: tuple[jnp.ndarray, jnp.ndarray],   # precomputed (k, v) from encoder
) -> jnp.ndarray:
    q = dsp.linear(x, p["wq"], bias=p.get("bq"))
    k, v = enc_kv
    disp = dsp.active_dispatcher()
    if disp is not None:
        out = dsp.flash_route(disp, q, k, v, causal=False)
    else:
        out = chunked_attention(q, k, v, causal=False)
    return dsp.linear(out, p["wo"], n_contract=2, bias=p.get("bo"))


def encode_cross_kv(cfg: ModelConfig, p: Params, enc_out: jnp.ndarray):
    k = dsp.linear(enc_out, p["wk"], bias=p.get("bk"))
    v = dsp.linear(enc_out, p["wv"], bias=p.get("bv"))
    return k, v
