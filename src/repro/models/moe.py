"""Mixture-of-experts: dense reference path + expert-parallel production path.

Two implementations of the same routed-FFN semantics:

  * `moe_dense` — every expert computed on every token, combined by gate
    weight. Exact (no capacity drops), O(E) overcompute: the reference the
    EP path is tested against, and the path smoke tests take (E <= 4).

  * `moe_ep` — the production path: experts sharded over the "model" mesh
    axis inside `shard_map`. Tokens are split across model ranks (sequence
    split), routed top-k, packed into per-destination capacity buffers,
    exchanged with `all_to_all`, bucketed per local expert, run through the
    expert FFNs as one batched einsum, and combined back through the inverse
    permutation + a second all_to_all + an all_gather. Capacity overflow
    drops (deterministically, highest-rank copies first), exactly like
    GShard-style TPU MoE; the dense path has no drops, so tests compare at
    high capacity factor.

Routing: softmax-then-top-k with renormalized gates + the standard
load-balance auxiliary loss (Switch §2.2 form).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import compat
from repro.models import dispatched as dsp
from repro.models.layers import Params, apply_mlp, init_mlp
from repro.parallel.ctx import ParallelContext


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p: Params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * std,
        # stacked expert banks (E, d, f) / (E, f, d)
        "wg": jax.random.normal(ks[1], (e, d, f), dtype) * std,
        "wu": jax.random.normal(ks[2], (e, d, f), dtype) * std,
        "wd": jax.random.normal(ks[3], (e, f, d), dtype) * f ** -0.5,
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(jax.random.fold_in(key, 7), cfg, d,
                               cfg.d_ff_expert * cfg.n_shared_experts, dtype)
    return p


def _route(cfg: ModelConfig, router_w: jnp.ndarray, x: jnp.ndarray):
    """x: (T, d) -> (gates (T,k), idx (T,k), aux_loss). Router math in fp32."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                  # P_e
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (x.shape[0] * cfg.experts_per_token))               # f_e
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(cfg: ModelConfig, wg, wu, wd, x):
    """x: (E, C, d) through stacked expert banks -> (E, C, d).

    With a dispatcher in scope (or packed expert banks) each expert's three
    matmuls route through the kernel registry one bank at a time — the
    expert dim is a stack dim of the weight-form tag, sliced per expert.
    Otherwise: one batched einsum over the stacked banks (the seed path)."""
    if dsp.active_dispatcher() is not None or isinstance(wg, dsp.DispatchedWeight):
        act = jax.nn.silu if cfg.act != "gelu" else jax.nn.gelu
        slice_ = (lambda w, e: w.index(e)
                  if isinstance(w, dsp.DispatchedWeight) else w[e])
        outs = []
        for e in range(x.shape[0]):
            g = act(dsp.linear(x[e], slice_(wg, e)))
            u = dsp.linear(x[e], slice_(wu, e))
            outs.append(dsp.linear((g * u).astype(x.dtype), slice_(wd, e)))
        return jnp.stack(outs)
    act = jax.nn.silu if cfg.act != "gelu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", x, wg, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, wu, preferred_element_type=jnp.float32)
    h = (act(g) * u).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wd,
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense reference path
# ---------------------------------------------------------------------------


def moe_dense(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              ctx: ParallelContext | None = None):
    """x: (B, S, d). Every expert on every token; exact combine.

    With a mesh, the expert axis shards over 'model': each device computes
    only its local experts on (gathered) tokens and the combine contracts the
    expert axis with a psum. For decode (few tokens, weight-read-bound) this
    is the *right* production strategy: the HBM cost is reading each local
    expert bank once, identical to perfectly-routed compute."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    gates, idx, aux = _route(cfg, p["router"], xt)
    # (E, T, d): tokens against every expert bank; sharded over E on a mesh
    xe = jnp.broadcast_to(xt[None], (cfg.n_experts, xt.shape[0], d))
    if ctx is not None and ctx.active:
        xe = ctx.constrain(xe, "model", None, None)
    ye = _expert_ffn(cfg, p["wg"], p["wu"], p["wd"], xe)          # (E, T, d)
    if ctx is not None and ctx.active:
        ye = ctx.constrain(ye, "model", None, None)
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=ye.dtype)   # (T, k, E)
    comb = jnp.einsum("tke,etd,tk->td", onehot, ye, gates.astype(ye.dtype))
    out = comb.reshape(b, s, d)
    if "shared" in p:
        out = out + apply_mlp(cfg, p["shared"], x).reshape(b, s, d)
    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map over the "model" axis)
# ---------------------------------------------------------------------------


def _ep_block(cfg: ModelConfig, capacity_src: int, x_loc, router_w, wg, wu, wd):
    """Per-device body. x_loc: (T_m, d) — this rank's EXCLUSIVE token slice
    (the caller does the sequence split); expert banks are local shards
    (E_loc, ...). Returns this rank's token outputs (T_m, d)."""
    msize = compat.axis_size("model")
    t_m, d = x_loc.shape
    k = cfg.experts_per_token
    e_loc = cfg.n_experts // msize

    # 1. route this rank's tokens
    gates, idx, aux = _route(cfg, router_w, x_loc)

    # 2. pack token copies into per-destination capacity buffers
    flat_e = idx.reshape(-1)                                      # (T_m*k,)
    dest = flat_e // e_loc
    order = jnp.argsort(dest, stable=True)                        # group by dest
    sorted_dest = dest[order]
    # rank within destination group
    start = jnp.searchsorted(sorted_dest, jnp.arange(msize))
    rank_in_dest = jnp.arange(t_m * k) - start[sorted_dest]
    slot = jnp.where(rank_in_dest < capacity_src, rank_in_dest, capacity_src)
    send_x = jnp.zeros((msize, capacity_src + 1, d), x_loc.dtype)
    send_e = jnp.full((msize, capacity_src + 1), e_loc, jnp.int32)  # pad expert id
    rows = x_loc[order // k]
    send_x = send_x.at[sorted_dest, slot].set(rows)
    send_e = send_e.at[sorted_dest, slot].set((flat_e % e_loc)[order])
    send_x, send_e = send_x[:, :capacity_src], send_e[:, :capacity_src]

    # 3. exchange: rows travel to the rank that owns their expert
    recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)
    rows_in = recv_x.reshape(msize * capacity_src, d)
    es_in = recv_e.reshape(msize * capacity_src)

    # 4. bucket by local expert with per-expert capacity (slack over the
    #    balanced expectation; overflow and padding rows land in a dump slot)
    cap_e = int((msize * capacity_src) / e_loc * 1.25) + 8
    cap_e = min(cap_e, msize * capacity_src)
    order2 = jnp.argsort(es_in, stable=True)
    sorted_e = es_in[order2]
    start_e = jnp.searchsorted(sorted_e, jnp.arange(e_loc))
    rank_e = jnp.arange(es_in.shape[0]) - start_e[jnp.clip(sorted_e, 0, e_loc - 1)]
    valid = (sorted_e < e_loc) & (rank_e < cap_e)
    buf = jnp.zeros((e_loc, cap_e + 1, d), x_loc.dtype)   # +1 = dump slot
    buf = buf.at[jnp.where(valid, sorted_e, e_loc - 1),
                 jnp.where(valid, jnp.clip(rank_e, 0, cap_e - 1), cap_e)].set(
        jnp.where(valid[:, None], rows_in[order2], 0.0))
    buf = buf[:, :cap_e]

    # 5. the expert FFNs, one batched einsum over the local bank
    yb = _expert_ffn(cfg, wg, wu, wd, buf)                        # (E_loc, cap_e, d)

    # 6. inverse of step 4: back to arrival order
    y_sorted = jnp.where(valid[:, None],
                         yb[jnp.clip(sorted_e, 0, e_loc - 1),
                            jnp.clip(rank_e, 0, cap_e - 1)], 0.0)
    y_arrival = jnp.zeros_like(rows_in).at[order2].set(y_sorted)

    # 7. return trip + inverse of step 2
    y_send = y_arrival.reshape(msize, capacity_src, d)
    y_back = jax.lax.all_to_all(y_send, "model", 0, 0, tiled=False)
    dropped = rank_in_dest >= capacity_src
    y_copy_sorted = jnp.where(
        dropped[:, None], 0.0,
        y_back[sorted_dest, jnp.clip(slot, 0, capacity_src - 1)])
    y_copies = jnp.zeros((t_m * k, d), x_loc.dtype).at[order].set(y_copy_sorted)

    # 8. gate-weighted combine of the k copies
    y_loc = jnp.einsum("tkd,tk->td", y_copies.reshape(t_m, k, d),
                       gates.astype(x_loc.dtype))
    return y_loc, jax.lax.pmean(aux, "model")


def _bank_spec(w, ctx: ParallelContext):
    """shard_map in_spec for one expert bank: plain (E, d, f) arrays shard
    the leading expert dim; a packed `DispatchedWeight` gets the same cut on
    every payload leaf (the expert dim is its leading stack dim), so each
    rank holds — and its palette/sparse kernels stream — only its own
    experts' compressed payload."""
    if isinstance(w, dsp.DispatchedWeight):
        return w.stack_specs(*ctx.spec("model"))
    return ctx.spec("model", None, None)


def moe_ep(cfg: ModelConfig, p: Params, x: jnp.ndarray, ctx: ParallelContext):
    """x: (B, S, d) sharded over batch axes; experts sharded over 'model'."""
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    msize = ctx.axis_size("model")
    t_m = (b * s) // (_batch_shards(ctx) * msize)
    cap = int(t_m * cfg.experts_per_token / msize * cfg.moe_capacity_factor)
    cap = max(8, ((cap + 7) // 8) * 8)
    # EP+SP fusion: with a sequence-sharded residual stream the MoE output
    # stays sequence-sharded and the per-layer output all-gather disappears
    seq_out = cfg.seq_shard and s % msize == 0

    def body(x_blk, router_w, wg, wu, wd):
        b_loc, s_full, dd = x_blk.shape
        m = jax.lax.axis_index("model")
        if seq_out:
            # per-row sequence split: rank m owns x[:, m*s_m:(m+1)*s_m, :],
            # matching the sequence-sharded out_spec exactly
            s_m = s_full // msize
            x_loc = jax.lax.dynamic_slice_in_dim(
                x_blk, m * s_m, s_m, axis=1).reshape(-1, dd)
            y_loc, aux = _ep_block(cfg, cap, x_loc, router_w, wg, wu, wd)
            return y_loc.reshape(b_loc, s_m, dd), aux[None]
        # flat token split + all-gather back to a replicated block
        tb = b_loc * s_full
        t_m = tb // msize
        x_loc = jax.lax.dynamic_slice_in_dim(
            x_blk.reshape(tb, dd), m * t_m, t_m)
        y_loc, aux = _ep_block(cfg, cap, x_loc, router_w, wg, wu, wd)
        y = jax.lax.all_gather(y_loc, "model", axis=0, tiled=True)
        return y.reshape(x_blk.shape), aux[None]

    pspec_x = ctx.spec(("pod", "data"), None, None)
    out_y_spec = ctx.spec(("pod", "data"), "model", None) if seq_out else pspec_x
    y, aux = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(pspec_x, ctx.spec(None, None), _bank_spec(p["wg"], ctx),
                  _bank_spec(p["wu"], ctx), _bank_spec(p["wd"], ctx)),
        out_specs=(out_y_spec, ctx.spec("model")), check_rep=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    out = y
    if "shared" in p:
        out = out + apply_mlp(cfg, p["shared"], x)
    return out, aux.mean()


def _batch_shards(ctx: ParallelContext) -> int:
    n = 1
    for a in ctx.batch_axes:
        n *= ctx.axis_size(a)
    return n


# Trace-time route ledger: which MoE path each traced forward compiled into.
# jit caches programs, so counts tick per *trace*, not per step — tests and
# the sharded-serve bench read "ep" > 0 to prove packed banks actually took
# the shard_map path rather than silently falling back to dense.
ROUTE_COUNTS: dict[str, int] = {"ep": 0, "dense": 0}


def moe_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                ctx: ParallelContext):
    """Dispatch to EP when the mesh has a >1 'model' axis and the expert
    count divides it; dense reference otherwise. Packed `DispatchedWeight`
    banks take the same EP path: `shard_map` in_specs cover their payload
    leaves (expert stack dim over 'model'), so each rank streams only its
    local experts' compressed payload."""
    msize = ctx.axis_size("model")
    tokens = x.shape[0] * x.shape[1]
    batch_ok = x.shape[0] % _batch_shards(ctx) == 0
    if (ctx.active and ctx.use_ep and msize > 1 and batch_ok
            and cfg.n_experts % msize == 0
            and tokens % (_batch_shards(ctx) * msize) == 0):
        ROUTE_COUNTS["ep"] += 1
        return moe_ep(cfg, p, x, ctx)
    ROUTE_COUNTS["dense"] += 1
    return moe_dense(cfg, p, x, ctx)
