"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill: the chunked SSD algorithm — intra-chunk "attention-like"
quadratic block + inter-chunk state recurrence. The cross-chunk recurrence is
a scalar-decay linear recurrence, so we run it as `associative_scan`
(log-depth on TPU rather than sequential — a TPU-native choice the original
CUDA kernel makes differently).

Decode: O(1) per token — the recurrent state update. This is what makes
`long_500k` a running cell for this family.

Layout: x (B, L, H, P) head values; B̃/C̃ (B, L, G, N) with G groups broadcast
over heads; A (H,) negative reals; dt (B, L, H) softplus-positive.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dot

# ---------------------------------------------------------------------------


def tp_row_dot(x, w, ctx):
    """Row-parallel matmul with the cross-shard reduction in bf16.

    Under plain GSPMD the psum of a row-parallel contraction happens on the
    fp32 accumulator (4-byte all-reduce). Here the per-shard contraction
    keeps its wide accumulator, converts to bf16, and THEN reduces — halving
    the dominant TP collective's bytes at the cost of one extra bf16
    rounding on a 16-way sum (§Perf pair C). Falls back to `dot` off-mesh."""
    batch_shards = 1
    if ctx is not None:
        for a in ("pod", "data"):
            batch_shards *= ctx.axis_size(a)
    if ctx is None or not ctx.active or ctx.axis_size("model") <= 1 \
            or x.shape[-1] % ctx.axis_size("model") != 0 \
            or x.shape[0] % batch_shards != 0:
        return dot(x, w)
    from jax.experimental.shard_map import shard_map

    def body(xb, wb):
        out = jax.lax.dot_general(xb, wb, (((xb.ndim - 1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        out = out.astype(x.dtype)            # narrow BEFORE the wire
        return jax.lax.psum(out, "model")

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(ctx.spec(("pod", "data"), None, "model"),
                  ctx.spec("model", None)),
        out_specs=ctx.spec(("pod", "data"), None, None),
        check_rep=False,
    )(x, w)


def init_ssm(key, cfg: ModelConfig, dtype) -> Params:
    """Projections kept as separate tensors (w_z, w_x, w_b, w_c, w_dt) rather
    than one fused in_proj, so tensor parallelism shards d_inner/heads over
    the 'model' axis cleanly (a fused projection would slice across component
    boundaries under TP)."""
    d = cfg.d_model
    di = cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * std,
        "w_x": jax.random.normal(ks[1], (d, di), dtype) * std,
        "w_b": jax.random.normal(ks[2], (d, g * n), dtype) * std,
        "w_c": jax.random.normal(ks[3], (d, g * n), dtype) * std,
        "w_dt": jax.random.normal(ks[4], (d, h), dtype) * std,
        "conv_x_w": jax.random.normal(ks[5], (cfg.ssm_conv_width, di), dtype) * 0.5,
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": jax.random.normal(ks[6], (cfg.ssm_conv_width, 2 * g * n),
                                       dtype) * 0.5,
        "conv_bc_b": jnp.zeros((2 * g * n,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[7], (h,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(
            jax.random.fold_in(key, 11), (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv, width W. xbc: (B, L, C); w: (W, C).

    Returns (out, new_state) where state carries the last W-1 inputs."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + full[:, i: i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = full[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def _ssd_chunked(x, dt, a, b, c, chunk, init_state=None):
    """The SSD algorithm. x:(B,L,H,P) dt:(B,L,H) a:(H,) b,c:(B,L,G,N).

    Returns (y (B,L,H,P), final_state (B,H,P,N)).

    Structured as a `lax.scan` over chunks carrying the (B,H,P,N) state so
    the only quadratic live buffer is one chunk's (B,Q,Q,H) decay tile —
    the paper's working-set rule (§9.2) applied to SSD: never materialize
    the per-chunk quadratics for all chunks at once.
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q
    rep = h // g
    # (nc, B, Q, ...) chunk-major for the scan
    xc = jnp.moveaxis(x.reshape(bsz, nc, q, h, p), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(bsz, nc, q, h), 1, 0).astype(jnp.float32)
    bc = jnp.moveaxis(
        jnp.repeat(b.reshape(bsz, nc, q, g, n), rep, axis=3), 1, 0).astype(jnp.float32)
    cc = jnp.moveaxis(
        jnp.repeat(c.reshape(bsz, nc, q, g, n), rep, axis=3), 1, 0).astype(jnp.float32)

    causal = jnp.tril(jnp.ones((q, q), bool))
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inputs):
        xz, dtz, bz, cz = inputs                   # (B,Q,H,P) (B,Q,H) (B,Q,H,N)x2
        da = dtz * a                               # (B,Q,H), negative
        da_cs = jnp.cumsum(da, axis=1)
        # intra-chunk quadratic
        seg = da_cs[:, :, None, :] - da_cs[:, None, :, :]        # (B,Q,Q,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bqhn,bkhn->bqkh", cz, bz) * decay
        y = jnp.einsum("bqkh,bkh,bkhp->bqhp", scores, dtz, xz)
        # contribution of the entering state
        decay_from_start = jnp.exp(da_cs)                        # (B,Q,H)
        y = y + jnp.einsum("bqhn,bhpn,bqh->bqhp", cz, state, decay_from_start)
        # state update to the chunk end
        decay_to_end = jnp.exp(da_cs[:, -1:, :] - da_cs)         # (B,Q,H)
        inc = jnp.einsum("bkh,bkh,bkhn,bkhp->bhpn", decay_to_end, dtz, bz, xz)
        chunk_decay = jnp.exp(da_cs[:, -1, :])                   # (B,H)
        state = state * chunk_decay[..., None, None] + inc
        return state, y

    # checkpoint the chunk step: its backward recomputes the (B,Q,Q,H)
    # quadratics per chunk instead of letting scan save them for all chunks
    final_state, ys = jax.lax.scan(jax.checkpoint(step), init_state,
                                   (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * q, h, p)[:, :l]
    return y, final_state


def ssm_forward(
    cfg: ModelConfig,
    p: Params,
    xin: jnp.ndarray,              # (B, S, D)
    *,
    mode: str = "train",
    cache: Params | None = None,
    ctx=None,
) -> tuple[jnp.ndarray, Params | None]:
    bsz, s, _ = xin.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    z = dot(xin, p["w_z"])
    xs = dot(xin, p["w_x"])
    bc = jnp.concatenate([dot(xin, p["w_b"]), dot(xin, p["w_c"])], axis=-1)
    dt = dot(xin, p["w_dt"])
    a = -jnp.exp(p["a_log"])                                     # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    if mode in ("train", "prefill"):
        xs, conv_x_state = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"])
        bc, conv_bc_state = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
        x = xs.reshape(bsz, s, h, pdim)
        b = bc[..., : g * n].reshape(bsz, s, g, n)
        c = bc[..., g * n:].reshape(bsz, s, g, n)
        y, state = _ssd_chunked(x, dt, a, b, c, cfg.ssm_chunk)
        y = y + x.astype(jnp.float32) * p["d_skip"][None, None, :, None]
        new_cache = None
        if mode == "prefill":
            new_cache = {"state": state.astype(xin.dtype),
                         "conv_x": conv_x_state, "conv_bc": conv_bc_state}
    elif s == 1:  # decode: O(1) state update
        assert cache is not None
        xs, conv_x_state = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"],
                                        state=cache["conv_x"])
        bc, conv_bc_state = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                                         state=cache["conv_bc"])
        x = xs.reshape(bsz, 1, h, pdim)[:, 0]                     # (B,H,P)
        b = bc[..., : g * n].reshape(bsz, g, n)
        c = bc[..., g * n:].reshape(bsz, g, n)
        rep = h // g
        bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)       # (B,H,N)
        ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
        dt1 = dt[:, 0]                                            # (B,H)
        decay = jnp.exp(dt1 * a)                                  # (B,H)
        state = cache["state"].astype(jnp.float32)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt1, bh, x.astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", ch, state)
        y = y + x.astype(jnp.float32) * p["d_skip"][:, None]
        y = y[:, None]                                            # (B,1,H,P)
        new_cache = {"state": state.astype(xin.dtype),
                     "conv_x": conv_x_state, "conv_bc": conv_bc_state}
    else:  # prefill chunk: SSD scan resumed from the carried state
        assert cache is not None
        xs, conv_x_state = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"],
                                        state=cache["conv_x"])
        bc, conv_bc_state = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                                         state=cache["conv_bc"])
        x = xs.reshape(bsz, s, h, pdim)
        b = bc[..., : g * n].reshape(bsz, s, g, n)
        c = bc[..., g * n:].reshape(bsz, s, g, n)
        y, state = _ssd_chunked(
            x, dt, a, b, c, cfg.ssm_chunk,
            init_state=cache["state"].astype(jnp.float32))
        y = y + x.astype(jnp.float32) * p["d_skip"][None, None, :, None]
        new_cache = {"state": state.astype(xin.dtype),
                     "conv_x": conv_x_state, "conv_bc": conv_bc_state}

    # gated RMS norm + out projection
    y = y.reshape(bsz, -1, di)
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    ms = (gated * gated).mean(-1, keepdims=True)
    y = (gated * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]).astype(xin.dtype)
    return tp_row_dot(y, p["out_proj"], ctx), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), dtype),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.d_inner),
                            dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                              2 * cfg.ssm_groups * cfg.ssm_state), dtype),
    }
