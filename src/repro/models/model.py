"""Model facade: init / train-loss / prefill / decode for every arch family.

`build_model(cfg, ctx)` returns a `Model` whose five entry points are what
the launcher jits:

    init(key)                          -> params
    loss(params, batch)                -> (scalar, metrics)       [train_step]
    prefill(params, batch)             -> (caches, last_logits)   [prefill]
    decode_step(params, caches, token, pos) -> (caches, logits)   [serve_step]
    init_cache(batch, max_len)         -> caches

plus `input_specs(shape)` producing the ShapeDtypeStruct stand-ins the
multi-pod dry-run lowers against (no allocation).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.dispatch import KernelDispatcher
from repro.kernels import compat
from repro.models import dispatched as dsp
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib
from repro.models.layers import (Params, apply_norm, embed_tokens, init_embed,
                                 init_norm, logits as logits_fn)
from repro.parallel.ctx import CPU_CTX, ParallelContext

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    ctx: ParallelContext
    # Op-by-device routing: when set, every matmul traced by loss/prefill/
    # decode_step resolves through this dispatcher against the kernel
    # registry (packed weights stream; gated kernels fall back to oracles).
    # None = the seed's plain dense path.
    dispatcher: KernelDispatcher | None = None

    # ------------------------------------------------------------------
    @property
    def dtype(self):
        return _DTYPES[self.cfg.dtype]

    def _dispatch_scope(self):
        if self.dispatcher is None:
            return contextlib.nullcontext()
        return dsp.use_dispatcher(self.dispatcher)

    def init(self, key) -> Params:
        cfg = self.cfg
        k_embed, k_stack, k_final, k_mtp = jax.random.split(key, 4)
        params: Params = {
            "embed": init_embed(k_embed, cfg, self.dtype),
            "final_ln": init_norm(cfg, cfg.d_model),
        }
        if cfg.family == "encdec":
            params["encdec"] = encdec_lib.init_encdec_stacks(k_stack, cfg,
                                                             self.dtype)
        else:
            params["layers"] = tf_lib.init_stack(k_stack, cfg, self.dtype)
        if cfg.mtp_depth:
            sig = tf_lib.layer_signature(cfg, cfg.n_layers - 1)
            params["mtp"] = {
                "proj": jax.random.normal(
                    k_mtp, (2 * cfg.d_model, cfg.d_model), self.dtype)
                * (2 * cfg.d_model) ** -0.5,
                "ln_h": init_norm(cfg, cfg.d_model),
                "ln_e": init_norm(cfg, cfg.d_model),
                "layer": tf_lib.init_layer(jax.random.fold_in(k_mtp, 1), cfg,
                                           sig, self.dtype),
            }
        return params

    # ------------------------------------------------------------------
    @staticmethod
    def named_leaves(tree: Params) -> list[tuple[str, Any]]:
        """(path, leaf) pairs over a param/cache tree, rendered "a/b/0/c" —
        the naming the sharding rules and checkpoint layout key on. Goes
        through the version-adaptive pytree surface (`kernels.compat`): the
        path-aware flatten moved modules between jax 0.4.x and 0.5+."""
        leaves, _ = compat.tree_flatten_with_path(tree)
        return [(compat.tree_path_str(p), leaf) for p, leaf in leaves]

    # ------------------------------------------------------------------
    def _backbone(self, params, x, positions, *, mode, caches=None,
                  frames=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            if mode == "decode":
                cross = caches["cross"]   # built at prefill; resident
            else:
                enc_out = encdec_lib.encode(cfg, params["encdec"], frames,
                                            self.ctx)
                cross = encdec_lib.build_cross_cache(cfg, params["encdec"],
                                                     enc_out)
            x, self_caches = encdec_lib.decoder_stack(
                cfg, params["encdec"], x, positions, self.ctx, mode=mode,
                cross=cross,
                caches=caches["self"] if mode == "decode" else None)
            new_caches = None
            if mode in ("prefill", "decode"):
                new_caches = {"self": self_caches, "cross": cross}
            return x, new_caches, jnp.zeros((), jnp.float32)
        return tf_lib.apply_stack(cfg, params["layers"], x, positions,
                                  self.ctx, mode=mode, caches=caches)

    def forward(self, params, tokens, positions, *, mode, caches=None,
                frames=None):
        cfg = self.cfg
        with self._dispatch_scope():
            x = embed_tokens(params["embed"], tokens).astype(self.dtype)
            if cfg.family == "encdec" and mode == "decode":
                # cross cache already built at prefill; frames unused in decode
                frames = None
            x, new_caches, aux = self._backbone(params, x, positions,
                                                mode=mode, caches=caches,
                                                frames=frames)
            h = apply_norm(cfg, params["final_ln"], x)
        return h, new_caches, aux

    # ------------------------------------------------------------------
    # Training
    # ------------------------------------------------------------------
    def loss(self, params, batch) -> tuple[jnp.ndarray, dict[str, Any]]:
        """Next-token cross entropy, logits in fp32 (the wide anchor), with
        z-loss and the MoE balance loss; optional MTP auxiliary loss."""
        cfg = self.cfg
        tokens = batch["tokens"]                       # (B, S)
        targets = batch["targets"]                     # (B, S)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, _, aux = self.forward(params, tokens, positions, mode="train",
                                 frames=batch.get("frames"))
        with self._dispatch_scope():
            lg = logits_fn(cfg, params["embed"], h)    # fp32 (B,S,V)
            ce, z = _xent(lg, targets, cfg.vocab)
            loss = ce + 1e-4 * z + 1e-2 * aux
            metrics = {"ce": ce, "zloss": z, "moe_aux": aux,
                       "tokens": jnp.asarray(b * s, jnp.float32)}
            if cfg.mtp_depth and "mtp" in params:
                mtp_loss = self._mtp_loss(params, tokens, targets, h,
                                          positions)
                loss = loss + 0.3 * mtp_loss
                metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, tokens, targets, h, positions):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from the
        main stream at t combined with the embedding of t+1.

        Runs at FULL sequence length (shift via roll + loss mask) so the MoE
        layer keeps its EP-divisible token count — slicing to S-1 tokens
        would push the routed experts onto the dense fallback path."""
        cfg = self.cfg
        p = params["mtp"]
        h_in = apply_norm(cfg, p["ln_h"], h)
        next_tok = jnp.roll(tokens, -1, axis=1)       # t+1 (last col is junk)
        e_next = apply_norm(
            cfg, p["ln_e"],
            embed_tokens(params["embed"], next_tok).astype(h.dtype))
        merged = jnp.concatenate([h_in, e_next], axis=-1)
        x = dsp.linear(merged, p["proj"])
        sig = tf_lib.layer_signature(cfg, cfg.n_layers - 1)
        x, _, _ = tf_lib.apply_layer(cfg, sig, p["layer"], x, positions,
                                     self.ctx, mode="train", cache=None)
        lg = logits_fn(cfg, params["embed"], apply_norm(cfg, params["final_ln"], x))
        # position t predicts target t+1 of the shifted stream = token t+2;
        # the last two positions see rolled-around junk -> masked out
        mtp_targets = jnp.roll(targets, -1, axis=1)
        s = tokens.shape[1]
        mask = (jnp.arange(s) < s - 2).astype(jnp.float32)[None, :]
        ce, _ = _xent(lg, mtp_targets, cfg.vocab, mask=mask)
        return ce

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        if cfg.family == "encdec":
            return {
                "self": encdec_lib.init_decoder_cache(cfg, batch, max_len,
                                                      self.dtype),
                "cross": {
                    "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                                    cfg.n_kv_heads, cfg.d_head), self.dtype),
                    "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                                    cfg.n_kv_heads, cfg.d_head), self.dtype),
                },
            }
        return tf_lib.init_stack_cache(cfg, batch, max_len, self.dtype)

    def prefill(self, params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, caches, _ = self.forward(params, tokens, positions, mode="prefill",
                                    frames=batch.get("frames"))
        with self._dispatch_scope():
            lg = logits_fn(self.cfg, params["embed"], h[:, -1:])
        return caches, lg

    def decode_step(self, params, caches, token, pos):
        """token: (B, 1) int32; pos: (B,) int32 absolute positions."""
        positions = pos[:, None]
        h, caches, _ = self.forward(params, token, positions, mode="decode",
                                    caches=caches)
        with self._dispatch_scope():
            lg = logits_fn(self.cfg, params["embed"], h)
        return caches, lg

    def prefill_chunk(self, params, caches, tokens, pos0):
        """One prefill chunk: C prompt tokens written into decode-shaped
        `caches` as if they were C fused decode steps.

        tokens: (B, C) int32 prompt slice; pos0: (B,) int32 absolute
        position of tokens[:, 0]. Returns (caches, last-token logits) —
        the same contract as `prefill`, so the scheduler's donated
        admission path treats the staging cache like a prefill cache. The
        shape (B, C) is the whole program signature: every chunk of every
        prompt reuses one ProgramCache entry per chunk size."""
        b, c = tokens.shape
        positions = pos0[:, None] + jnp.arange(c, dtype=pos0.dtype)[None]
        h, caches, _ = self.forward(params, tokens, positions, mode="decode",
                                    caches=caches)
        with self._dispatch_scope():
            lg = logits_fn(self.cfg, params["embed"], h[:, -1:])
        return caches, lg

    # ------------------------------------------------------------------
    # Dry-run stand-ins
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        cell — weak-type-correct, shardable, no device allocation."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
            if shape.kind == "train":
                specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b,) + cfg.frame_shape, self.dtype)
            return specs
        # decode: one new token against a cache of seq_len
        cache_spec = jax.eval_shape(
            functools.partial(self.init_cache, b, s))
        return {
            "caches": cache_spec,
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }


def _xent(lg: jnp.ndarray, targets: jnp.ndarray, vocab: int, mask=None):
    """CE over the true vocab (padded slots masked), plus z-loss term.

    TP-friendly: `picked` contracts the (model-sharded) vocab axis with a
    fused compare-select-reduce instead of a take_along_axis gather, so no
    logits all-gather is forced (the vocab axis reduces with a psum)."""
    lg = lg.astype(jnp.float32)
    v = lg.shape[-1]
    vmask = jnp.arange(v) < vocab
    lg = jnp.where(vmask, lg, -1e30)
    m = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    onehot = jnp.arange(v)[None, None, :] == targets[..., None]
    picked = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
    per_tok = lse - picked
    z_tok = lse ** 2
    if mask is not None:
        denom = jnp.maximum(mask.sum() * per_tok.shape[0] / mask.shape[0], 1.0)
        ce = (per_tok * mask).sum() / denom
        z = (z_tok * mask).sum() / denom
    else:
        ce = per_tok.mean()
        z = z_tok.mean()
    return ce, z


def build_model(cfg: ModelConfig, ctx: ParallelContext = CPU_CTX,
                dispatcher: KernelDispatcher | None = None) -> Model:
    return Model(cfg=cfg, ctx=ctx, dispatcher=dispatcher)
