"""Pipeline parallelism: GPipe-style microbatching over a "stage" mesh axis.

Opt-in third parallelism dimension for depth-dominated models. Layers are
split into S contiguous stages (params sharded over "stage"); microbatches
flow through a `shard_map` whose time loop runs S + M - 1 ticks, activations
hopping stage-to-stage via `collective_permute` each tick. The bubble is the
standard (S-1)/(S+M-1) fraction — reported by `bubble_fraction`.

The stage function is arbitrary (any jax-traceable layer-stack apply), so
this composes with the model zoo's stacked-layer params: reshape the layer
axis (L,) -> (S, L/S) and hand each stage its slab.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def gpipe(
    stage_fn: Callable,            # (stage_params, x_micro) -> y_micro
    mesh: Mesh,
    *,
    stage_axis: str = "stage",
):
    """Returns pipelined(params_stacked, x_micro) running under shard_map.

    params_stacked: pytree with leading dim = n_stages (sharded over stage).
    x_micro: (M, mb, ...) microbatches (replicated across stages).
    Output: (M, mb, ...) after all stages.
    """
    n_stages = mesh.shape[stage_axis]

    def body(params_blk, x_micro):
        # params_blk leaves: (1, ...) local stage slab
        sparams = jax.tree.map(lambda a: a[0], params_blk)
        sid = jax.lax.axis_index(stage_axis)
        m, mb = x_micro.shape[0], x_micro.shape[1]
        ticks = n_stages + m - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        carry0 = jnp.zeros_like(x_micro[0])
        outbuf0 = jnp.zeros_like(x_micro)

        def tick(state, t):
            carry, outbuf = state
            # stage 0 ingests microbatch t (if any); others take the carry
            feed = x_micro[jnp.clip(t, 0, m - 1)]
            x_in = jnp.where(sid == 0, feed, carry)
            y = stage_fn(sparams, x_in)
            # last stage emits microbatch (t - (S-1)) at ticks >= S-1
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            emit = (sid == n_stages - 1) & (t >= n_stages - 1)
            outbuf = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(outbuf, y, out_idx, 0),
                outbuf)
            nxt = jax.lax.ppermute(y, stage_axis, perm)
            return (nxt, outbuf), None

        (_, outbuf), _ = jax.lax.scan(tick, (carry0, outbuf0),
                                      jnp.arange(ticks))
        # everyone returns; only the last stage's buffer is meaningful —
        # gather and select it so the output is replicated across stages
        gathered = jax.lax.all_gather(outbuf, stage_axis, axis=0)
        return gathered[n_stages - 1]

    pp = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return pp


def split_stages(stacked_params, n_stages: int):
    """(L, ...) layer-stacked params -> (S, L/S, ...) stage slabs."""
    def reshape(a):
        l = a.shape[0]
        assert l % n_stages == 0, f"{l} layers not divisible by {n_stages} stages"
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(reshape, stacked_params)
