"""Sharding rules: param/cache/optimizer PartitionSpecs per architecture.

Megatron-style TP over the "model" axis, DP over ("pod", "data"), EP for
expert banks, with two framework rules:

  * divisibility-guarded: a dim that does not divide the axis size
    replicates instead (e.g. 8 KV heads on a 16-way model axis — the
    standard duplicate-KV fallback);
  * ZeRO-1: optimizer moments take the param spec *plus* the data axis on
    the largest still-unsharded dim, so state memory scales with the fleet.

Rules are path-pattern based over the param pytree, so any new layer that
follows the naming convention shards without new code.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.dispatched import DispatchedWeight
from repro.parallel.ctx import ParallelContext

# (path regex, dim index -> axis) — dims not listed replicate.
# Paths look like "layers/0/sub0/mix/wq" after flattening.
# Two-axis entries are TP ("model") + FSDP ("data"): large weights shard a
# second dim over the data axis and are all-gathered per scan step (GSPMD
# inserts the gather inside the loop) — without this, a 671B model's bf16
# working params alone would be P/model = 85 GB per chip.
_RULES: list[tuple[str, dict[int, str]]] = [
    # embeddings: vocab over model only — FSDP on the d_model dim would make
    # every lookup gather from a 2D-sharded table, which the SPMD partitioner
    # can only do by replicating the output (involuntary full remat).
    # Small tables replicate entirely (size gate below): a lookup from a
    # vocab-sharded table costs one (B,S,D) all-reduce per step, which for a
    # small-vocab model dwarfs the table's replicated footprint.
    (r"embed/table$", {0: "model"}),
    (r"embed/unembed$", {1: "model"}),
    # attention (leading stack dim shifts indices by +1 when stacked)
    (r"mix/wq$", {1: "model", 0: "data"}),          # (D, H, dh)
    (r"mix/wk$", {1: "model", 0: "data"}),
    (r"mix/wv$", {1: "model", 0: "data"}),
    (r"mix/wo$", {0: "model", 2: "data"}),          # (H, dh, D)
    (r"(self_attn|cross_attn|attn)/wq$", {1: "model", 0: "data"}),
    (r"(self_attn|cross_attn|attn)/wk$", {1: "model", 0: "data"}),
    (r"(self_attn|cross_attn|attn)/wv$", {1: "model", 0: "data"}),
    (r"(self_attn|cross_attn|attn)/wo$", {0: "model", 2: "data"}),
    # MLA
    (r"mix/wq_a$", {1: "model", 0: "data"}),        # (D, q_lora)
    (r"mix/wq_b$", {1: "model", 0: "data"}),        # (q_lora, H, qk_head)
    (r"mix/wkv_a$", {0: "data"}),                   # (D, lora+rope)
    (r"mix/wkv_b$", {1: "model", 0: "data"}),       # (kv_lora, H, nope+v)
    # GLU MLPs
    (r"(mlp|shared)/wg$", {1: "model", 0: "data"}),
    (r"(mlp|shared)/wu$", {1: "model", 0: "data"}),
    (r"(mlp|shared)/wd$", {0: "model", 1: "data"}),
    (r"mlp/wi$", {1: "model", 0: "data"}),
    (r"mlp/wo$", {0: "model", 1: "data"}),
    # MoE expert banks: EP over model on the expert dim, FSDP over data
    (r"moe/wg$", {0: "model", 1: "data"}),          # (E, d, f)
    (r"moe/wu$", {0: "model", 1: "data"}),
    (r"moe/wd$", {0: "model", 2: "data"}),
    # SSM: d_inner / heads over model
    (r"mix/w_z$", {1: "model", 0: "data"}),
    (r"mix/w_x$", {1: "model", 0: "data"}),
    (r"mix/w_dt$", {1: "model", 0: "data"}),
    (r"mix/(w_b|w_c)$", {0: "data"}),
    (r"mix/conv_x_w$", {1: "model"}),
    (r"mix/conv_x_b$", {0: "model"}),
    (r"mix/(norm_scale)$", {0: "model"}),
    (r"mix/out_proj$", {0: "model", 1: "data"}),
    (r"mix/(a_log|d_skip|dt_bias)$", {0: "model"}),
    # RG-LRU: lru_width over model
    (r"mix/linear_x$", {1: "model", 0: "data"}),
    (r"mix/linear_y$", {1: "model", 0: "data"}),
    (r"mix/w_r$", {1: "model", 0: "data"}),
    (r"mix/w_i$", {1: "model", 0: "data"}),
    (r"mix/lam$", {0: "model"}),
    (r"mix/conv_w$", {1: "model"}),
    (r"mix/conv_b$", {0: "model"}),
    (r"mix/out$", {0: "model", 1: "data"}),
    # MTP projection
    (r"mtp/proj$", {1: "model", 0: "data"}),
]

# cache specs: batch over (pod,data); heads/width over model where divisible
_CACHE_RULES: list[tuple[str, dict[int, Any]]] = [
    (r"/(k|v)$", {0: ("pod", "data"), 2: "model"}),      # (B,S,KV,dh)
    (r"/(c_kv|k_rope)$", {0: ("pod", "data")}),          # MLA latents
    (r"/pos$", {0: ("pod", "data")}),
    (r"/state$", {0: ("pod", "data"), 1: "model"}),      # SSM (B,H,P,N)
    (r"/conv_x$", {0: ("pod", "data"), 2: "model"}),
    (r"/conv_bc$", {0: ("pod", "data")}),
    (r"/h$", {0: ("pod", "data"), 1: "model"}),          # RG-LRU (B,w)
    (r"/conv$", {0: ("pod", "data"), 2: "model"}),
    (r"cross/(k|v)$", {1: ("pod", "data"), 3: "model"}), # (L,B,S,KV,dh)
]


# FSDP ("data"-axis weight sharding) only pays above this size: below it the
# whole shard fits trivially in HBM and GSPMD may otherwise choose to
# contract over the sharded weight dim (an activation-sized all-reduce)
# instead of gathering the weight.
FSDP_MIN_ELEMENTS = 32 * 1024 * 1024

# Embedding tables below this replicate rather than shard over 'model': the
# replicated footprint (<= 400 MB bf16) is cheaper than the per-step (B,S,D)
# lookup all-reduce a vocab-sharded table forces.
EMBED_SHARD_MIN_ELEMENTS = 200_000_000


def _spec_for(path: str, shape: tuple[int, ...], ctx: ParallelContext,
              rules, stacked_offset: bool) -> P:
    ndim = len(shape)
    n_elements = 1
    for s in shape:
        n_elements *= s
    for pattern, dims in rules:
        if re.search(pattern, path):
            # stacked layer params carry a leading layer dim: shift indices
            offset = 0
            if stacked_offset and path.startswith("layers/") or \
               stacked_offset and re.match(r"encdec/(enc|dec)/", path):
                offset = 1
            axes: list[Any] = [None] * ndim
            ok = True
            for dim, axis in dims.items():
                d = dim + offset
                if d >= ndim:
                    ok = False
                    break
                if axis == "data" and n_elements < FSDP_MIN_ELEMENTS:
                    continue   # FSDP not worth it for small weights
                if path.endswith("embed/table") \
                        and n_elements < EMBED_SHARD_MIN_ELEMENTS:
                    continue   # replicate small embedding tables
                sizes = 1
                names = axis if isinstance(axis, tuple) else (axis,)
                for nm in names:
                    sizes *= ctx.axis_size(nm)
                if sizes > 1 and shape[d] % sizes == 0:
                    axes[d] = axis
            if ok:
                return ctx.spec(*axes)
    return ctx.spec(*([None] * ndim))


def _dispatched_specs(path: str, w: DispatchedWeight, ctx: ParallelContext,
                      rules) -> DispatchedWeight:
    """Spec tree for a packed weight under the path-rule table.

    Only the payload's leading stack dims (layer-scan, expert) are
    addressable: the packed 2-D matmul view interleaves logical K/N into
    nibble planes / codebooks / selector bits, so rule dims that land past
    the stack (TP/FSDP cuts of the dense matrix) are dropped and those
    dims replicate. The surviving cut is the one serving needs — MoE
    expert banks over the EP "model" axis — and it is divisibility-guarded
    exactly like the dense rules."""
    ref = w.payload["packed" if "packed" in w.payload else "values"]
    n_stack = w.n_stack
    axes: list[Any] = [None] * n_stack
    for pattern, dims in rules:
        if not re.search(pattern, path):
            continue
        offset = 1 if (path.startswith("layers/")
                       or re.match(r"encdec/(enc|dec)/", path)) else 0
        for dim, axis in dims.items():
            d = dim + offset
            if d >= n_stack:
                continue          # packed matmul dims cannot shard
            sizes = 1
            names = axis if isinstance(axis, tuple) else (axis,)
            for nm in names:
                sizes *= ctx.axis_size(nm)
            if sizes > 1 and ref.shape[d] % sizes == 0:
                axes[d] = axis
        break
    return w.stack_specs(*ctx.spec(*axes))


def _walk_params(params, ctx: ParallelContext, rules):
    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(node[k], f"{prefix}/{k}" if prefix else str(k))
                    for k in node}
        if isinstance(node, (list, tuple)):
            out = [walk(v, f"{prefix}/{i}") for i, v in enumerate(node)]
            return out if isinstance(node, list) else tuple(out)
        if node is None:
            return None
        if isinstance(node, DispatchedWeight):
            return _dispatched_specs(prefix, node, ctx, rules)
        return _spec_for(prefix, node.shape, ctx, rules, stacked_offset=True)
    return walk(params)


def param_specs(params, ctx: ParallelContext):
    """PartitionSpec pytree matching `params` (structure-preserving).
    `DispatchedWeight` nodes map to same-structure spec subtrees over their
    payload leaves (see `_dispatched_specs`)."""
    return _walk_params(params, ctx, _RULES)


# Serving placement: the scheduler promises token streams bit-identical to
# the single-device path, so per-lane math must never cross ranks — TP
# sharding of dense weights inserts partial-sum reductions whose float
# accumulation order differs from the single-device contraction. Everything
# therefore replicates EXCEPT the MoE expert banks, which shard over the EP
# "model" axis: the shard_map EP path keeps each expert's FFN whole on one
# rank and the all_to_all moves tokens, not partial sums.
_SERVE_RULES: list[tuple[str, dict[int, str]]] = [
    (r"moe/w[gud]$", {0: "model"}),
]


def serve_param_specs(params, ctx: ParallelContext):
    """Mesh placement for scheduler params: EP expert banks (dense or
    packed `DispatchedWeight`) over "model", everything else replicated."""
    return _walk_params(params, ctx, _SERVE_RULES)


def _drop_model(spec):
    if spec is None:
        return None
    axes = []
    for a in spec:
        if a == "model":
            axes.append(None)
        elif isinstance(a, tuple):
            kept = tuple(x for x in a if x != "model")
            axes.append(kept if kept else None)
        else:
            axes.append(a)
    return P(*axes)


def serve_cache_specs(caches, ctx: ParallelContext):
    """Decode-cache placement for mesh serving: lanes (the batch dim) span
    hosts over the batch axes; head/width dims stay whole. `cache_specs`'
    model-axis cuts pair with TP attention weights — serving replicates
    those weights (see `serve_param_specs`), and a head-sharded cache
    against replicated projections would force cross-rank reshards that
    break the token-bit-parity contract."""
    return jax.tree.map(_drop_model, cache_specs(caches, ctx),
                        is_leaf=lambda s: s is None or isinstance(s, P))


def serve_arena_specs(arenas, ctx: ParallelContext):
    """Paged-pool arenas replicate: rows are (block, stack, ...) with no
    lane dim — any lane on any host may assemble any resident prefix."""
    return jax.tree.map(lambda _: P(), arenas)


def serve_staging_specs(staging, ctx: ParallelContext):
    """Chunked-prefill staging caches replicate: they are batch-1 scratch
    with no lane dim to span hosts (`serve_cache_specs`' batch rule would
    not divide anyway), and the donated admission merge that lands them
    into a lane needs every rank to hold the whole chunk state."""
    return jax.tree.map(lambda _: P(), staging)


def cache_specs(caches, ctx: ParallelContext, *, seq_fallback: bool = False):
    """Cache pytree specs: stacked leading layer dim shifts cache rules.

    seq_fallback (context-parallel decode): when the KV-head dim does not
    divide the model axis (GQA kv < |model|), shard the cache's SEQUENCE dim
    over 'model' instead — per-token scores/values reduce over the sharded
    context with two small per-layer all-reduces, and per-chip cache memory
    drops by |model| (the §Perf lever for memory-dominant decode cells)."""
    msize = ctx.axis_size("model")

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(node[k], f"{prefix}/{k}" if prefix else str(k))
                    for k in node}
        if isinstance(node, (list, tuple)):
            out = [walk(v, f"{prefix}/{i}") for i, v in enumerate(node)]
            return out if isinstance(node, list) else tuple(out)
        if node is None:
            return None
        shape = node.shape
        # stacked caches carry a leading layer axis not covered by the rule
        for pattern, dims in _CACHE_RULES:
            if re.search(pattern, prefix):
                for off in (1, 0):   # try stacked first
                    axes: list[Any] = [None] * len(shape)
                    fit = True
                    model_used = False
                    for dim, axis in dims.items():
                        d = dim + off
                        if d >= len(shape):
                            fit = False
                            break
                        sizes = 1
                        names = axis if isinstance(axis, tuple) else (axis,)
                        for nm in names:
                            sizes *= ctx.axis_size(nm)
                        if sizes > 1 and shape[d] % sizes == 0:
                            axes[d] = axis
                            if "model" in names:
                                model_used = True
                    if fit:
                        if (seq_fallback and not model_used and msize > 1
                                and re.search(r"/(k|v|c_kv|k_rope|pos)$", prefix)):
                            # sequence dim: dim 1 of the rule frame
                            d = 1 + off
                            if d < len(shape) and axes[d] is None \
                                    and shape[d] % msize == 0:
                                axes[d] = "model"
                        return ctx.spec(*axes)
        return ctx.spec(*([None] * len(shape)))
    return walk(caches)


def batch_specs(batch_like, ctx: ParallelContext):
    """Input batches shard dim 0 over (pod, data)."""
    def one(x):
        if x is None:
            return None
        axes = [None] * x.ndim
        total = 1
        for a in ctx.batch_axes:
            total *= ctx.axis_size(a)
        if total > 1 and x.shape[0] % total == 0:
            axes[0] = ("pod", "data")
        return ctx.spec(*axes)
    return jax.tree.map(one, batch_like,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))


def opt_state_specs(opt_state, pspecs, ctx: ParallelContext,
                    zero1: bool = True):
    """Moments take the param spec; with ZeRO-1 additionally shard the
    largest unsharded dim over 'data' when divisible."""
    data_size = ctx.axis_size("data")

    def widen(spec: P, shape) -> P:
        if not zero1 or data_size <= 1 or spec is None:
            return spec
        axes = list(spec) + [None] * (len(shape) - len(spec))
        flat = [a for ax in axes if ax is not None
                for a in (ax if isinstance(ax, tuple) else (ax,))]
        if "data" in flat:          # FSDP params already use the data axis
            return P(*axes)
        best, best_dim = -1, -1
        for i, (a, s) in enumerate(zip(axes, shape)):
            if a is None and s % data_size == 0 and s > best:
                best, best_dim = s, i
        if best_dim >= 0:
            axes[best_dim] = "data"
        return P(*axes)

    m_spec = jax.tree.map(widen, pspecs,
                          jax.tree.map(lambda x: x.shape, opt_state["m"]))
    v_spec = jax.tree.map(widen, pspecs,
                          jax.tree.map(lambda x: x.shape, opt_state["v"]))
    return {"step": ctx.spec(), "m": m_spec, "v": v_spec}
