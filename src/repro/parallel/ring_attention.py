"""Ring attention: context-parallel prefill over the 'model' axis.

For prefill lengths where even one sequence's KV does not fit a chip (the
regime between `prefill_32k` and `long_500k`), the sequence dimension itself
shards across the mesh: each rank holds an S/m slice of Q, K, V; KV blocks
rotate around the ring (`ppermute`) while each rank accumulates its local
queries' online softmax against every block. ICI cost: each KV block
traverses the ring once — bytes = S·KV·d·2 per rank pair, fully overlappable
with the block's attention compute on real hardware.

Forward-only by design: this is the serving-prefill path. Training-time
sequence parallelism uses the GSPMD `seq_shard` route instead (DESIGN §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def ring_attention(
    q: jnp.ndarray,            # (B, S, H, dh) — S sharded over `axis`
    k: jnp.ndarray,            # (B, S, KV, dh)
    v: jnp.ndarray,            # (B, S, KV, dh)
    mesh: Mesh,
    *,
    axis: str = "model",
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    m = mesh.shape[axis]
    assert s % m == 0, f"seq {s} must divide the {axis} axis ({m})"
    scale_ = scale if scale is not None else dh ** -0.5

    def body(qb, kb, vb):
        # local blocks: (B, S/m, ...) on every rank
        rank = jax.lax.axis_index(axis)
        s_m = qb.shape[1]
        q32 = qb.reshape(b, s_m, kvh, g, dh).astype(jnp.float32)
        q_pos = rank * s_m + jnp.arange(s_m)

        acc0 = jnp.zeros((b, s_m, kvh, g, dh), jnp.float32)
        m0 = jnp.full((b, s_m, kvh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, s_m, kvh, g), jnp.float32)
        perm = [(i, (i + 1) % m) for i in range(m)]

        def step(carry, r):
            mx, l, acc, kc, vc = carry
            src = (rank - r) % m                 # origin rank of this block
            k_pos = src * s_m + jnp.arange(s_m)
            srt = jnp.einsum("bqkgd,bckd->bqkgc", q32, kc.astype(jnp.float32)) \
                * scale_
            if causal:
                allow = k_pos[None, :] <= q_pos[:, None]
                srt = jnp.where(allow[None, :, None, None, :], srt, NEG_INF)
            m_new = jnp.maximum(mx, srt.max(axis=-1))
            p = jnp.exp(srt - m_new[..., None])
            corr = jnp.exp(mx - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vc.astype(jnp.float32))
            # rotate KV around the ring for the next step
            kc = jax.lax.ppermute(kc, axis, perm)
            vc = jax.lax.ppermute(vc, axis, perm)
            return (m_new, l, acc, kc, vc), None

        (mx, l, acc, _, _), _ = jax.lax.scan(
            step, (m0, l0, acc0, kb, vb), jnp.arange(m))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(b, s_m, h, dh).astype(qb.dtype)

    spec = P(None, axis, None, None)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_rep=False)(q, k, v)


def ring_prefill(
    q: jnp.ndarray,            # (B, S, H, dh)
    k: jnp.ndarray,            # (B, S, KV, dh)
    v: jnp.ndarray,            # (B, S, KV, dh)
    ctx,                       # ParallelContext
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Serve-path wrapper around `ring_attention`: the degenerate ring (null
    context or a 1-rank model axis) falls back to the monolithic flash path,
    and a ragged sequence pads up to the ring multiple — causal masking keeps
    the padded tail keys inert for every real query (their positions are
    strictly greater), so the slice back is exact."""
    if ctx is None or not ctx.active or ctx.axis_size("model") <= 1:
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal, scale=scale)
    assert causal, "ring_prefill pads the sequence; needs causal masking"
    m = ctx.axis_size("model")
    s = q.shape[1]
    pad = (-s) % m
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    out = ring_attention(q, k, v, ctx.mesh, causal=True, scale=scale)
    return out[:, :s]
