"""Parallel context: the mesh and axis names threaded through the model.

Axis convention (DESIGN.md §5):
  * "pod"   — outer data-parallel axis across pods (multi-pod mesh only)
  * "data"  — data-parallel axis within a pod
  * "model" — tensor/expert-parallel axis (TP for dense blocks, EP for MoE)

`ParallelContext(mesh=None)` is the single-device mode every smoke test runs
in: all sharding constraints become no-ops and MoE takes the dense path.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh | None = None
    use_ep: bool = True            # expert-parallel MoE (shard_map all_to_all)
    zero1: bool = True             # shard optimizer state over the data axes
    remat: str = "full"            # full | dots | none
    # Ring-attention prefill threshold: prompts of at least this many tokens
    # route prefill attention through `parallel.ring_attention` (the
    # context-parallel path for sequences beyond one device's cache slab).
    # None keeps every prefill on the local flash path — the default, so
    # mesh serving stays bit-identical to single-device unless opted in.
    ring_prefill_min: int | None = None

    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.mesh is not None and self.mesh.devices.size > 1

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    @property
    def batch_axes(self) -> tuple[str, ...]:
        """Axes the global batch shards over (pod+data when present)."""
        return tuple(a for a in ("pod", "data") if a in self.axis_names)

    @property
    def model_axis(self) -> str | None:
        return "model" if "model" in self.axis_names else None

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.axis_names:
            return 1
        return self.mesh.shape[name]

    @property
    def num_devices(self) -> int:
        return self.mesh.devices.size if self.mesh is not None else 1

    # ------------------------------------------------------------------
    def spec(self, *axes: str | tuple[str, ...] | None) -> P:
        """PartitionSpec with axes not present in the mesh dropped."""
        cleaned = []
        for a in axes:
            if a is None:
                cleaned.append(None)
            elif isinstance(a, tuple):
                present = tuple(x for x in a if x in self.axis_names)
                cleaned.append(present if present else None)
            else:
                cleaned.append(a if a in self.axis_names else None)
        return P(*cleaned)

    def sharding(self, *axes) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*axes))

    def constrain(self, x, *axes):
        """with_sharding_constraint that degrades to identity off-mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(*axes))

    def divisible(self, n: int, axis: str) -> bool:
        s = self.axis_size(axis)
        return s > 1 and n % s == 0


CPU_CTX = ParallelContext(mesh=None)
