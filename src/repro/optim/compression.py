"""Gradient compression for the DP all-reduce: int8 with error feedback.

The paper's weight-compression result — bandwidth, not storage, is what
compression buys on the direct route (ch. 7) — applied to the *gradient*
stream of data-parallel training: quantize each gradient leaf to int8 with a
per-block fp32 scale before it crosses the interconnect, carry the
quantization residual forward (error feedback, Seide et al. / 1-bit SGD
lineage), and dequantize after the reduce.

Under `jit`+GSPMD the all-reduce is implicit; this module exposes the
quantize/dequantize pair and a `compressed_psum` for explicit shard_map
pipelines, plus the error-feedback wrapper used by the train loop when
`--grad-compression int8` is set. Bytes crossing the DP boundary drop 4x
(the collective term of the roofline), at the cost of one extra residual
buffer — exactly the stream-vs-fold trade of paper ch. 7.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 256


def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8. Returns (q (N/B, B) int8, scales (N/B,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape: tuple[int, ...]) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, residual):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed_repr, new_residual). compressed_repr round-trips via
    `decompress_grads`; residual holds what quantization dropped and is added
    back into the next step's gradients (so the *long-run* update is unbiased
    even though each step moves 4x fewer bytes)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + (r if r is not None else 0.0)
        q, s = quantize_int8(g32)
        back = dequantize_int8(q, s, g.shape)
        return (q, s), g32 - back

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual) if residual is not None else [None] * len(flat_g)
    comp, res = [], []
    for g, r in zip(flat_g, flat_r):
        c, nr = one(g, r)
        comp.append(c)
        res.append(nr)
    return jax.tree.unflatten(td, comp), jax.tree.unflatten(td, res)


def decompress_grads(comp, like):
    flat_c, _ = jax.tree.flatten(comp, is_leaf=lambda x: isinstance(x, tuple))
    flat_l, td = jax.tree.flatten(like)
    out = [dequantize_int8(q, s, l.shape).astype(l.dtype)
           for (q, s), l in zip(flat_c, flat_l)]
    return jax.tree.unflatten(td, out)


def init_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Explicit compressed all-reduce for shard_map pipelines.

    Two-phase shared-scale scheme: (1) a tiny pmax agrees on one fp32 scale
    per 256-element block across shards; (2) every shard quantizes against
    the shared scale and the payload reduces in integer space — exact w.r.t.
    the quantized values, deterministic, and the wire payload is int8-wide
    (the int32 psum here models the 8-bit wire; real deployments ship the
    int8 and widen at the reducer). Bytes on the wire: ~1/4 of fp32."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    local_max = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.maximum(jax.lax.pmax(local_max, axis_name) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    out = (total.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape)
