"""Compression for the two streams that cross a bandwidth boundary.

1. **Model-weight compression for serving** (paper ch. 7): tag matmul
   weights in the param pytree with a `WeightForm` and pack them
   (`compress_model_params`) so the dispatcher streams them through the
   `palette`/`sparse` kernel rows instead of folding to dense on the host.
   The tag rides in `models.dispatched.DispatchedWeight` aux data and is
   preserved by `checkpoint/`.

2. **Gradient compression for the DP all-reduce**: int8 with error feedback.

The paper's weight-compression result — bandwidth, not storage, is what
compression buys on the direct route (ch. 7) — applied to the *gradient*
stream of data-parallel training: quantize each gradient leaf to int8 with a
per-block fp32 scale before it crosses the interconnect, carry the
quantization residual forward (error feedback, Seide et al. / 1-bit SGD
lineage), and dequantize after the reduce.

Both halves share the same roofline argument: the bytes that matter are the
ones that move, and the reconstruction point sits on the far side of the
boundary (multiplier input for weights, reducer input for gradients).

Under `jit`+GSPMD the all-reduce is implicit; this module exposes the
quantize/dequantize pair and a `compressed_psum` for explicit shard_map
pipelines, plus the error-feedback wrapper used by the train loop when
`--grad-compression int8` is set. Bytes crossing the DP boundary drop 4x
(the collective term of the roofline), at the cost of one extra residual
buffer — exactly the stream-vs-fold trade of paper ch. 7.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hal import WeightForm
from repro.kernels import compat
from repro.models import dispatched as dsp

_BLOCK = 256


# ---------------------------------------------------------------------------
# Model-weight compression: per-parameter WeightForm tagging + packing
# ---------------------------------------------------------------------------

# Param-leaf names that are matmul weights, with their matmul view:
# (n_contract, n_out) — leading dims beyond that are stack dims (layer scan,
# expert bank). Attention-context names need their module prefix to
# disambiguate (an MLP "wo" contracts one dim, an attention "wo" two).
_ATTN_CONTEXT = ("mix", "attn", "self_attn", "cross_attn")
_MLP_CONTEXT = ("mlp", "moe", "shared", "mtp")


def matmul_view(path: str):
    """(n_contract, n_out) of the leaf at `path`, or None if it is not an
    eligible matmul weight. MLA's `wq_b`/`wkv_b` stay dense: the absorbed
    decode slices the expanded bank, which a packed form cannot do."""
    parts = path.split("/")
    name = parts[-1]
    in_attn = any(c in parts for c in _ATTN_CONTEXT)
    if in_attn and name in ("wq", "wk", "wv"):
        return (1, 2)
    if in_attn and name == "wo":
        return (2, 1)
    if in_attn and name in ("wq_a", "wkv_a"):
        return (1, 1)
    if name in ("wi", "wg", "wu", "wd", "wo") and \
            any(c in parts for c in _MLP_CONTEXT):
        return (1, 1)
    if name == "unembed" or (name == "proj" and "mtp" in parts):
        return (1, 1)
    return None


def compress_model_params(params, form: WeightForm | str, *,
                          predicate: Callable[[str], bool] | None = None,
                          palette_iters: int = 4):
    """Tag-and-pack every eligible matmul weight of a param pytree.

    Walks the tree by path, replaces each eligible dense leaf with a
    `DispatchedWeight` carrying the `WeightForm` tag and the packed payload
    (stack dims — layer scan, expert banks — preserved as leading payload
    dims). Leaves whose contraction extent cannot pack into `form`
    (palette wants K even, sparse K % 16 == 0) stay dense and keep routing
    through the `anemm` row. `predicate(path)` further restricts the set.
    """
    form = WeightForm(form) if isinstance(form, str) else form
    if form not in dsp.FORM_KERNELS:
        raise ValueError(f"{form} has no streaming kernel; "
                         f"have {sorted(f.value for f in dsp.FORM_KERNELS)}")

    def one(path, leaf):
        path_str = compat.tree_path_str(path)
        view = matmul_view(path_str)
        if view is None or (predicate is not None and not predicate(path_str)):
            return leaf
        n_contract, n_out = view
        if leaf.ndim < n_contract + n_out:
            return leaf
        n_stack = leaf.ndim - n_contract - n_out
        k = int(np.prod(leaf.shape[n_stack:n_stack + n_contract]))
        if not dsp.packable(form, k):
            return leaf
        return dsp.pack_linear_weight(np.asarray(leaf), form,
                                      n_contract=n_contract, n_out=n_out,
                                      palette_iters=palette_iters)

    return compat.tree_map_with_path(one, params)


def decompress_model_params(params):
    """The FOLD path: decode every packed weight back to a dense array with
    its logical shape/dtype — what the parity harness multiplies against
    (same quantized values, dense bytes)."""
    def one(leaf):
        if not isinstance(leaf, dsp.DispatchedWeight):
            return leaf
        lead = jax.tree.leaves(leaf.payload)[0].shape[:leaf.n_stack]
        if not lead:
            return leaf.dense()
        flat = [jax.tree.map(lambda a, idx=idx: a[idx], leaf).dense()
                for idx in np.ndindex(*lead)]
        stacked = jnp.stack(flat)
        return stacked.reshape(lead + stacked.shape[1:])
    return jax.tree.map(
        one, params,
        is_leaf=lambda x: isinstance(x, dsp.DispatchedWeight))


def weight_form_census(params) -> dict[str, str]:
    """path -> form tag for every packed leaf (debug/report surface)."""
    out: dict[str, str] = {}
    leaves, _ = compat.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, dsp.DispatchedWeight))
    for path, leaf in leaves:
        if isinstance(leaf, dsp.DispatchedWeight):
            out[compat.tree_path_str(path)] = leaf.form.value
    return out


def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8. Returns (q (N/B, B) int8, scales (N/B,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape: tuple[int, ...]) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, residual):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed_repr, new_residual). compressed_repr round-trips via
    `decompress_grads`; residual holds what quantization dropped and is added
    back into the next step's gradients (so the *long-run* update is unbiased
    even though each step moves 4x fewer bytes)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + (r if r is not None else 0.0)
        q, s = quantize_int8(g32)
        back = dequantize_int8(q, s, g.shape)
        return (q, s), g32 - back

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual) if residual is not None else [None] * len(flat_g)
    comp, res = [], []
    for g, r in zip(flat_g, flat_r):
        c, nr = one(g, r)
        comp.append(c)
        res.append(nr)
    return jax.tree.unflatten(td, comp), jax.tree.unflatten(td, res)


def decompress_grads(comp, like):
    flat_c, _ = jax.tree.flatten(comp, is_leaf=lambda x: isinstance(x, tuple))
    flat_l, td = jax.tree.flatten(like)
    out = [dequantize_int8(q, s, l.shape).astype(l.dtype)
           for (q, s), l in zip(flat_c, flat_l)]
    return jax.tree.unflatten(td, out)


def init_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Explicit compressed all-reduce for shard_map pipelines.

    Two-phase shared-scale scheme: (1) a tiny pmax agrees on one fp32 scale
    per 256-element block across shards; (2) every shard quantizes against
    the shared scale and the payload reduces in integer space — exact w.r.t.
    the quantized values, deterministic, and the wire payload is int8-wide
    (the int32 psum here models the 8-bit wire; real deployments ship the
    int8 and widen at the reducer). Bytes on the wire: ~1/4 of fp32."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    local_max = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.maximum(jax.lax.pmax(local_max, axis_name) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    out = (total.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape)
