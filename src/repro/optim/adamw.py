"""AdamW in pure JAX: decoupled weight decay, cosine schedule, global-norm
clipping, fp32 master arithmetic over low-precision params.

ZeRO-1 optimizer-state sharding and gradient compression live in their own
modules (`repro.parallel.sharding`, `repro.optim.compression`) and wrap this
kernel-simple optimizer.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" halves state memory
    # "cosine" decays to min_lr_ratio * peak over total_steps; "constant"
    # holds peak_lr after warmup — the right shape for short distillation
    # runs whose step count is a budget, not a convergence horizon
    schedule_kind: str = "cosine"


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup, then cosine to min_lr_ratio * peak or constant peak."""
    if cfg.schedule_kind not in ("cosine", "constant"):
        raise ValueError(f"schedule_kind {cfg.schedule_kind!r} "
                         f"not in ('cosine', 'constant')")
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    if cfg.schedule_kind == "constant":
        return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(cfg: AdamWConfig, params) -> dict[str, Any]:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    new_state = {"step": step,
                 "m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v)}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return jax.tree.unflatten(treedef, new_p), new_state, metrics
