"""Config system: architecture configs and the assigned input-shape set.

Every assigned architecture is a `ModelConfig`; the four assigned shapes are
`ShapeConfig`s. `smoke(cfg)` produces the reduced same-family config used by
the CPU smoke tests (full configs are exercised only via the dry-run's
ShapeDtypeStructs — no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # --- common options ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # gate activation for the GLU MLP
    use_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    attn_window: int | None = None   # sliding-window (local) attention size
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0          # leading dense layers (DeepSeek-V3: 3)
    moe_capacity_factor: float = 1.25
    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0               # multi-token-prediction extra depth
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # --- hybrid (RG-LRU / RecurrentGemma) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "attn")
    lru_width: int = 0
    # --- encoder-decoder (Whisper backbone) ---
    n_encoder_layers: int = 0
    encoder_len: int = 0
    n_mels: int = 0                  # >0: conv stem eats mel frames
    stem_width: int = 3              # conv-stem kernel width (time axis)
    stem_stride: int = 2             # second stem conv's time downsample
    # --- numerics / technique knobs ---
    dtype: str = "bfloat16"          # activation/weight compute dtype
    logits_fp32: bool = True         # the paper's "wider anchor" rule (§3.9)
    remat: str = "full"              # full | dots | none
    scan_layers: bool = True
    seq_shard: bool = True           # Megatron-style sequence parallelism:
    # residual stream (and thus the saved remat checkpoints) sharded over
    # 'model' between layers; GSPMD inserts the all-gather/reduce-scatter
    # pair around attention/MLP. Validated in §Perf pair B; now the default
    # (the paper-faithful baseline sweep ran with it off).
    shard_cache_seq: bool = True     # context-parallel decode: shard the KV
    # cache's sequence dim over 'model' when the KV-head count doesn't
    # divide it (GQA kv=8 on a 16-way axis). Validated in §Perf pair A;
    # now the default (baseline sweep ran with it off).

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k context? SSM state is O(1); the hybrid's
        local attention caches only its window. Full-attention archs are not
        sub-quadratic and skip `long_500k` (DESIGN.md §Arch-applicability)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.attn_window is not None:
            return True
        return False

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 for clean TP sharding."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def block_kind(self, layer_idx: int) -> str:
        """Temporal-mixing kind for layer `layer_idx`."""
        if self.family == "ssm":
            return "ssm"
        if self.block_pattern:
            return self.block_pattern[layer_idx % len(self.block_pattern)]
        return "attn"

    def layer_is_moe(self, layer_idx: int) -> bool:
        return self.n_experts > 0 and layer_idx >= self.n_dense_layers

    @property
    def frame_shape(self) -> tuple[int, int]:
        """Per-request encoder input (frames, features). With a conv stem
        (`n_mels > 0`) the encoder eats `stem_stride * encoder_len` mel
        frames of width `n_mels`; without one it eats pre-projected
        `d_model` features directly (the seed's stubbed frontend)."""
        if self.n_mels:
            return (self.stem_stride * self.encoder_len, self.n_mels)
        return (self.encoder_len, self.d_model)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned input-shape set (every arch pairs with all four; long_500k is
# principled-skipped for pure full-attention archs).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_runs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Does (arch x shape) run, and if not, why (the principled skip)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense KV cache + O(S) scores "
                       "per token is the quadratic regime long_500k excludes "
                       "(DESIGN.md §Arch-applicability)")
    return True, ""


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny vocab — one forward/train step must run on CPU."""
    n_layers = max(2, min(3, cfg.n_layers)) if not cfg.block_pattern else len(cfg.block_pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=16,
        d_ff=128,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_dense_layers=min(cfg.n_dense_layers, 1),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_rope_dim=8 if cfg.qk_rope_dim else 0,
        qk_nope_dim=8 if cfg.qk_nope_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        mtp_depth=min(cfg.mtp_depth, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=32 if cfg.ssm_state else cfg.ssm_chunk,
        lru_width=64 if cfg.lru_width else 0,
        attn_window=min(cfg.attn_window, 32) if cfg.attn_window else None,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_len=min(cfg.encoder_len, 24) if cfg.encoder_len else 0,
        dtype="float32",
    )
