"""chameleon-34b [vlm]: early-fusion, VQ image tokens [arXiv:2405.09818].

Backbone only per assignment: the VQ tokenizer frontend is a stub —
`input_specs()` supplies token ids that already include image tokens.
Chameleon's stability recipe is QK-norm (norm on queries/keys).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65536,
    norm="rmsnorm", act="silu", qk_norm=True,
)
