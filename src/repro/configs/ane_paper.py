"""ane-paper: the paper's own workload config — the probe networks the guide
measures (conv stacks, matmul chains, reduction probes) expressed as a tiny
dense transformer plus the standalone probes driven by the benchmarks.

This is not an assigned architecture; it is "the paper's own" config per the
deliverable (f) parenthetical, used by the paper-validation benchmarks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="ane-paper", family="dense",
    n_layers=8, d_model=1024, n_heads=8, n_kv_heads=8, d_head=128,
    d_ff=4096, vocab=32000,
    norm="layernorm", act="gelu",
    dtype="float16",          # the engine's datapath dtype
)
