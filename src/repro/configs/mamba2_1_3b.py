"""mamba2-1.3b [ssm]: SSD (state-space duality), attn-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50280,
    norm="rmsnorm",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, ssm_groups=1,
)
