"""tinyllama-1.1b [dense]: llama2-arch small [arXiv:2401.02385; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_head=64,
    d_ff=5632, vocab=32000,
    norm="rmsnorm", act="silu",
)
