"""recurrentgemma-9b [hybrid]: RG-LRU + local attn, 1:2 [arXiv:2402.19427].

Block pattern: two RG-LRU recurrent blocks per one local-attention block
(window 2048), MQA (kv=1). Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
    d_ff=12288, vocab=256000,
    norm="rmsnorm", act="gelu",
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096, attn_window=2048,
)
