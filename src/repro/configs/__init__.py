"""Architecture registry: one module per assigned architecture.

`get_config(name)` returns the full published config; `get_smoke(name)` the
reduced same-family config the CPU smoke tests instantiate.
"""

from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_runs, smoke
from repro.configs import (
    chameleon_34b,
    command_r_35b,
    dbrx_132b,
    deepseek_v3_671b,
    granite_8b,
    mamba2_1_3b,
    phi4_mini_3_8b,
    recurrentgemma_9b,
    tinyllama_1_1b,
    whisper_small,
    ane_paper,
)

_MODULES = {
    "chameleon-34b": chameleon_34b,
    "granite-8b": granite_8b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "command-r-35b": command_r_35b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "dbrx-132b": dbrx_132b,
    "whisper-small": whisper_small,
    "mamba2-1.3b": mamba2_1_3b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "ane-paper": ane_paper,
}

ARCH_NAMES = [n for n in _MODULES if n != "ane-paper"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ModelConfig:
    return smoke(get_config(name))


__all__ = [
    "ARCH_NAMES", "SHAPES", "ModelConfig", "ShapeConfig",
    "cell_runs", "get_config", "get_smoke", "smoke",
]
