"""granite-8b [dense]: llama-arch code model [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=49152,
    norm="rmsnorm", act="silu", tie_embeddings=True,
)
