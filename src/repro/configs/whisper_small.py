"""whisper-small [audio]: enc-dec with a real conv stem [arXiv:2212.04356].

The modality frontend is Whisper's two-conv stem: `input_specs()` provides
log-mel frames (B, 3000, 80); two width-3 1-D convs (the second stride-2)
with GELU project them to (B, 1500, d_model) before the 12L encoder. The
stem runs through the conv2d kernel family (fused LUT-GELU epilogue when
dispatched); the transformer backbone is the 12L+12L enc-dec with
cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab=51865,
    norm="layernorm", act="gelu_mlp", use_bias=True,
    n_encoder_layers=12, encoder_len=1500, n_mels=80,
)
