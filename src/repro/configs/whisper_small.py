"""whisper-small [audio]: enc-dec, conv frontend STUB [arXiv:2212.04356].

Per assignment the modality frontend is a stub: `input_specs()` provides
precomputed frame embeddings (B, 1500, d_model); the transformer backbone
(12L encoder + 12L decoder with cross-attention) is what we build.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab=51865,
    norm="layernorm", act="gelu_mlp", use_bias=True,
    n_encoder_layers=12, encoder_len=1500,
)
