"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].

Assignment line: 61L d_model=7168 128H d_ff=2048(expert) vocab=129280,
MoE 256e top-8. MLA dims per the published config: q_lora 1536, kv_lora 512,
qk_rope 64, qk_nope 128, v_head 128; first 3 layers dense with d_ff 18432.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=192,
    d_ff=18432, vocab=129280,
    norm="rmsnorm", act="silu",
    n_experts=256, experts_per_token=8, n_shared_experts=1,
    d_ff_expert=2048, n_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    mtp_depth=1,
)
