"""command-r-35b [dense]: GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22528, vocab=256000,
    norm="layernorm", act="silu", use_bias=False, tie_embeddings=True,
)
