"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=200064,
    norm="rmsnorm", act="silu", tie_embeddings=True,
)
