"""Elastic scaling: resume a checkpoint on a different mesh.

When a pod is lost (or gained), the job re-plans: a new mesh is built from
the surviving device count, every parameter/optimizer leaf gets the sharding
the *new* mesh prescribes, and the checkpoint restores through a placer that
device_puts each full array with its new sharding. Batch and learning-rate
re-scaling follow the linear rule.

The expensive part on a real fleet — resharding in-memory state without
going through the filesystem — maps to `jax.device_put` with the new
sharding (XLA moves only the bytes that change owner). Here we validate the
plan + restore logic; the dry-run validates that both mesh shapes compile.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_devices: int
    new_devices: int
    new_mesh_shape: tuple[int, ...]
    new_axis_names: tuple[str, ...]
    batch_scale: float          # keep global batch (1.0) or scale with fleet
    lr_scale: float

    @property
    def shrinking(self) -> bool:
        return self.new_devices < self.old_devices


def plan_rescale(old_devices: int, new_devices: int,
                 *, model_parallel: int = 16,
                 keep_global_batch: bool = True) -> RescalePlan:
    """Choose the new mesh: keep the model axis (sharding invariants of the
    params), flex the data axis, split off a pod axis when the data axis
    would exceed one pod's worth."""
    if new_devices % model_parallel != 0:
        raise ValueError(f"{new_devices} devices not divisible by "
                         f"model={model_parallel}")
    data = new_devices // model_parallel
    if data >= 32 and data % 2 == 0:
        shape = (2, data // 2, model_parallel)
        names = ("pod", "data", "model")
    else:
        shape = (data, model_parallel)
        names = ("data", "model")
    scale = 1.0 if keep_global_batch else new_devices / old_devices
    return RescalePlan(old_devices, new_devices, shape, names,
                       batch_scale=scale, lr_scale=scale)


def build_mesh(plan: RescalePlan, devices=None) -> Mesh:
    """Mesh for the plan. `devices` (e.g. the survivors of a host loss, in
    placement order) restricts where the mesh lands; the default uses every
    visible device — which after a *real* host loss is exactly the survivor
    set, but in single-process simulation still contains the "failed"
    rows, so the elastic supervisor passes the survivors explicitly."""
    if devices is None:
        return jax.make_mesh(plan.new_mesh_shape, plan.new_axis_names)
    devices = np.asarray(devices).reshape(-1)
    need = int(np.prod(plan.new_mesh_shape))
    if devices.size < need:
        raise ValueError(f"plan wants {need} devices, "
                         f"got {devices.size} survivors")
    return Mesh(devices[:need].reshape(plan.new_mesh_shape),
                plan.new_axis_names)


def make_placer(mesh: Mesh, spec_fn):
    """Placer for CheckpointManager.restore: device_put each leaf with the
    sharding the new mesh prescribes (spec_fn(path, shape) -> PartitionSpec).
    """
    def place(path: str, arr: np.ndarray):
        spec = spec_fn(path, arr.shape)
        return jax.device_put(arr, NamedSharding(mesh, spec))
    return place
