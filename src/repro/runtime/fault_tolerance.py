"""Fault tolerance: heartbeats, straggler detection, restart policy.

At thousand-node scale the failure model is: hosts vanish (preemption,
hardware), hosts slow down (thermal, network), and steps hang (collective
deadlock after a peer dies). The framework's contract:

  * every step emits a heartbeat; a `Watchdog` with a step deadline turns
    hangs into restarts-from-checkpoint instead of infinite stalls;
  * a `StragglerDetector` tracks per-host step times against the fleet
    median and flags persistent outliers for replacement — on TPU pods the
    mitigation is re-slicing without the slow host (here: the elastic
    rescale plan of `runtime/elastic.py`);
  * `run_with_restarts` is the supervisor loop: run -> crash/hang -> restore
    latest committed checkpoint -> continue, with bounded retries. The data
    pipeline is a pure function of (seed, step), so restarts are
    bit-deterministic.

Everything here is exercised for real in tests by injecting failures into a
training loop; nothing requires more than one physical host to validate the
logic.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable


@dataclasses.dataclass
class Heartbeat:
    host: int
    step: int
    wall_s: float
    t: float


class StragglerDetector:
    """Flags hosts whose recent step times exceed `threshold` x fleet median
    for at least `patience` consecutive windows (paper §2.4's single-queue
    serialization means one slow host gates the whole step — finding it fast
    matters)."""

    def __init__(self, window: int = 16, threshold: float = 1.5,
                 patience: int = 3):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self._times: dict[int, deque] = defaultdict(
            lambda: deque(maxlen=window))
        self._strikes: dict[int, int] = defaultdict(int)

    def record(self, hb: Heartbeat) -> None:
        self._times[hb.host].append(hb.wall_s)

    def evaluate(self) -> list[int]:
        """Returns hosts currently flagged as stragglers."""
        import statistics

        medians = {h: statistics.median(t) for h, t in self._times.items() if t}
        if len(medians) < 2:
            return []
        fleet = statistics.median(medians.values())
        flagged = []
        for h, m in medians.items():
            if m > self.threshold * fleet:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.patience:
                flagged.append(h)
        return flagged


class Watchdog:
    """Step-deadline watchdog: `poke()` every step; `expired()` turns a hang
    into a supervisor-visible failure."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self._last = time.monotonic()

    def poke(self) -> None:
        self._last = time.monotonic()

    def expired(self) -> bool:
        return (time.monotonic() - self._last) > self.deadline_s


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 0.0            # real deployments back off; tests don't


class TrainingAborted(RuntimeError):
    pass


def run_with_restarts(
    run_fn: Callable[[int], int],
    *,
    policy: RestartPolicy | None = None,
    on_restart: Callable[[int, BaseException], None] | None = None,
) -> int:
    """Supervisor loop. `run_fn(start_step)` trains from `start_step` (the
    caller restores its own checkpoint inside) and returns the final step;
    raising simulates/relays a node failure. Returns the final step.

    `policy=None` constructs a fresh `RestartPolicy` per call — a dataclass
    instance in the signature default would be one object shared by every
    caller, so a caller mutating e.g. `max_restarts` would silently change
    the retry budget of unrelated supervisors.
    """
    policy = policy if policy is not None else RestartPolicy()
    restarts = 0
    start_step = 0
    while True:
        try:
            return run_fn(start_step)
        except TrainingAborted:
            raise
        except Exception as e:  # noqa: BLE001 — any crash triggers restart
            restarts += 1
            if restarts > policy.max_restarts:
                raise TrainingAborted(
                    f"exceeded {policy.max_restarts} restarts") from e
            if on_restart is not None:
                on_restart(restarts, e)
            if policy.backoff_s:
                time.sleep(policy.backoff_s)
            # run_fn restores from the latest committed checkpoint; we pass
            # -1 to signal "resume from checkpoint".
            start_step = -1
