"""Elastic serve supervision: heartbeats, host-loss evacuation, restarts.

The scheduler (`launch/scheduler.py`) runs one SPMD engine fleet: lanes (the
decode batch dim) span "hosts" — the batch-axis ranks of the serving mesh,
each rank's model-axis column being one host's co-located engine slice. This
module is the control plane the ROADMAP's fleet story needs on top of it,
reusing the training-side machinery wholesale:

  * **heartbeats** — every scheduler tick calls the supervisor's
    `step_hook`; each live host records a `Heartbeat` into the
    `StragglerDetector` and pokes the step `Watchdog`. A host that stops
    heartbeating for `deadline_steps` ticks, or a tick that blows the
    watchdog's wall deadline (a collective hung on a dead peer), raises
    `HostFailure` out of the serve loop.
  * **evacuation** — on `HostFailure` the supervisor (a) harvests results
    the aborted run already finished, (b) snapshots every active lane's
    host-side state machine (request, tokens generated so far), (c) plans
    the shrunken mesh with `plan_rescale` + `build_mesh` over the surviving
    devices (the model axis is preserved; one batch rank disappears),
    (d) rebuilds the scheduler on the new mesh — `device_put` against the
    new placement is the whole in-memory reshard — carrying the paged KV
    pool across so resident prefixes stay warm, and (e) re-admits every
    interrupted lane through the *ordinary* admission path.
  * **token exactness** — a resumed request's prompt is the original prompt
    plus the tokens it already generated, with the remaining budget. The
    re-admitted lane teacher-forces through that extended prompt (bucketed
    prefill + catch-up decode, or a paged-pool prefix hit), and sampling is
    keyed per (rid, absolute position), so the resumed stream continues
    with exactly the tokens the uninterrupted run would have produced.
  * **bounded restarts** — the attempt loop is `run_with_restarts`: each
    `HostFailure` costs one restart from the policy budget; exceeding it
    raises `TrainingAborted` like any training job.

Failure injection (`FailureInjection`) simulates the two §fault_tolerance
failure classes in-process: "vanish" (the host stops heartbeating) and
"hang" (one tick stalls past the watchdog deadline). Nothing here requires
more than one physical host; on a real fleet the heartbeats would arrive
over the network and `build_mesh`'s default device set would already be the
survivor set.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.launch.kv_pool import PagedKVPool
from repro.launch.scheduler import Request, RequestResult, _SchedulerBase
from repro.parallel.ctx import ParallelContext
from repro.runtime.elastic import RescalePlan, build_mesh, plan_rescale
from repro.runtime.fault_tolerance import (Heartbeat, RestartPolicy,
                                           StragglerDetector, TrainingAborted,
                                           Watchdog, run_with_restarts)


class HostFailure(RuntimeError):
    """A serving host is gone (or wedged): raised out of the scheduler's
    step hook so the supervisor unwinds at a tick boundary."""

    def __init__(self, host: int, reason: str = "heartbeat lost") -> None:
        self.host = host
        super().__init__(f"host {host}: {reason}")


@dataclasses.dataclass(frozen=True)
class FailureInjection:
    """Simulated host loss: at scheduler step `at_step`, host `host` either
    stops heartbeating ("vanish") or stalls one tick past the watchdog
    deadline ("hang"). Consumed by the first evacuation it triggers."""

    host: int
    at_step: int
    kind: str = "vanish"          # "vanish" | "hang"

    def __post_init__(self) -> None:
        if self.kind not in ("vanish", "hang"):
            raise ValueError(f"unknown failure kind {self.kind!r}")
        if self.host < 0:
            raise ValueError(f"host must be a batch-axis rank, "
                             f"got {self.host}")


class ServeSupervisor:
    """Wrap a scheduler factory with heartbeat monitoring and host-loss
    evacuation.

    `make_sched(ctx, pool)` builds a fresh scheduler (continuous or slo) on
    the given `ParallelContext`, binding `pool` as its prefix pool when not
    None — the supervisor calls it once up front and again after every
    rescale. `hosts` overrides the host count for null-mesh simulation
    (lane evacuation without any mesh: the scheduler rebuild stays on the
    same devices); on a mesh it defaults to the batch-axis rank count.
    """

    def __init__(self, make_sched: Callable[[ParallelContext,
                                             PagedKVPool | None],
                                            _SchedulerBase],
                 ctx: ParallelContext, *,
                 hosts: int | None = None,
                 deadline_steps: int = 3,
                 watchdog_deadline_s: float = 5.0,
                 policy: RestartPolicy | None = None,
                 injection: FailureInjection | None = None) -> None:
        self.make_sched = make_sched
        self.ctx = ctx
        self.n_hosts = hosts if hosts is not None else max(
            1, int(np.prod([ctx.axis_size(a) for a in ctx.batch_axes] or [1])))
        self.deadline_steps = deadline_steps
        self.watchdog = Watchdog(watchdog_deadline_s)
        self.straggler = StragglerDetector()
        self.policy = policy           # None -> fresh RestartPolicy per serve
        self.injection = injection
        self.sched = make_sched(ctx, None)
        self.rescales: list[RescalePlan] = []
        self.evacuated_rids: list[int] = []
        self.restarts = 0
        self._last_beat: dict[int, int] = {h: 0 for h in range(self.n_hosts)}
        self._t_prev = time.monotonic()
        # serve()-scoped request bookkeeping
        self._orig: dict[int, Request] = {}
        self._prefix: dict[int, list[int]] = {}
        self._done: dict[int, RequestResult] = {}
        self._pending: list[Request] = []

    # -- lane -> host placement ---------------------------------------------
    def host_of_lane(self, lane: int) -> int:
        """The batch rank holding lane `lane`: `serve_cache_specs` block-
        partitions the lane dim over the batch axes, so lanes map to hosts
        in contiguous blocks (all lanes to host 0 when indivisible — the
        cache then replicates and no lane state is host-exclusive)."""
        n_slots = getattr(self.sched, "n_slots", 1)
        if self.n_hosts <= 1 or n_slots % self.n_hosts != 0:
            return 0
        return lane // (n_slots // self.n_hosts)

    # -- the heartbeat hook --------------------------------------------------
    def _heartbeat_hook(self, sched: _SchedulerBase, step: int) -> None:
        inj = self.injection
        now = time.monotonic()
        wall = now - self._t_prev
        self._t_prev = now
        if inj is not None and inj.kind == "hang" and step >= inj.at_step:
            # a collective wedged on the dead peer: this tick overruns the
            # step deadline, and the watchdog turns the stall into a
            # supervisor-visible failure instead of an infinite hang
            time.sleep(self.watchdog.deadline_s * 1.25)
            if self.watchdog.expired():
                raise HostFailure(inj.host, "step deadline exceeded (hang)")
        self.watchdog.poke()
        for h in range(self.n_hosts):
            if inj is not None and inj.kind == "vanish" \
                    and h == inj.host and step >= inj.at_step:
                continue               # vanished: no heartbeat arrives
            self._last_beat[h] = step
            self.straggler.record(Heartbeat(host=h, step=step,
                                            wall_s=wall, t=now))
        for h in range(self.n_hosts):
            missed = step - self._last_beat[h]
            if missed >= self.deadline_steps:
                raise HostFailure(h, f"no heartbeat for {missed} steps")

    # -- serving --------------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[RequestResult]:
        """Run the request batch to completion, evacuating through however
        many host losses the restart policy allows."""
        self._orig = {r.rid: r for r in requests}
        self._prefix = {r.rid: [] for r in requests}
        self._done = {}
        self._pending = list(requests)
        self.sched.step_hook = self._heartbeat_hook
        self._t_prev = time.monotonic()
        self.watchdog.poke()

        def attempt(start_step: int) -> int:
            results = self.sched.run(list(self._pending))
            for r in results:
                self._finish(r)
            return len(self._done)

        def on_restart(restarts: int, err: BaseException) -> None:
            if not isinstance(err, HostFailure):
                raise err              # only host loss is evacuable
            self.restarts = restarts
            self._evacuate(err.host)

        run_with_restarts(attempt, policy=self.policy,
                          on_restart=on_restart)
        return sorted(self._done.values(), key=lambda r: r.rid)

    def _finish(self, r: RequestResult) -> None:
        """Stitch pre-evacuation tokens onto a (possibly resumed) result,
        reporting against the ORIGINAL request's prompt/budget."""
        base = self._orig.get(r.rid)
        pref = self._prefix.get(r.rid, [])
        toks = np.concatenate([np.asarray(pref, np.int32),
                               np.asarray(r.tokens, np.int32)]) \
            if pref else np.asarray(r.tokens, np.int32)
        budget = base.max_new_tokens if base is not None else toks.size
        plen = base.prompt.size if base is not None else r.prompt_len
        self._done[r.rid] = RequestResult(
            r.rid, plen, toks[:budget], bucket=r.bucket,
            admitted_step=r.admitted_step, finished_step=r.finished_step)

    # -- evacuation -----------------------------------------------------------
    def _evacuate(self, failed_host: int) -> None:
        sched = self.sched
        # 1. harvest requests the aborted run already finished (run() aliases
        #    its live lists, so they survive the unwind)
        for r in list(getattr(sched, "_results", [])):
            self._finish(r)
        # 2. snapshot every active lane's host-side state machine
        snaps = []
        for lane, slot in enumerate(getattr(sched, "slots", [])):
            if slot.active:
                snaps.append((slot.req, list(slot.generated),
                              self.host_of_lane(lane)))
        remainder = [r for r in getattr(sched, "_queue", [])
                     if r.rid not in self._done]
        # 3. the paged pool carries over: lane page tables die with the old
        #    engine (the rebuilt scheduler re-admits from scratch), resident
        #    blocks and anchors stay warm for prefix hits after the rescale
        pool = getattr(sched, "pool", None)
        if pool is not None:
            for owner in list(pool.owners()):
                pool.release(owner)
            pool.audit()
        # 4. rebuild resume requests: original prompt + everything generated
        #    so far re-enters the ordinary admission path; per-(rid, pos)
        #    sampling keys make the resumed stream token-exact
        resume: list[Request] = []
        for req, gen, host in snaps:
            base = self._orig.get(req.rid, req)
            pref = self._prefix.setdefault(req.rid, [])
            pref.extend(gen)
            remaining = base.max_new_tokens - len(pref)
            if host == failed_host:
                self.evacuated_rids.append(req.rid)
            if remaining <= 0:     # already had its full budget in hand
                self._done[req.rid] = RequestResult(
                    base.rid, base.prompt.size,
                    np.asarray(pref[:base.max_new_tokens], np.int32),
                    bucket=-1, admitted_step=-1, finished_step=-1)
                continue
            prompt = np.concatenate(
                [base.prompt, np.asarray(pref, np.int32)]) \
                if pref else base.prompt
            resume.append(Request(rid=base.rid, prompt=prompt,
                                  max_new_tokens=remaining,
                                  arrival=0, frames=base.frames))
        self._pending = sorted(resume + remainder,
                               key=lambda r: (r.arrival, r.rid))
        # 5. shrink the mesh: drop the failed batch rank's device column,
        #    keep the model axis (plan_rescale's invariant)
        new_ctx = self.ctx
        if self.ctx.active and self.n_hosts > 1:
            mesh = self.ctx.mesh
            msize = max(1, self.ctx.axis_size("model"))
            survivors = np.delete(mesh.devices.reshape(self.n_hosts, -1),
                                  failed_host, axis=0).reshape(-1)
            plan = plan_rescale(mesh.devices.size, survivors.size,
                                model_parallel=msize)
            new_mesh = build_mesh(plan, devices=survivors)
            new_ctx = dataclasses.replace(self.ctx, mesh=new_mesh)
            self.rescales.append(plan)
            self.ctx = new_ctx
            self.n_hosts -= 1
        elif self.n_hosts > 1:
            self.n_hosts -= 1      # null-mesh simulation: just fewer hosts
        else:
            raise TrainingAborted("no surviving hosts to evacuate onto")
        # 6. the rebuilt engine: make_sched re-places params/caches through
        #    the scheduler's own mesh placement (device_put IS the reshard);
        #    hosts renumber 0..n-1 on the new mesh, the injection is spent
        self.injection = None
        self._last_beat = {h: 0 for h in range(self.n_hosts)}
        self.sched = self.make_sched(new_ctx, pool)
        self.sched.step_hook = self._heartbeat_hook
        self._t_prev = time.monotonic()
        self.watchdog.poke()

    # -- reporting ------------------------------------------------------------
    def stats(self, n_requests: int) -> dict:
        out = self.sched.stats(n_requests)
        out.update({
            "restarts": self.restarts,
            "rescales": [dataclasses.asdict(p) for p in self.rescales],
            "evacuated_rids": list(self.evacuated_rids),
            "stragglers": self.straggler.evaluate(),
            "n_hosts_now": self.n_hosts,
        })
        return out
