"""Checkpointing: atomic, async, shard-aware, elastic.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json        # flat-key -> {shape, dtype, file}
        arrays.npz           # the leaves (this host's addressable shards)
        COMMIT               # written last: a checkpoint without it is torn

Properties the tests exercise:
  * atomicity: a crash mid-write never yields a loadable-but-wrong state
    (restore only considers COMMITted steps);
  * weight-form tags: a `models.dispatched.DispatchedWeight` node (packed
    weight + `WeightForm` tag) flattens into its payload arrays plus a
    `__weightform__` marker, and restores as the same tagged node — a
    compressed-serving checkpoint round-trips without folding to dense;
  * async: `save_async` snapshots device arrays to host, then writes on a
    background thread while training continues (the paper's resident-state
    rule inverted: state crosses the host boundary only at checkpoints);
  * elastic restore: leaves are loaded as full arrays and re-placed with
    whatever sharding the *new* mesh prescribes, so a job can resume on a
    different pod count (`runtime/elastic.py` plans the rescale).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np

from repro.models.dispatched import DispatchedWeight

_SEP = "/"
_FORM_KEY = "__weightform__"


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def walk(node, prefix):
        if isinstance(node, DispatchedWeight):
            # payload arrays under the node's path + the form tag marker
            flat[f"{prefix}{_SEP}{_FORM_KEY}"] = np.asarray(node.form.value)
            walk(node.payload, prefix)
        elif isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], f"{prefix}{_SEP}{k}" if prefix else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{prefix}{_SEP}{i}")
        elif node is None:
            flat[prefix] = None
        else:
            flat[prefix] = node

    walk(tree, "")
    return flat


def _unflatten_into(template, flat: dict[str, Any]):
    def walk(node, prefix):
        if isinstance(node, DispatchedWeight):
            stored = flat.get(f"{prefix}{_SEP}{_FORM_KEY}")
            if stored is None:
                raise ValueError(
                    f"checkpoint weight form mismatch at {prefix!r}: template "
                    f"expects a packed {node.form.value!r} weight but the "
                    f"checkpoint holds a dense one (no {_FORM_KEY} marker)")
            if str(stored) != node.form.value:
                raise ValueError(
                    f"checkpoint weight form {str(stored)!r} at {prefix!r} "
                    f"does not match template tag {node.form.value!r}")
            payload = {k: flat[f"{prefix}{_SEP}{k}"] for k in node.payload}
            return DispatchedWeight(node.form, node.contract_shape,
                                    node.out_shape, node.dtype_name, payload)
        if isinstance(node, dict):
            return {k: walk(node[k], f"{prefix}{_SEP}{k}" if prefix else str(k))
                    for k in node}
        if isinstance(node, (list, tuple)):
            out = [walk(v, f"{prefix}{_SEP}{i}") for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        if node is None:
            return None
        return flat[prefix]
    return walk(template, "")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, metadata: dict | None = None) -> None:
        """Synchronous atomic save. `metadata` is a small JSON-serializable
        dict written alongside the arrays (inside the atomic step dir, so it
        commits with them) — provenance a consumer can validate against
        before loading, e.g. the drafter checkpoints record arch/vocab/
        d_model/weight form and `Drafter.shrink` rejects mismatches loud."""
        snapshot = jax.tree.map(
            lambda x: np.asarray(x) if x is not None else None, tree,
            is_leaf=lambda x: x is None)
        self._write(step, snapshot, metadata)

    def save_async(self, step: int, tree,
                   metadata: dict | None = None) -> None:
        """Snapshot to host now; write on a background thread."""
        self.wait()
        snapshot = jax.tree.map(
            lambda x: np.asarray(x) if x is not None else None, tree,
            is_leaf=lambda x: x is None)
        self._thread = threading.Thread(
            target=self._write, args=(step, snapshot, metadata), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, snapshot,
               metadata: dict | None = None) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(snapshot)
        arrays = {k: v for k, v in flat.items() if v is not None}
        manifest = {k: (None if v is None else
                        {"shape": list(v.shape), "dtype": str(v.dtype)})
                    for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace(_SEP, "|"): v for k, v in arrays.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if metadata is not None:
            with open(os.path.join(tmp, "metadata.json"), "w") as f:
                json.dump(metadata, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write("ok")
        self._gc()

    def metadata(self, step: int | None = None) -> dict | None:
        """The metadata dict saved with `step` (default: latest committed),
        or None when the checkpoint carries none."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self._step_dir(step), "metadata.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template, step: int | None = None,
                placer: Callable[[str, np.ndarray], Any] | None = None):
        """Restore into the structure of `template`. `placer(path, array)`
        lets the caller device_put with the new mesh's sharding (elastic
        restore); default leaves numpy arrays for jnp to consume."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        npz = np.load(os.path.join(d, "arrays.npz"))
        flat: dict[str, Any] = {}
        for k, meta in manifest.items():
            if meta is None:
                flat[k] = None
                continue
            arr = npz[k.replace(_SEP, "|")]
            if k.endswith(f"{_SEP}{_FORM_KEY}"):
                # weight-form marker: a host-side string tag, never a device
                # array — elastic placers must not see it
                flat[k] = arr
                continue
            flat[k] = placer(k, arr) if placer else arr
        return _unflatten_into(template, flat), step
