"""Block-paged KV pool with cross-request prefix sharing (paper §9).

The dispatch-floor model says every command the engine executes pays a fixed
~t0 regardless of useful work, so the cheapest prefill is the one that never
dispatches: chat-shaped traffic (shared system prompts, few-shot templates,
multi-turn) re-computes identical prefixes from token 0 on every admission.
This module turns the per-lane monolithic cache slab into a shared,
block-paged pool so a resident prefix is *reused* instead of re-prefilled:

  * **block arena** — a fixed set of `n_blocks` rows per paged cache leaf,
    each row holding `block_size` consecutive token positions of one
    sequence's KV state (`(n_blocks, stack, block_size, ...)` per leaf).
  * **prefix trie on token-block hashes** — block k of a prompt is keyed by
    ``sha256(parent_key || tokens[k*bs:(k+1)*bs])``, so a key identifies the
    *entire* prefix up to its block, not just the block's own tokens (KV at
    position p depends on every token <= p). Matching a prompt walks the
    chain; the trie is the set of resident chains.
  * **per-lane page tables** — an owner (decode lane / request) holds an
    ordered list of chain keys; `acquire`/`release` move block refcounts.
  * **refcounts + copy-on-write** — a block's refcount is its lane
    references plus its resident children. `write` diverges an owner's
    chain at a block: shared blocks are copied to a fresh arena row, never
    mutated in place.
  * **LRU eviction** — refcount-0 blocks stay resident (that is the cache)
    on an LRU list; allocation evicts the oldest only when the free list is
    empty. A referenced block is never evicted or reallocated.
  * **anchors** — resuming decode at position M needs more than the KV
    rows: recurrent state (SSM/RG-LRU), conv tails and ring-buffer window
    leaves do not decompose into position blocks. The final block of each
    inserted prefill chain therefore carries an *anchor*: a snapshot of
    every non-paged cache leaf at exactly that boundary. A prefix hit lands
    on the longest matched chain that ends at an anchor, so the assembled
    lane state is complete for every architecture in the registry —
    attention, MLA, hybrid SSM and ring-window alike.

Cross-prefill sharing is bit-safe: KV at position p is a deterministic
function of tokens[0..p] only (causal masking), so a block produced by a
bucket-8 prefill is bit-identical to the same positions of a bucket-16
prefill of the same prefix — the serve-scheduler parity suite locks this.

The scheduler composes the pool with its admission machinery
(`launch/scheduler.py`): a hit replaces the prefill + admit dispatches with
one gather-and-merge dispatch; the matched blocks' prefill work is skipped
entirely. The decode-side read path for an arena-resident lane is
`kernels/flash/decode_attention.paged_decode_attention`, conformance-swept
against its oracle via the registry.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import compat

# Cache leaves with a KV time axis, merged/paged by name: the single axis on
# which a prefill cache may be shorter than the decode buffer. Everything
# else (recurrent SSM/RG-LRU state, conv tails) must match exactly or fail
# loud. (Historically defined in launch/scheduler.py, which re-exports it.)
TIME_MERGE_LEAVES = frozenset({"k", "v", "pos", "c_kv", "k_rope"})

#: time axis of stacked serving cache leaves: (stack, batch, time, ...)
_TIME_AXIS = 2


def _leaf_name(loc: str) -> str:
    return loc.rsplit("/", 1)[-1]


@dataclasses.dataclass
class _Block:
    """One resident block: a trie node plus its arena row."""

    key: str                      # chain hash (identifies the whole prefix)
    parent: str | None
    bid: int                      # arena row
    tokens: np.ndarray            # this block's own tokens (audit/debug)
    lane_refs: int = 0            # owners whose page table includes this key
    children: int = 0             # resident child nodes
    anchored: bool = False        # a prefill ended exactly at this boundary
    anchor: dict | None = None    # non-paged leaf snapshot at the boundary
    depth: int = 1                # chain length in blocks, this one included

    @property
    def refcount(self) -> int:
        return self.lane_refs + self.children


class PagedKVPool:
    """Fixed-size block arena + prefix trie + per-lane page tables.

    The metadata layer (match/acquire/release/fork/write/insert and the
    refcount/LRU bookkeeping) runs host-side and is payload-agnostic — the
    hypothesis suite in tests/test_kv_pool.py drives it unbound. `bind`
    attaches the device arenas for a concrete cache pytree; the traceable
    helpers (`insert_blocks`, `assemble_prefix`) then run *inside* the
    scheduler's jitted admission programs, so pool traffic is dispatched —
    and floor-charged — through the ExecutionStream like everything else.
    """

    def __init__(self, n_blocks: int, block_size: int, *,
                 evict_cost_fn=None) -> None:
        if n_blocks < 1:
            raise ValueError(f"pool needs n_blocks >= 1, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"pool needs block_size >= 1, got {block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # Costmodel-aware eviction: `evict_cost_fn(n_tokens) -> float` is
        # the modeled cost of re-prefilling a `n_tokens`-deep prefix (the
        # scheduler wires its §9 floor+work estimate in). When set, the
        # eviction victim is the refcount-0 block whose chain is cheapest
        # to rebuild, not merely the least-recently-used; only leaves of
        # the resident trie are ever refcount-0, so this preferentially
        # keeps the deep (expensive) chains hot. None keeps plain LRU.
        self.evict_cost_fn = evict_cost_fn
        self._free: list[int] = list(range(n_blocks - 1, -1, -1))
        self._nodes: dict[str, _Block] = {}
        self._lru: OrderedDict[str, None] = OrderedDict()
        self._tables: dict[Any, list[str]] = {}
        self.stats: dict[str, int] = {
            "hits": 0, "misses": 0, "hit_tokens": 0, "inserted_blocks": 0,
            "evictions": 0, "cow_copies": 0,
        }
        # device side (None until bind)
        self.arenas: dict[str, jnp.ndarray] | None = None
        self._paged_paths: set[str] = set()
        self._anchor_paths: set[str] = set()
        self._leaf_paths: list[str] = []

    # -- chain hashing ------------------------------------------------------
    def _key(self, parent: str | None, block_tokens: np.ndarray) -> str:
        h = hashlib.sha256()
        h.update(b"root" if parent is None else parent.encode())
        h.update(np.asarray(block_tokens, np.int32).tobytes())
        return h.hexdigest()

    def _blocks_of(self, tokens) -> list[np.ndarray]:
        t = np.asarray(tokens, np.int32).reshape(-1)
        bs = self.block_size
        return [t[i * bs:(i + 1) * bs] for i in range(t.size // bs)]

    # -- refcounts / LRU ----------------------------------------------------
    def _ref(self, key: str) -> None:
        node = self._nodes[key]
        node.lane_refs += 1
        self._lru.pop(key, None)

    def _unref(self, key: str) -> bool:
        """Drop one lane reference; True when the block became free
        (refcount 0, parked on the LRU list but still resident)."""
        node = self._nodes[key]
        if node.lane_refs <= 0:
            raise AssertionError(f"block {key[:8]}: unref below zero")
        node.lane_refs -= 1
        if node.refcount == 0:
            self._lru[key] = None
            return True
        return False

    def _evict_victim(self) -> _Block | None:
        """The refcount-0 block the next allocation evicts: the LRU-oldest
        by default, or — with `evict_cost_fn` set — the one whose chain's
        re-prefill cost (`cost_fn(depth * block_size)`) is cheapest, LRU
        order breaking ties. Stale LRU entries are pruned either way."""
        best: _Block | None = None
        best_cost = 0.0
        for key in list(self._lru):
            node = self._nodes.get(key)
            if node is None or node.refcount:
                self._lru.pop(key, None)    # stale entry
                continue
            if self.evict_cost_fn is None:
                return node                 # oldest valid = plain LRU
            cost = float(self.evict_cost_fn(node.depth * self.block_size))
            if best is None or cost < best_cost:
                best, best_cost = node, cost
        return best

    def _alloc_bid(self) -> int | None:
        """A free arena row, evicting a refcount-0 block if needed (see
        `_evict_victim` for the policy). None when every block is
        referenced (pool full, caller skips)."""
        if self._free:
            return self._free.pop()
        victim = self._evict_victim()
        if victim is None:
            return None
        self._lru.pop(victim.key, None)
        self._evict(victim)
        return self._free.pop()

    def _evict(self, node: _Block) -> None:
        del self._nodes[node.key]
        self._free.append(node.bid)
        self.stats["evictions"] += 1
        if node.parent is not None:
            parent = self._nodes.get(node.parent)
            if parent is not None:
                parent.children -= 1
                if parent.refcount == 0:
                    self._lru[parent.key] = None

    # -- trie matching ------------------------------------------------------
    def match(self, tokens) -> list[str]:
        """Chain keys of the longest resident whole-block prefix."""
        keys: list[str] = []
        parent = None
        for blk in self._blocks_of(tokens):
            key = self._key(parent, blk)
            if key not in self._nodes:
                break
            keys.append(key)
            parent = key
        return keys

    def anchored_match(self, tokens, *, limit: int | None = None) -> list[str]:
        """Longest resident chain that ends at an *anchored* boundary,
        covering at most `limit` tokens — the prefix a lane can actually
        resume from (the anchor carries the non-paged state at M)."""
        keys = self.match(tokens)
        if limit is not None:
            keys = keys[: max(limit, 0) // self.block_size]
        while keys and not self._nodes[keys[-1]].anchored:
            keys.pop()
        return keys

    # -- page tables --------------------------------------------------------
    def acquire(self, owner, keys: list[str]) -> int:
        """Reference a matched chain as `owner`'s page table. Returns the
        token length covered."""
        if owner in self._tables:
            raise ValueError(f"pool owner {owner!r} already holds a table")
        for key in keys:
            if key not in self._nodes:
                raise KeyError(f"block {key[:8]} not resident")
        for key in keys:
            self._ref(key)
        self._tables[owner] = list(keys)
        return len(keys) * self.block_size

    def release(self, owner) -> list[str]:
        """Drop `owner`'s page table. Returns exactly the keys that became
        free (refcount 0) — the blocks the lane exclusively owned."""
        keys = self._tables.pop(owner, [])
        return [k for k in keys if self._unref(k)]

    def fork(self, owner, new_owner) -> None:
        """Share `owner`'s page table with `new_owner` (both reference every
        block; divergence later goes through `write`'s copy-on-write)."""
        if new_owner in self._tables:
            raise ValueError(f"pool owner {new_owner!r} already holds a table")
        keys = list(self._tables[owner])
        for key in keys:
            self._ref(key)
        self._tables[new_owner] = keys

    def write(self, owner, idx: int, block_tokens) -> str | None:
        """Diverge `owner`'s chain at block `idx` with new content: the
        copy-on-write point. A block shared with anyone else (other lane
        refs, or resident children) is never mutated or aliased — the new
        content lands on a fresh arena row under its own chain key, and the
        owner's stale suffix is released. Returns the new key, or None when
        the pool is full."""
        table = self._tables[owner]
        if not 0 <= idx < len(table):
            raise IndexError(f"owner {owner!r} has {len(table)} blocks, "
                             f"cannot write block {idx}")
        block_tokens = np.asarray(block_tokens, np.int32).reshape(-1)
        if block_tokens.size != self.block_size:
            raise ValueError(f"write wants exactly one block "
                             f"({self.block_size} tokens), "
                             f"got {block_tokens.size}")
        old = self._nodes[table[idx]]
        parent = table[idx - 1] if idx else None
        new_key = self._key(parent, block_tokens)
        if new_key == old.key:
            # content-identical write: the chain already says this
            for key in table[idx + 1:]:
                self._unref(key)
            self._tables[owner] = table[: idx + 1]
            return new_key
        old_bid = old.bid
        shared = old.lane_refs > 1 or old.children > 0
        for key in table[idx:]:
            self._unref(key)
        node = self._nodes.get(new_key)
        if node is None:
            bid = self._alloc_bid()
            if bid is None:
                self._tables[owner] = table[:idx]
                return None
            if shared and bid == old_bid:
                raise AssertionError(
                    f"copy-on-write aliased shared block {old.key[:8]}")
            node = _Block(key=new_key, parent=parent, bid=bid,
                          tokens=block_tokens.copy(),
                          depth=(self._nodes[parent].depth + 1
                                 if parent is not None else 1))
            self._nodes[new_key] = node
            if parent is not None:
                pnode = self._nodes[parent]
                pnode.children += 1
                self._lru.pop(parent, None)
            self.stats["cow_copies"] += 1
            if self.arenas is not None:
                # divergence copies the old row's payload to the new row;
                # the caller overwrites the diverged positions afterwards
                self.arenas = {loc: ar.at[bid].set(ar[old_bid])
                               for loc, ar in self.arenas.items()}
        self._ref(node.key)
        self._tables[owner] = table[:idx] + [node.key]
        return node.key

    # -- insertion (the cold path) ------------------------------------------
    def reserve(self, tokens) -> tuple[list[str], list[int], int]:
        """Metadata insert for a prompt prefix: walk/extend the chain for
        every whole block of `tokens`, allocating arena rows for the blocks
        not already resident. Returns (chain keys, new bids, first new block
        index). Stops early when the pool is full — a partial chain is still
        shareable, it just cannot anchor."""
        keys: list[str] = []
        new_bids: list[int] = []
        first_new = -1
        parent = None
        for i, blk in enumerate(self._blocks_of(tokens)):
            key = self._key(parent, blk)
            node = self._nodes.get(key)
            if node is None:
                # take the parent's child reference BEFORE allocating: the
                # allocation may evict, and the chain built so far (fresh
                # refcount-0 blocks included) must not be eviction fodder
                if parent is not None:
                    pnode = self._nodes[parent]
                    pnode.children += 1
                    self._lru.pop(parent, None)
                bid = self._alloc_bid()
                if bid is None:
                    if parent is not None:
                        pnode.children -= 1
                        if pnode.refcount == 0:
                            self._lru[pnode.key] = None
                    break
                node = _Block(key=key, parent=parent, bid=bid,
                              tokens=blk.copy(), depth=i + 1)
                self._nodes[key] = node
                self._lru[key] = None      # refcount 0: resident, evictable
                self.stats["inserted_blocks"] += 1
                new_bids.append(bid)
                if first_new < 0:
                    first_new = i
            keys.append(key)
            parent = key
        return keys, new_bids, first_new

    def set_anchor(self, key: str, anchor: dict | None) -> None:
        """Mark `key`'s boundary as resumable, attaching the non-paged leaf
        snapshot taken at exactly that prefix length."""
        node = self._nodes[key]
        node.anchored = True
        node.anchor = anchor

    def anchor_of(self, key: str) -> dict | None:
        return self._nodes[key].anchor

    def bids_of(self, keys: list[str]) -> list[int]:
        return [self._nodes[k].bid for k in keys]

    # -- introspection (tests / audit) --------------------------------------
    def refcount(self, key: str) -> int:
        return self._nodes[key].refcount

    def resident(self) -> set[str]:
        return set(self._nodes)

    def free_blocks(self) -> int:
        return len(self._free)

    def table(self, owner) -> list[str]:
        return list(self._tables.get(owner, []))

    def owners(self) -> set:
        return set(self._tables)

    def audit(self) -> None:
        """Check every structural invariant; raises AssertionError with the
        first violation. The hypothesis suite calls this after every op."""
        lane_refs: dict[str, int] = {}
        for owner, keys in self._tables.items():
            parent = None
            for key in keys:
                node = self._nodes.get(key)
                assert node is not None, \
                    f"owner {owner!r} references evicted block {key[:8]}"
                assert node.parent == parent, \
                    f"owner {owner!r} table breaks the chain at {key[:8]}"
                lane_refs[key] = lane_refs.get(key, 0) + 1
                parent = key
        children: dict[str, int] = {}
        for node in self._nodes.values():
            if node.parent is not None:
                assert node.parent in self._nodes, \
                    f"block {node.key[:8]} orphaned (parent evicted)"
                children[node.parent] = children.get(node.parent, 0) + 1
        for node in self._nodes.values():
            assert node.lane_refs == lane_refs.get(node.key, 0), \
                (f"block {node.key[:8]}: lane_refs {node.lane_refs} != live "
                 f"page-table references {lane_refs.get(node.key, 0)}")
            assert node.children == children.get(node.key, 0), \
                (f"block {node.key[:8]}: children {node.children} != "
                 f"resident child count {children.get(node.key, 0)}")
        bids = [n.bid for n in self._nodes.values()]
        assert len(bids) == len(set(bids)), "two resident blocks share a row"
        assert not set(bids) & set(self._free), \
            "a resident block's row is on the free list"
        assert len(bids) + len(self._free) == self.n_blocks, \
            "arena rows leaked"
        for key in self._lru:
            node = self._nodes.get(key)
            assert node is None or node.refcount == 0, \
                f"referenced block {key[:8]} is on the eviction list"
        for node in self._nodes.values():
            if node.refcount == 0:
                assert node.key in self._lru, \
                    f"free block {node.key[:8]} missing from the LRU list"
        for node in self._nodes.values():
            want = 1 if node.parent is None \
                else self._nodes[node.parent].depth + 1
            assert node.depth == want, \
                (f"block {node.key[:8]}: depth {node.depth} != chain "
                 f"length {want}")

    # -- device arenas ------------------------------------------------------
    def bind(self, dec_caches, *, max_len: int) -> None:
        """Attach arenas for a concrete decode-cache pytree. A leaf pages
        iff it is a named KV-time leaf whose time extent equals `max_len` —
        ring-buffer window leaves (extent = window < max_len) wrap by
        position and do not decompose into blocks, so they ride the anchor
        instead, as does every recurrent/conv leaf."""
        if self.arenas is not None:
            return
        leaves, _ = compat.tree_flatten_with_path(dec_caches)
        arenas: dict[str, jnp.ndarray] = {}
        anchor_paths: set[str] = set()
        paths: list[str] = []
        for path, leaf in leaves:
            loc = compat.tree_path_str(path)
            paths.append(loc)
            if (_leaf_name(loc) in TIME_MERGE_LEAVES
                    and leaf.ndim > _TIME_AXIS
                    and leaf.shape[_TIME_AXIS] == max_len):
                row_shape = ((self.n_blocks, leaf.shape[0], self.block_size)
                             + leaf.shape[_TIME_AXIS + 1:])
                arenas[loc] = jnp.zeros(row_shape, leaf.dtype)
            else:
                anchor_paths.add(loc)
        self.arenas = arenas
        self._paged_paths = set(arenas)
        self._anchor_paths = anchor_paths
        self._leaf_paths = paths

    def validate_prefill(self, pf_caches, n_tokens: int, *,
                         staging: bool = False) -> None:
        """Loud-failure gate before arena writes: every paged leaf of a
        prefill cache must be batch-1, rank-matched and exactly `n_tokens`
        long on the time axis; any page-table/arena mismatch raises with the
        tree path rather than silently caching truncated state.

        `staging=True` relaxes the time-extent check to >= `n_tokens`: a
        chunked-prefill staging cache is decode-shaped (time extent =
        max_len) but only valid through the chunk boundary `n_tokens`, and
        `insert_blocks` slices exactly the whole-block prefix — the
        unwritten tail past `n_tokens` is never read."""
        leaves, _ = compat.tree_flatten_with_path(pf_caches)
        seen = []
        for path, leaf in leaves:
            loc = compat.tree_path_str(path)
            seen.append(loc)
            if loc not in self._paged_paths:
                continue
            arena = self.arenas[loc]
            # same rank: the arena drops the batch axis but adds the block
            # axis ((n_blocks, stack, bs, ...) vs (stack, 1, T, ...))
            if leaf.ndim != arena.ndim:
                raise ValueError(
                    f"cache leaf {loc!r}: prefill rank {leaf.ndim} "
                    f"{leaf.shape} cannot page into arena rank "
                    f"{arena.ndim} {arena.shape}")
            if leaf.shape[1] != 1:
                raise ValueError(
                    f"cache leaf {loc!r}: pool insert wants a batch-1 "
                    f"prefill cache, got batch {leaf.shape[1]}")
            extent = leaf.shape[_TIME_AXIS]
            if (extent < n_tokens) if staging else (extent != n_tokens):
                raise ValueError(
                    f"cache leaf {loc!r}: prefill time extent "
                    f"{extent} {'<' if staging else '!='} inserted prefix "
                    f"{n_tokens}; off-axis state would be dropped")
            if leaf.shape[_TIME_AXIS + 1:] != arena.shape[_TIME_AXIS + 1:]:
                raise ValueError(
                    f"cache leaf {loc!r}: prefill tail {leaf.shape} does "
                    f"not match arena row {arena.shape}")
        if set(seen) != set(self._leaf_paths):
            missing = set(self._leaf_paths) ^ set(seen)
            raise ValueError(
                f"prefill cache structure diverges from the bound decode "
                f"cache at {sorted(missing)}")

    def anchor_leaves(self, pf_caches) -> dict[str, jnp.ndarray]:
        """Snapshot every non-paged leaf of a prefill cache (recurrent
        state, conv tails, ring-window KV) — the anchor payload."""
        leaves, _ = compat.tree_flatten_with_path(pf_caches)
        return {compat.tree_path_str(p): leaf for p, leaf in leaves
                if compat.tree_path_str(p) in self._anchor_paths}

    # -- traceable bodies (run inside the scheduler's jitted dispatches) ----
    def insert_blocks(self, arenas, pf_caches, bids, start: int):
        """Write blocks [start, start+len(bids)) of a prefill cache into the
        arena rows `bids`. Traceable; `start` must be static."""
        leaves, _ = compat.tree_flatten_with_path(pf_caches)
        bs = self.block_size
        m = bids.shape[0]
        out = dict(arenas)
        for path, leaf in leaves:
            loc = compat.tree_path_str(path)
            if loc not in self._paged_paths:
                continue
            row = leaf[:, 0]                       # (stack, T, ...)
            sl = jax.lax.dynamic_slice_in_dim(row, start * bs, m * bs, axis=1)
            sl = sl.reshape((row.shape[0], m, bs) + row.shape[2:])
            sl = jnp.moveaxis(sl, 1, 0)            # (m, stack, bs, ...)
            out[loc] = arenas[loc].at[bids].set(sl.astype(arenas[loc].dtype))
        return out

    def assemble_prefix(self, dec_caches, arenas, bids, anchor):
        """Gather `bids` through the page table into a batch-1 prefill-like
        pytree (paged leaves from the arena, the rest from the anchor) with
        the decode cache's structure, ready for `_admit_into_slot_impl`.
        Traceable: this *is* the prefix-hit admission body."""
        leaves, treedef = compat.tree_flatten_with_path(dec_caches)
        bs = self.block_size
        m = bids.shape[0]
        out = []
        for path, _leaf in leaves:
            loc = compat.tree_path_str(path)
            if loc in self._paged_paths:
                g = jnp.take(arenas[loc], bids, axis=0)  # (m, stack, bs, ...)
                g = jnp.moveaxis(g, 0, 1)                # (stack, m, bs, ...)
                g = g.reshape((g.shape[0], m * bs) + g.shape[3:])
                out.append(g[:, None])                   # (stack, 1, M, ...)
            else:
                if loc not in anchor:
                    raise ValueError(
                        f"cache leaf {loc!r}: prefix anchor is missing the "
                        f"non-paged leaf; lane state would be dropped")
                out.append(anchor[loc])
        return jax.tree_util.tree_unflatten(treedef, out)
