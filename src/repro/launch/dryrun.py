import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-XLA hoists loop-invariant converts of the whole residual stack out
    # of the backward while-loop (doubling temp memory with an fp32 copy a
    # real TPU toolchain would never materialize) — disable that pass so
    # memory_analysis reflects the per-step working set:
    "--xla_disable_hlo_passes=while-loop-expensive-invariant-code-motion "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all

This is how the distribution config is proven coherent without hardware:
`.lower().compile()` against ShapeDtypeStruct stand-ins (no allocation) on
the 16x16 production mesh and the 2x16x16 multi-pod mesh. A sharding
mismatch, compile-time OOM, or unsupported collective here is a bug in the
framework, not an environment problem.

Per cell it records: memory_analysis (fits?), cost_analysis (FLOPs/bytes),
the parsed collective bytes, and the three roofline terms (§Roofline of
EXPERIMENTS.md reads these JSONs). It is also the paper's confirm-op rule
(§4.6) applied at system scale: only the compile on the real target counts.

`--all` fans cells out to subprocesses (one compile per process: isolates
failures, frees memory between cells).
"""

import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import analytic, costmodel, hal, roofline
from repro.models.model import build_model
from repro.optim import adamw
from repro.parallel import sharding as shard_lib
from repro.parallel.ctx import ParallelContext
from repro.launch.mesh import make_production_mesh

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _named(specs, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if s is not None else None, specs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec) or s is None)


def _with_sharding(sds_tree, shard_tree):
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)
        if sh is not None else sds,
        sds_tree, shard_tree)


def parse_overrides(text: str) -> dict:
    """'seq_shard=True,remat=dots,moe_capacity_factor=1.0' -> kwargs."""
    out = {}
    if not text:
        return out
    for part in text.split(","):
        k, v = part.split("=")
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def build_cell(arch: str, shape_name: str, mesh_kind: str,
               *, overrides: str = ""):
    """Construct (fn, arg_specs, donate) for one cell — not yet lowered."""
    cfg = configs.get_config(arch)
    ov = parse_overrides(overrides)
    if ov:
        cfg = dataclasses.replace(cfg, **ov)
    shape = configs.SHAPES[shape_name]
    runs, why = configs.cell_runs(cfg, shape)
    if not runs:
        return None, why
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    ctx = ParallelContext(mesh=mesh)
    model = build_model(cfg, ctx)

    pspecs_tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shard_lib.param_specs(pspecs_tree, ctx)
    params_sds = _with_sharding(pspecs_tree, _named(pspecs, mesh))

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
        opt_tree = jax.eval_shape(
            functools.partial(adamw.init_state, opt_cfg), pspecs_tree)
        ospecs = shard_lib.opt_state_specs(opt_tree, pspecs, ctx, zero1=True)
        opt_sds = _with_sharding(opt_tree, _named(ospecs, mesh))
        batch_tree = model.input_specs(shape)
        bspecs = shard_lib.batch_specs(batch_tree, ctx)
        batch_sds = _with_sharding(batch_tree, _named(bspecs, mesh))

        from repro.launch.train import make_train_step
        step = make_train_step(model, opt_cfg)
        fn = jax.jit(step, donate_argnums=(0, 1),
                     out_shardings=(_named(pspecs, mesh),
                                    _named(ospecs, mesh), None))
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        batch_tree = model.input_specs(shape)
        bspecs = shard_lib.batch_specs(batch_tree, ctx)
        batch_sds = _with_sharding(batch_tree, _named(bspecs, mesh))
        fn = jax.jit(model.prefill)
        args = (params_sds, batch_sds)
    else:  # decode
        specs = model.input_specs(shape)
        cspecs = shard_lib.cache_specs(specs["caches"], ctx,
                                       seq_fallback=cfg.shard_cache_seq)
        cache_sds = _with_sharding(specs["caches"], _named(cspecs, mesh))
        tok_sds = _with_sharding(
            {"t": specs["token"], "p": specs["pos"]},
            _named(shard_lib.batch_specs(
                {"t": specs["token"], "p": specs["pos"]}, ctx), mesh))
        fn = jax.jit(model.decode_step, donate_argnums=(1,),
                     out_shardings=(_named(cspecs, mesh), None))
        args = (params_sds, cache_sds, tok_sds["t"], tok_sds["p"])
    return (fn, args, cfg, shape, mesh, ctx), ""


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: str = "") -> dict:
    t0 = time.perf_counter()
    built, why = build_cell(arch, shape_name, mesh_kind, overrides=overrides)
    if built is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "SKIP", "reason": why}
    fn, args, cfg, shape, mesh, ctx = built
    chips = mesh.devices.size

    lowered = fn.lower(*args)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    mf = costmodel.model_flops(cfg, shape)
    report = roofline.analyze(
        arch=arch, shape=shape_name, mesh_name=mesh_kind, chips=chips,
        cost_analysis=cost, hlo_text=hlo, memory_analysis=mem,
        model_flops=mf, target=hal.TPU_V5E)
    # analytic terms (primary magnitudes: XLA counts while-loop bodies once)
    terms = analytic.analyze_cell(cfg, shape, analytic.mesh_of(mesh_kind),
                                  seq_parallel_residuals=cfg.seq_shard)
    tsec = terms.seconds(hal.TPU_V5E)

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "overrides": overrides, "status": "OK", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
        },
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "roofline": report.row(),
        "analytic": {
            "flops_per_chip": terms.flops_per_chip,
            "hbm_bytes_per_chip": terms.hbm_bytes_per_chip,
            "coll_bytes_per_chip": terms.coll_bytes_per_chip,
            **{k: round(v, 6) for k, v in tsec.items()},
            "dominant": terms.dominant(hal.TPU_V5E),
            "detail": {k: float(v) for k, v in terms.detail.items()},
        },
        "collectives": dict(report.collectives),
        "model_flops": mf,
        "params_total": costmodel.param_count(cfg),
        "params_active": costmodel.active_param_count(cfg),
        "hlo_lines": hlo.count("\n"),
    }
    return out


def cell_list() -> list[tuple[str, str, str]]:
    cells = []
    for arch in configs.ARCH_NAMES:
        for shape in configs.SHAPES:
            for mesh in ("pod", "multipod"):
                cells.append((arch, shape, mesh))
    return cells


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(configs.SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--override", default="",
                    help="cfg overrides, e.g. seq_shard=True,remat=dots")
    ap.add_argument("--tag", default="", help="suffix for the report file")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=REPORT_DIR)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have a report")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = 0
        for arch, shape, mesh in cell_list():
            tag = f"{arch}__{shape}__{mesh}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"cached  {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", args.out]
            t0 = time.perf_counter()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ,
                                    "PYTHONPATH": os.environ.get("PYTHONPATH", "src")})
            dt = time.perf_counter() - t0
            if r.returncode == 0:
                print(f"ok      {tag}  ({dt:.0f}s)")
            else:
                failures += 1
                print(f"FAIL    {tag}  ({dt:.0f}s)\n{r.stderr[-2000:]}")
        return failures

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    result = run_cell(args.arch, args.shape, args.mesh, args.override)
    tag = f"{args.arch}__{args.shape}__{args.mesh}"
    if args.tag:
        tag += f"__{args.tag}"
    path = os.path.join(args.out, tag + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    status = result["status"]
    print(f"{status}: {tag}")
    if status == "OK":
        r = result["roofline"]
        print(f"  chips={result['chips']} compile={result['compile_s']}s "
              f"hlo_flops/chip={r['hlo_flops_per_chip']:.3e} "
              f"bytes/chip={r['hlo_bytes_per_chip']:.3e} "
              f"coll/chip={r['coll_bytes_per_chip']:.3e}")
        print(f"  artifact terms: compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dominant={r['dominant']}")
        a = result["analytic"]
        print(f"  analytic terms: compute={a['compute_s']:.4f}s memory={a['memory_s']:.4f}s "
              f"collective={a['collective_s']:.4f}s dominant={a['dominant']}")
        print(f"  peak_mem={r['peak_mem_gb']:.2f} GB/chip "
              f"useful_ratio={r['useful_ratio']:.3f} "
              f"roofline_fraction={r['roofline_fraction']:.3f}")
    elif status == "SKIP":
        print(f"  reason: {result['reason']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
