"""Continuous-batching request scheduler on `ExecutionStream` (paper §9.4).

The paper's dispatch-floor measurements put a fixed ~t0 on every command the
engine executes; batching to 512 samples drops the per-sample share ~127x
(§9.4). Serving lives or dies on amortizing exactly that floor across queued
requests, so this module schedules a *request queue* onto the decode program
rather than serving fixed-shape rounds:

  * **request queue** — FIFO of `Request`s (own prompt, own generation
    budget, own arrival step), admitted in arrival order.
  * **prompt-length bucketing** — heterogeneous prompts compile against a
    bounded set of prefill shapes: a prompt prefills at the largest bucket
    <= its length and catches the remainder up through the (single-shape)
    decode program, so the content-hash `ProgramCache` sees at most
    `len(buckets)` prefill programs + 1 decode program, no matter how many
    distinct prompt lengths arrive.
  * **slot-masked decode** — `n_slots` decode lanes step together with
    per-slot absolute positions; idle lanes carry a masked dummy token.
    Admission writes a new request's prefill state into a free lane
    mid-flight (`_admit_into_slot`), while the other lanes keep decoding.
  * **encode-many / execute-once** — every model dispatch goes through
    `ExecutionStream.encode_operation` + one `execute_sync` per scheduler
    tick, and every `DispatchRecord` carries the costmodel floor estimate,
    so per-request dispatch overhead is measured, not modeled.

Scheduling policies
-------------------
Three ship here; all subclass `_SchedulerBase` and share admission/cache
machinery:

  * `SequentialSchedule` — the parity reference: one request at a time,
    full-length prefill + a private decode loop. One dispatch per token per
    request: the un-amortized floor.
  * `ContinuousSchedule` — slot-masked batched decode with mid-flight
    admission, serialized through `execute_sync` (the sound default).
  * `SLOSchedule` — overlapped decode on `AsyncExecutionStream` (the
    paper's unfinished overlapping-streams path): the host encodes decode
    step N+1 while step N executes, with sampling fused on-device so the
    token chain never round-trips the host, plus SLO-aware admission that
    defers a queued request while the costmodel-predicted token latency
    would breach `--slo-ms`.

Adding a policy: subclass `_SchedulerBase`, implement
`run(requests) -> list[RequestResult]` from the shared helpers
(`_prefill_program`, `_decode_program`, `_admit_into_slot`, `_reset_slot`,
`self.sampler`), and register it in `SCHEDULES`;
`launch/serve.py --schedule <name>` then drives it. Keep every model dispatch on `self.stream` so the floor accounting and
the `BENCH_serve.json` curve stay truthful.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from functools import partial
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ShapeConfig
from repro.core import costmodel, hal
from repro.core.dispatch import (AsyncExecutionStream, ExecutionStream,
                                 ProgramCache)
from repro.kernels import compat
# TIME_MERGE_LEAVES historically lived here; the pool module owns the leaf
# taxonomy now and this re-export keeps existing imports working.
from repro.launch.kv_pool import PagedKVPool, TIME_MERGE_LEAVES  # noqa: F401
from repro.parallel import sharding as shard_rules
from repro.parallel.ctx import ParallelContext


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a generation budget."""

    rid: int
    prompt: np.ndarray            # (L,) int32 token ids, L >= 1
    max_new_tokens: int
    arrival: int = 0              # scheduler step at which the request exists
    frames: np.ndarray | None = None   # encdec only: cfg.frame_shape

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: np.ndarray            # (max_new_tokens,) generated ids
    bucket: int                   # prefill bucket used (0 = decode-only)
    admitted_step: int
    finished_step: int


def default_buckets(max_prompt_len: int) -> tuple[int, ...]:
    """Powers of two up to the longest prompt: ceil(log2) buckets total, so
    the prefill shape set stays logarithmic in prompt length."""
    out = []
    b = 8
    while b <= max_prompt_len:
        out.append(b)
        b *= 2
    return tuple(out) or (max(1, max_prompt_len),)


def bucket_for(prompt_len: int, buckets: Iterable[int]) -> int:
    """Largest bucket <= prompt_len (the prefilled prefix); 0 when every
    bucket is longer — the request then catches up entirely through decode."""
    fits = [b for b in buckets if b <= prompt_len]
    return max(fits) if fits else 0


# ---------------------------------------------------------------------------
# Prefill-cache -> decode-buffer merges
# ---------------------------------------------------------------------------


def _leaf_name(path: Any) -> str:
    return compat.tree_path_str(path).rsplit("/", 1)[-1]


def merge_prefill_caches(dec_caches: Any, pf_caches: Any) -> Any:
    """Copy prefill cache contents into the (longer time axis) decode
    buffers, whole-batch. Merging is by *named time axis*: a leaf may differ
    from its decode buffer on exactly one axis, and only when the leaf is a
    KV-time leaf (`TIME_MERGE_LEAVES`); the prefilled prefix lands at time
    offset 0, which is the ring-buffer slot for positions 0..s-1. Any rank
    mismatch, off-axis mismatch, or unnamed-axis mismatch raises with the
    tree path — prefill state (e.g. SSM conv/recurrent state) must never be
    silently dropped."""
    def merge(path, dst, src):
        loc = compat.tree_path_str(path)
        if dst.ndim != src.ndim:
            raise ValueError(
                f"cache leaf {loc!r}: prefill rank {src.ndim} {src.shape} != "
                f"decode buffer rank {dst.ndim} {dst.shape}; prefill state "
                f"would be dropped")
        diff = [i for i in range(dst.ndim) if dst.shape[i] != src.shape[i]]
        if not diff:
            return src.astype(dst.dtype)
        name = _leaf_name(path)
        if (len(diff) == 1 and name in TIME_MERGE_LEAVES
                and src.shape[diff[0]] <= dst.shape[diff[0]]):
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        raise ValueError(
            f"cache leaf {loc!r}: cannot merge prefill {src.shape} into "
            f"decode buffer {dst.shape} (mismatched axes {diff}; only the "
            f"named time axis of {sorted(TIME_MERGE_LEAVES)} may differ)")
    return compat.tree_map_with_path(merge, dec_caches, pf_caches)


def _admit_leaf(path, dst, src, slot):
    """Write batch-1 prefill leaf `src` into decode lane `slot` of `dst`.

    Cache trees are stacked (stack/layer axis 0, batch axis 1); `src` has
    batch extent 1 and may be shorter than `dst` on its named time axis.
    `pos` lanes are re-initialized to -1 first so stale KV entries from the
    lane's previous occupant can never pass the validity mask."""
    loc = compat.tree_path_str(path)
    if dst.ndim != src.ndim:
        raise ValueError(
            f"cache leaf {loc!r}: prefill rank {src.ndim} != decode buffer "
            f"rank {dst.ndim}")
    if src.shape[1] != 1:
        raise ValueError(f"cache leaf {loc!r}: admission wants a batch-1 "
                         f"prefill cache, got batch {src.shape[1]}")
    diff = [i for i in range(dst.ndim)
            if i != 1 and dst.shape[i] != src.shape[i]]
    name = _leaf_name(path)
    row = src[:, 0].astype(dst.dtype)             # (stack, ...)
    if not diff:                                  # full-lane overwrite
        return dst.at[:, slot].set(row)
    if (len(diff) == 1 and name in TIME_MERGE_LEAVES
            and src.shape[diff[0]] <= dst.shape[diff[0]]):
        base = dst[:, slot]
        if name == "pos":                          # invalidate the stale tail
            base = jnp.full_like(base, -1)
        new_row = jax.lax.dynamic_update_slice(base, row, (0,) * base.ndim)
        return dst.at[:, slot].set(new_row)
    raise ValueError(
        f"cache leaf {loc!r}: cannot admit prefill {src.shape} into decode "
        f"buffer {dst.shape} (mismatched axes {diff})")


def _admit_into_slot_impl(dec_caches, pf_caches, slot):
    """Traceable admission body (speculative's joint two-model admission
    fuses this for target AND drafter caches inside one dispatch)."""
    return compat.tree_map_with_path(
        lambda p, d, s: _admit_leaf(p, d, s, slot), dec_caches, pf_caches)


@partial(jax.jit, donate_argnums=(0,))
def _admit_into_slot(dec_caches, pf_caches, slot):
    """One on-stream dispatch per admission: merge a batch-1 prefill cache
    into lane `slot` (resident buffers donated). Compiled once per prefill
    bucket shape via jit's own cache — deliberately outside the ProgramCache
    so the bucketing compile bound stays `#buckets x {prefill, decode}` —
    but executed through the ExecutionStream so the floor ledger charges
    it."""
    return _admit_into_slot_impl(dec_caches, pf_caches, slot)


# one fused dispatch for the sequential reference's whole-batch merge
_merge_prefill_jit = jax.jit(merge_prefill_caches, donate_argnums=(0,))


def _reset_slot_impl(dec_caches, slot):
    """Traceable reset body (see `_admit_into_slot_impl`)."""
    def reset(path, dst):
        name = _leaf_name(path)
        if name == "pos":
            return dst.at[:, slot].set(jnp.full_like(dst[:, slot], -1))
        if name in TIME_MERGE_LEAVES:
            return dst
        return dst.at[:, slot].set(jnp.zeros_like(dst[:, slot]))
    return compat.tree_map_with_path(reset, dec_caches)


@partial(jax.jit, donate_argnums=(0,))
def _reset_slot(dec_caches, slot):
    """Clear lane `slot` for a decode-only admission (no prefill prefix):
    `pos` lanes to -1 (nothing valid), recurrent/conv state to zeros (the
    init_cache state), KV payload left as-is (masked by pos)."""
    return _reset_slot_impl(dec_caches, slot)


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

SAMPLING_MODES = ("greedy", "categorical")


class TokenSampler:
    """Per-request deterministic sampling: the key for the token placed at
    absolute position p of request r is fold_in(fold_in(seed, r), p), so a
    request's stream is identical under any schedule or batch composition."""

    def __init__(self, mode: str, vocab: int, seed: int) -> None:
        if mode not in SAMPLING_MODES:
            raise ValueError(f"sampling mode {mode!r} not in {SAMPLING_MODES}")
        self.mode = mode
        self.vocab = vocab
        self._root = jax.random.PRNGKey(seed)
        self._draw = jax.jit(
            lambda key, lg: jax.random.categorical(key, lg))

    def __call__(self, logits_row: np.ndarray, rid: int, position: int) -> int:
        lg = np.asarray(logits_row, np.float32)[: self.vocab]
        if self.mode == "greedy":
            return int(np.argmax(lg))
        key = jax.random.fold_in(jax.random.fold_in(self._root, rid), position)
        return int(self._draw(key, jnp.asarray(lg)))


# ---------------------------------------------------------------------------
# Schedulers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ChunkedPrefill:
    """In-flight chunked prefill for one lane: a batch-1 decode-shaped
    staging cache accumulating chunk writes, and the [0, target) progress."""

    staging: Any
    done: int = 0
    target: int = 0


@dataclasses.dataclass
class _Slot:
    """One decode lane's host-side state machine."""

    req: Request | None = None
    next_pos: int = 0             # absolute position the next decode writes
    next_tok: int = 0             # token consumed by the next decode step
    generated: list[int] = dataclasses.field(default_factory=list)
    bucket: int = 0
    admitted_step: int = 0
    pending: _ChunkedPrefill | None = None

    @property
    def active(self) -> bool:
        return self.req is not None

    @property
    def prefilling(self) -> bool:
        """Mid chunked prefill: the lane holds the request but cannot decode
        yet — chunks still write into the staging cache."""
        return self.pending is not None

    @property
    def generating(self) -> bool:
        """Past the prompt: the next decode step's logits are sampled."""
        return self.active and not self.prefilling \
            and self.next_pos >= self.req.prompt.size


class _SchedulerBase:
    """Shared machinery: bucketed prefill programs, admission, floor stats."""

    def __init__(self, model, params, cfg, *, max_len: int,
                 buckets: tuple[int, ...] | None = None,
                 sampling: str = "greedy", seed: int = 0,
                 program_cache: ProgramCache | None = None,
                 stream: ExecutionStream | None = None,
                 target: hal.Target | None = None,
                 ctx: ParallelContext | None = None) -> None:
        self.model = model
        self.cfg = cfg
        self.max_len = max_len
        self.buckets = tuple(sorted(buckets)) if buckets else \
            default_buckets(max_len)
        self.stream = stream or ExecutionStream(program_cache, target=target)
        self.cache = program_cache or self.stream.cache
        self.sampler = TokenSampler(sampling, cfg.vocab, seed)
        # Mesh serving: lanes span hosts over the batch axes, params
        # replicate except EP expert banks (serve_param_specs) — the
        # placement that keeps every token stream bit-identical to the
        # single-device path while the floor ledger stays per-dispatch
        # truthful (one SPMD program per tick, same dispatch count).
        self.ctx = ctx if ctx is not None else ParallelContext(mesh=None)
        # ProgramCache content hashes ignore shardings; the mesh descriptor
        # rides the `options` field so a mesh program can never collide with
        # a single-device program of identical shapes.
        self._copts = "" if not self.ctx.active else "mesh=" + "x".join(
            f"{a}{self.ctx.axis_size(a)}" for a in self.ctx.axis_names)
        if self.ctx.active:
            params = self._place(params,
                                 shard_rules.serve_param_specs(params,
                                                               self.ctx))
        self.params = params
        # called as step_hook(self, step) at the top of every serve-loop
        # tick; the elastic supervisor hangs heartbeat/failure checks here
        self.step_hook = None
        # run() aliases its live queue/results lists here so a supervisor
        # can read scheduler progress after an aborted run
        self._queue: list[Request] = []
        self._results: list[RequestResult] = []
        # decode-program handle per (token, pos) shape: the per-token hot
        # path must not re-flatten the whole (params, caches) pytree for a
        # ProgramCache key on every step (the warm start is free here)
        self._decode_memo: dict = {}

    # -- mesh placement -----------------------------------------------------
    def _place(self, tree, specs):
        """device_put every leaf to its NamedSharding; `specs` mirrors
        `tree` (DispatchedWeight nodes carry spec payloads)."""
        mesh = self.ctx.mesh
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, specs)

    def _batch_put(self, x):
        """Host batch array -> device, lane dim sharded over the batch axes
        when divisible (replicated otherwise) — `batch_specs`, applied to
        the scheduler's token/position frames."""
        xj = jnp.asarray(x)
        if not self.ctx.active:
            return xj
        spec = shard_rules.batch_specs(xj, self.ctx)
        return jax.device_put(xj, NamedSharding(self.ctx.mesh, spec))

    # -- programs -----------------------------------------------------------
    def _prefill_batch(self, tokens: np.ndarray,
                       frames: np.ndarray | None) -> dict:
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.cfg.family == "encdec":
            if frames is None:
                raise ValueError("encdec serving needs per-request frames")
            batch["frames"] = jnp.asarray(frames[None],
                                          self.model.dtype)
        return batch

    def _prefill_program(self, batch: dict):
        compiled, key = self.cache.compile(self.model.prefill, self.params,
                                           batch, options=self._copts)
        return compiled, key

    def _decode_program(self, caches, tok, pos):
        """Compile-or-hit the decode program. Cache shapes are fixed per
        scheduler (n_slots x max_len), so the handle is memoized by the
        (token, pos) shapes after the first ProgramCache resolution."""
        sig = (tok.shape, str(tok.dtype), pos.shape, str(pos.dtype))
        hit = self._decode_memo.get(sig)
        if hit is not None:
            return hit
        compiled, key = self.cache.compile(
            self.model.decode_step, self.params, caches, tok, pos,
            options=self._copts, jit_kwargs={"donate_argnums": (1,)})
        self._decode_memo[sig] = (compiled, key)
        return compiled, key

    def _check(self, req: Request) -> None:
        need = req.prompt.size + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt.size} + gen "
                f"{req.max_new_tokens} exceeds max_len {self.max_len}")
        if self.cfg.family == "encdec" and bucket_for(
                req.prompt.size, self.buckets) == 0:
            raise ValueError(
                f"request {req.rid}: encdec prompts must reach a prefill "
                f"bucket (cross-attention cache is built at prefill); "
                f"buckets={self.buckets}")

    # -- floor accounting ---------------------------------------------------
    def stats(self, n_requests: int) -> dict:
        recs = self.stream.records
        n = max(n_requests, 1)
        out = {
            "n_dispatches": len(recs),
            "floor_s": self.stream.total_floor_s(),
            "work_s": self.stream.total_work_s(),
            "dispatch_wall_s": sum(r.wall_s for r in recs),
            "per_request_dispatch_overhead_s": self.stream.total_floor_s() / n,
            "per_request_dispatches": len(recs) / n,
        }
        if self.ctx.active:
            # SPMD: every host issues the same command sequence, so each
            # batch-axis rank (one "host": its model ranks are co-located
            # engine slices) pays the full per-dispatch floor — the fleet
            # floor is hosts x the ledger, an identity the sharded-serve
            # bench gates.
            n_hosts = 1
            for a in self.ctx.batch_axes:
                n_hosts *= self.ctx.axis_size(a)
            out.update({
                "mesh_axes": {a: self.ctx.axis_size(a)
                              for a in self.ctx.axis_names},
                "n_hosts": n_hosts,
                "per_host_floor_s": out["floor_s"],
                "fleet_floor_s": out["floor_s"] * n_hosts,
            })
        return out


class SequentialSchedule(_SchedulerBase):
    """The parity reference: requests served one at a time, full-length
    prefill + a private batch-1 decode loop. Every token pays its own
    dispatch floor — the §9.4 worst case the continuous schedule amortizes.
    This is the seed serve loop's semantics, kept bit-compatible."""

    name = "sequential"

    def run(self, requests: list[Request]) -> list[RequestResult]:
        results = []
        for step, req in enumerate(sorted(requests, key=lambda r:
                                          (r.arrival, r.rid))):
            self._check(req)
            L = req.prompt.size
            batch = self._prefill_batch(req.prompt[None], req.frames)
            prefill, pkey = self._prefill_program(batch)
            self.stream.encode_operation(prefill, (self.params, batch),
                                         pkey, batch=1)
            pf_caches, logits = self.stream.execute_sync()[0]

            caches = self.model.init_cache(1, self.max_len)
            self.stream.encode_operation(_merge_prefill_jit,
                                         (caches, pf_caches),
                                         "merge_prefill", batch=1)
            caches = self.stream.execute_sync()[0]
            tok = self.sampler(np.asarray(logits)[0, -1], req.rid, L)
            generated = [tok]
            for i in range(req.max_new_tokens - 1):
                pos = L + i
                tokj = jnp.asarray([[tok]], jnp.int32)
                posj = jnp.full((1,), pos, jnp.int32)
                decode, dkey = self._decode_program(caches, tokj, posj)
                self.stream.encode_operation(
                    decode, (self.params, caches, tokj, posj), dkey, batch=1)
                caches, logits = self.stream.execute_sync()[0]
                tok = self.sampler(np.asarray(logits)[0, -1], req.rid, pos + 1)
                generated.append(tok)
            results.append(RequestResult(
                req.rid, L, np.asarray(generated, np.int32),
                bucket=L, admitted_step=step, finished_step=step))
        return results


class ContinuousSchedule(_SchedulerBase):
    """Continuous batching: `n_slots` decode lanes in one resident cache,
    stepping together. New requests are admitted into free lanes mid-flight:
    prefill at the largest bucket <= the prompt, catch the tail up through
    the shared decode program (teacher-forced prompt tokens), then generate.
    All lanes share each decode dispatch, so the per-request floor share is
    floor / n_active."""

    name = "continuous"

    def __init__(self, model, params, cfg, *, n_slots: int, max_len: int,
                 prefix_cache: bool = False, prefix_blocks: int = 64,
                 prefix_block_size: int = 8,
                 prefix_pool: PagedKVPool | None = None,
                 prefill_chunk: int | None = None, **kw) -> None:
        super().__init__(model, params, cfg, max_len=max_len, **kw)
        if n_slots < 1:
            raise ValueError(f"continuous schedule needs n_slots >= 1, "
                             f"got {n_slots}")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, "
                                 f"got {prefill_chunk}")
            if cfg.family == "encdec":
                raise ValueError(
                    "chunked prefill cannot serve encdec: the cross-attention "
                    "cache is built by the monolithic prefill program, so a "
                    "decode-mode chunk has no frames to attend to")
        self.prefill_chunk = prefill_chunk
        self._chunk_memo: dict = {}
        self._chunk_keys: set[str] = set()
        self.n_slots = n_slots
        self.slots = [_Slot() for _ in range(n_slots)]
        self.caches = None        # allocated lazily on first run
        self.pool: PagedKVPool | None = None
        if prefix_cache or prefix_pool is not None:
            if cfg.family == "encdec":
                raise ValueError(
                    "prefix cache cannot serve encdec: the cross-attention "
                    "cache is built from per-request frames, so token-hash "
                    "block sharing would alias state across requests")
            # `prefix_pool` hands in an already-populated pool — the elastic
            # supervisor's rescale path, which carries resident blocks (and
            # their eviction policy) across scheduler rebuilds
            self.pool = prefix_pool if prefix_pool is not None else \
                PagedKVPool(prefix_blocks, prefix_block_size,
                            evict_cost_fn=self._re_prefill_cost)
            pool = self.pool

            # both admission-side pool programs are jitted outside the
            # ProgramCache, like `_admit_into_slot` (the compile bound stays
            # `#buckets x {prefill, decode}`), but dispatch on the stream so
            # every pool touch is floor-charged like any other command
            @partial(jax.jit, donate_argnums=(0,))
            def _prefix_admit(dec_caches, arenas, bids, anchor, slot):
                pf = pool.assemble_prefix(dec_caches, arenas, bids, anchor)
                return _admit_into_slot_impl(dec_caches, pf, slot)

            @partial(jax.jit, donate_argnums=(0,), static_argnums=(3,))
            def _pool_insert(arenas, pf_caches, bids, start):
                return pool.insert_blocks(arenas, pf_caches, bids, start)

            self._prefix_admit_jit = _prefix_admit
            self._pool_insert_jit = _pool_insert

    def _re_prefill_cost(self, n_tokens: int) -> float:
        """Costmodel floor+work of re-prefilling an `n_tokens` resident
        prefix at batch 1: what evicting a block whose chain ends
        `n_tokens` deep would cost to rebuild on a future hit-turned-miss.
        The pool minimizes this over refcount-0 eviction candidates
        (`PagedKVPool._evict_victim`), so cheap-to-recreate shallow chains
        go before deep ones."""
        shape = ShapeConfig("re_prefill", max(1, n_tokens), 1, "prefill")
        t = self.stream.target
        flops = costmodel.model_flops(self.cfg, shape) \
            + costmodel.attention_flops(self.cfg, shape)
        work = max(flops / t.peak_flops,
                   costmodel.weight_bytes(self.cfg) / t.hbm_bandwidth)
        return self.stream.floor_s + work

    def _ensure_caches(self) -> None:
        if self.caches is None:
            self.caches = self.model.init_cache(self.n_slots, self.max_len)
            if self.ctx.active:
                self.caches = self._place(
                    self.caches,
                    shard_rules.serve_cache_specs(self.caches, self.ctx))
        if self.pool is not None:
            if self.pool.arenas is None:
                self.pool.bind(self.caches, max_len=self.max_len)
            if self.ctx.active:
                # fresh or carried-over (supervisor rescale) arenas land
                # replicated on the *current* mesh — a carried pool's rows
                # may still be placed on the pre-failure device set
                self.pool.arenas = self._place(
                    self.pool.arenas,
                    shard_rules.serve_arena_specs(self.pool.arenas, self.ctx))

    # -- prefix-cache admission ---------------------------------------------
    def _prefix_hit_admit(self, req: Request, slot: _Slot, sidx,
                          bucket: int) -> bool:
        """Admit from resident blocks when the prompt's longest anchored
        resident prefix reaches at least the bucket a cold admission would
        prefill: ONE fused gather+merge dispatch replaces the prefill +
        lane-write pair, and the matched blocks' prefill work is never
        dispatched at all. The matched length M is capped at L-1 so at least
        one prompt token always remains to teacher-force the first decode
        step — hit admissions never need logits, and token streams stay
        bit-identical to cold admissions (sampling is keyed per (rid,
        position), and positions M..L-1 catch up through the shared decode
        program exactly as a bucket-M cold admission would)."""
        pool = self.pool
        if pool is None or pool.arenas is None:
            return False
        L = req.prompt.size
        keys = pool.anchored_match(req.prompt, limit=L - 1)
        M = len(keys) * pool.block_size
        if not keys or M < max(bucket, 1):
            return False
        bids = jnp.asarray(pool.bids_of(keys), jnp.int32)
        anchor = pool.anchor_of(keys[-1])
        self.stream.encode_operation(
            self._prefix_admit_jit,
            (self.caches, pool.arenas, bids, anchor, sidx),
            "prefix_admit", batch=1)
        self.caches = self.stream.execute_sync()[0]
        pool.acquire(req.rid, keys)
        pool.stats["hits"] += 1
        pool.stats["hit_tokens"] += M
        slot.next_pos = M
        slot.next_tok = int(req.prompt[M])
        return True

    def _pool_cold_insert(self, req: Request, bucket: int, pf_caches,
                          staging: bool = False) -> None:
        """Cold-path residency: reserve arena rows for the prefilled whole
        blocks and write them with one extra dispatch (floor-charged — the
        honest cost of caching); the chain end anchors the non-paged leaves
        (recurrent state, conv tails, ring-window KV) so later admissions
        can resume from exactly this boundary. Chunked admissions pass
        `staging=True`: the source is a decode-shaped staging cache whose
        time extent is `max_len`, valid through `bucket` (a chunk boundary,
        so the anchored chain lands exactly where chunks stopped writing)."""
        pool = self.pool
        pool.stats["misses"] += 1
        if bucket < pool.block_size:
            return
        keys, new_bids, first_new = pool.reserve(req.prompt[:bucket])
        if new_bids:
            pool.validate_prefill(pf_caches, bucket, staging=staging)
            bids = jnp.asarray(new_bids, jnp.int32)
            self.stream.encode_operation(
                self._pool_insert_jit,
                (pool.arenas, pf_caches, bids, first_new),
                "pool_insert", batch=1)
            pool.arenas = self.stream.execute_sync()[0]
        if keys:
            if len(keys) * pool.block_size == bucket:
                pool.set_anchor(keys[-1], pool.anchor_leaves(pf_caches))
            pool.acquire(req.rid, keys)

    def _release_lane(self, req: Request) -> None:
        if self.pool is not None:
            self.pool.release(req.rid)

    # -- chunked prefill ----------------------------------------------------
    def _chunk_program(self, staging, tok, pos0):
        """Compile-or-hit the prefill-chunk program. The staging cache is
        decode-shaped (batch 1 x max_len) whatever the prompt, so the handle
        is memoized by the chunk width alone: ONE ProgramCache entry per
        chunk size, not per prompt bucket — the whole point of chunking's
        compile economics."""
        sig = (tok.shape, str(tok.dtype))
        hit = self._chunk_memo.get(sig)
        if hit is not None:
            return hit
        compiled, key = self.cache.compile(
            self.model.prefill_chunk, self.params, staging, tok, pos0,
            options=self._copts, jit_kwargs={"donate_argnums": (1,)})
        self._chunk_keys.add(key)
        hit = (compiled, key)
        self._chunk_memo[sig] = hit
        return hit

    def _begin_chunked(self, slot: _Slot, req: Request, target: int) -> None:
        """Stage a chunked prefill: allocate a fresh batch-1 decode-shaped
        cache (attention positions init to -1, recurrent state zero — the
        same clean state `_reset_slot` produces, so no reset dispatch is
        needed) and mark the lane pending. Chunks advance one per serve
        tick, so in-flight decode lanes get a window between every pair of
        chunks instead of stalling behind one monolithic prefill."""
        staging = self.model.init_cache(1, self.max_len)
        if self.ctx.active:
            staging = self._place(
                staging, shard_rules.serve_staging_specs(staging, self.ctx))
        slot.pending = _ChunkedPrefill(staging=staging, done=0, target=target)
        slot.next_pos = 0
        slot.next_tok = 0

    def _advance_chunk(self, slot_idx: int, step: int) -> None:
        """Dispatch ONE chunk for a pending lane: C prompt tokens forward in
        decode mode against the staging cache, floor-charged on the stream
        like any other dispatch (`span` records the token range for the
        bench audit). The final chunk hands off to `_finish_chunked`."""
        slot = self.slots[slot_idx]
        pend, req = slot.pending, slot.req
        c0 = pend.done
        n = min(self.prefill_chunk, pend.target - c0)
        tokj = jnp.asarray(req.prompt[None, c0:c0 + n], jnp.int32)
        pos0 = jnp.full((1,), c0, jnp.int32)
        compiled, ckey = self._chunk_program(pend.staging, tokj, pos0)
        self.stream.encode_operation(
            compiled, (self.params, pend.staging, tokj, pos0), ckey,
            batch=1, span=(c0, c0 + n))
        pend.staging, _ = self.stream.execute_sync()[0]
        pend.done = c0 + n
        if pend.done >= pend.target:
            self._finish_chunked(slot_idx)

    def _finish_chunked(self, slot_idx: int) -> None:
        """Admit the fully-staged prefix into the lane: the staging cache's
        time extent equals the lane's, so `_admit_into_slot` overwrites
        every leaf of the lane wholesale (positions included) in one donated
        dispatch — the same path bucketed admissions take. The chunk target
        is capped at L-1, so the first decode step is always teacher-forced
        and no finalize logits are needed."""
        slot = self.slots[slot_idx]
        pend, req = slot.pending, slot.req
        sidx = jnp.asarray(slot_idx, jnp.int32)
        if self.pool is not None:
            self._pool_cold_insert(req, pend.target, pend.staging,
                                   staging=True)
        self.stream.encode_operation(
            _admit_into_slot, (self.caches, pend.staging, sidx),
            "admit_slot", batch=1)
        self.caches = self.stream.execute_sync()[0]
        slot.pending = None
        slot.next_pos = pend.target
        slot.next_tok = int(req.prompt[pend.target])

    # -- admission ----------------------------------------------------------
    def _admit(self, slot_idx: int, req: Request, step: int) -> None:
        """Prefill the bucket prefix through the stream, then write the
        prefill state into the lane. Called after `_check`. With
        `prefill_chunk` set, the prompt prefills as chunks instead: the
        target is the largest chunk multiple <= L-1 (positions target..L-1
        catch up teacher-forced through the shared decode program, exactly
        like a bucket-target cold admission, which keeps token streams
        bit-identical to unchunked serving)."""
        slot = self.slots[slot_idx]
        L = req.prompt.size
        C = self.prefill_chunk
        if C is not None:
            bucket = C * ((L - 1) // C)
        else:
            bucket = bucket_for(L, self.buckets)
        sidx = jnp.asarray(slot_idx, jnp.int32)
        # lane writes dispatch on the stream too: the floor ledger must
        # charge every real dispatch, admissions included
        if self._prefix_hit_admit(req, slot, sidx, bucket):
            pass                  # admitted from resident blocks
        elif C is not None and bucket > 0:
            self._begin_chunked(slot, req, bucket)
        elif bucket == 0:
            self.stream.encode_operation(_reset_slot, (self.caches, sidx),
                                         "reset_slot", batch=1)
            self.caches = self.stream.execute_sync()[0]
            if self.pool is not None:
                self.pool.stats["misses"] += 1
            slot.next_pos, slot.next_tok = 0, int(req.prompt[0])
        else:
            batch = self._prefill_batch(req.prompt[None, :bucket], req.frames)
            prefill, pkey = self._prefill_program(batch)
            self.stream.encode_operation(prefill, (self.params, batch),
                                         pkey, batch=1)
            pf_caches, logits = self.stream.execute_sync()[0]
            if self.pool is not None:
                self._pool_cold_insert(req, bucket, pf_caches)
            self.stream.encode_operation(
                _admit_into_slot, (self.caches, pf_caches, sidx),
                "admit_slot", batch=1)
            self.caches = self.stream.execute_sync()[0]
            slot.next_pos = bucket
            if bucket < L:        # catch up through decode, teacher-forced
                slot.next_tok = int(req.prompt[bucket])
            else:                 # prompt fully prefilled: sample token L
                tok = self.sampler(np.asarray(logits)[0, -1], req.rid, L)
                slot.generated.append(tok)
                slot.next_tok = tok
        slot.req = req
        slot.bucket = bucket
        slot.admitted_step = step

    def _advance(self, slot: _Slot, logits_row: np.ndarray,
                 results: list[RequestResult], step: int) -> None:
        """Consume one decode step's logits for an active lane."""
        req = slot.req
        pos_written = slot.next_pos
        slot.next_pos = pos_written + 1
        nxt = pos_written + 1
        if nxt < req.prompt.size:            # still catching up: teacher-force
            slot.next_tok = int(req.prompt[nxt])
            return
        tok = self.sampler(logits_row, req.rid, nxt)
        slot.generated.append(tok)
        slot.next_tok = tok
        if len(slot.generated) >= req.max_new_tokens:
            results.append(RequestResult(
                req.rid, req.prompt.size,
                np.asarray(slot.generated[:req.max_new_tokens], np.int32),
                bucket=slot.bucket, admitted_step=slot.admitted_step,
                finished_step=step))
            self._release_lane(req)
            slot.req = None
            slot.generated = []

    # -- the serve loop -----------------------------------------------------
    def run(self, requests: list[Request]) -> list[RequestResult]:
        for r in requests:
            self._check(r)
        queue = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self._ensure_caches()
        results: list[RequestResult] = []
        # alias live state for the elastic supervisor: both lists mutate in
        # place, so lane snapshots survive a mid-run HostFailure
        self._queue, self._results = queue, results
        step = 0
        while queue or any(s.active for s in self.slots):
            if self.step_hook is not None:
                self.step_hook(self, step)
            # admissions: free lanes x arrived requests, in arrival order
            for i, slot in enumerate(self.slots):
                if not queue or queue[0].arrival > step:
                    break
                if not slot.active:
                    self._admit(i, queue.pop(0), step)
            # pending lanes advance ONE chunk per tick, so a decode window
            # runs between every pair of chunks instead of the whole prompt
            # blocking the in-flight lanes at once
            for i, slot in enumerate(self.slots):
                if slot.prefilling:
                    self._advance_chunk(i, step)
            active = [s for s in self.slots
                      if s.active and not s.prefilling
                      and not (s.generating
                               and len(s.generated) >= s.req.max_new_tokens)]
            # a fully-prefilled request can finish without a decode step
            for s in list(self.slots):
                if s.active and s.generating \
                        and len(s.generated) >= s.req.max_new_tokens:
                    self._advance_finished(s, results, step)
            if not active:
                if queue or any(s.prefilling for s in self.slots):
                    step += 1     # idle tick: arrival or mid-chunk prefill
                    continue
                break
            # one slot-masked decode dispatch for every lane
            tok = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            for i, s in enumerate(self.slots):
                if s.active and not s.prefilling:
                    tok[i, 0] = s.next_tok
                    pos[i] = s.next_pos
            tokj = self._batch_put(tok)
            posj = self._batch_put(pos)
            decode, dkey = self._decode_program(self.caches, tokj, posj)
            self.stream.encode_operation(
                decode, (self.params, self.caches, tokj, posj), dkey,
                batch=len(active))
            self.caches, logits = self.stream.execute_sync()[0]
            lg = np.asarray(logits[:, -1, : self.cfg.vocab], np.float32)
            for i, s in enumerate(self.slots):
                if s.active and not s.prefilling:
                    self._advance(s, lg[i], results, step)
            step += 1
        results.sort(key=lambda r: r.rid)
        return results

    def _advance_finished(self, slot: _Slot, results: list[RequestResult],
                          step: int) -> None:
        req = slot.req
        results.append(RequestResult(
            req.rid, req.prompt.size,
            np.asarray(slot.generated[:req.max_new_tokens], np.int32),
            bucket=slot.bucket, admitted_step=slot.admitted_step,
            finished_step=step))
        self._release_lane(req)
        slot.req = None
        slot.generated = []

    # -- reporting ----------------------------------------------------------
    def stats(self, n_requests: int) -> dict:
        out = super().stats(n_requests)
        if self.pool is not None:
            out["prefix_cache"] = dict(self.pool.stats)
            out["prefix_cache"]["free_blocks"] = self.pool.free_blocks()
        if self.prefill_chunk is not None:
            recs = self.stream.records
            out["chunked_prefill"] = {
                "prefill_chunk": self.prefill_chunk,
                "n_chunks": sum(1 for r in recs
                                if r.key in self._chunk_keys),
                "chunk_tokens": sum(r.span[1] - r.span[0] for r in recs
                                    if r.span is not None),
            }
        return out


class SLOSchedule(ContinuousSchedule):
    """Overlapped continuous batching with SLO-aware admission.

    The decode loop is software-pipelined on `AsyncExecutionStream`: the
    host plans a *window* of decode steps whose control flow is fully
    deterministic (teacher-forcing vs sampling per lane follows positions,
    never logits), fuses next-token selection into the decode program
    (device argmax / per-(rid, pos) fold_in categorical — bit-identical to
    the host `TokenSampler`), and submits each step with the previous
    step's token output chained in as a live async value. The host never
    blocks per token: step N+1 is encoded and submitted while step N
    executes, and tokens materialize once per window at the sync barrier.
    Windows end exactly where host decisions live — a lane completing, or a
    queued arrival that could claim a free lane.

    Admission is gated on the costmodel: a queued request is admitted into
    a free lane only when the predicted token latency
    `dispatch_floor_s x in-flight depth + per-token work` (work = p99 of
    recent decode-step walls, the floor until observed) stays under the
    SLO. An idle engine always admits — the gate sheds load, it cannot
    starve. Deferred admissions are counted in `deferred_admissions`.

    Token streams are schedule-invariant by construction (greedy ignores
    the schedule; categorical is keyed per (request, position)), so this
    policy is token-exact against `ContinuousSchedule` and
    `SequentialSchedule` whatever the SLO defers.
    """

    name = "slo"

    #: decode-wall samples retained for the p99 work predictor
    WALL_WINDOW = 64

    #: default in-flight window when this schedule builds its own stream: a
    #: typical decode run-ahead, deep enough that submits inside one window
    #: rarely throttle (each throttle costs a drain-thread wakeup on the
    #: critical path); the stream's own default of 2 is plain double
    #: buffering for callers that hand-roll submit/sync
    MAX_IN_FLIGHT = 8

    def __init__(self, model, params, cfg, *, n_slots: int, max_len: int,
                 slo_ms: float | None = None, max_in_flight: int = MAX_IN_FLIGHT,
                 stream: ExecutionStream | None = None,
                 program_cache: ProgramCache | None = None,
                 target: hal.Target | None = None, **kw) -> None:
        if stream is None:
            stream = AsyncExecutionStream(program_cache, target=target,
                                          max_in_flight=max_in_flight)
        if not isinstance(stream, AsyncExecutionStream):
            raise ValueError(
                "SLOSchedule pipelines decode through AsyncExecutionStream; "
                f"got {type(stream).__name__} (a sync stream would serialize "
                "the window and the floor accounting would not reflect "
                "overlap)")
        super().__init__(model, params, cfg, n_slots=n_slots, max_len=max_len,
                         stream=stream, program_cache=program_cache,
                         target=target, **kw)
        self.slo_s = None if slo_ms is None else float(slo_ms) * 1e-3
        self.deferred_admissions = 0
        self._step_memo: dict = {}
        self._decode_keys: set[str] = set()
        self._decode_walls: deque[float] = deque(maxlen=self.WALL_WINDOW)
        self._records_seen = 0

    # -- fused decode + on-device sampling ----------------------------------
    def _fused_step_program(self, caches, tok, pos, forced, do_sample, rids):
        """Compile-or-hit the pipelined step: decode_step + next-token
        selection in one program, so the token chain stays on device. The
        sampling math mirrors `TokenSampler` exactly: fp32 logits sliced to
        the vocab, first-index argmax for greedy, fold_in(fold_in(seed,
        rid), pos) categorical otherwise."""
        sig = (tok.shape, str(tok.dtype), pos.shape)
        hit = self._step_memo.get(sig)
        if hit is not None:
            return hit
        model, vocab = self.model, self.cfg.vocab
        mode, root = self.sampler.mode, self.sampler._root

        def fused(params, caches, tok, pos, forced, do_sample, rids):
            caches, logits = model.decode_step(params, caches, tok, pos)
            lg = logits[:, -1, :vocab].astype(jnp.float32)
            if mode == "greedy":
                samp = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                def draw(rid, p, row):
                    key = jax.random.fold_in(jax.random.fold_in(root, rid), p)
                    return jax.random.categorical(key, row)
                samp = jax.vmap(draw)(rids, pos + 1, lg).astype(jnp.int32)
            nxt = jnp.where(do_sample, samp, forced).astype(jnp.int32)
            return caches, nxt[:, None], samp

        compiled, key = self.cache.compile(
            fused, self.params, caches, tok, pos, forced, do_sample, rids,
            options=self._copts, jit_kwargs={"donate_argnums": (1,)})
        self._decode_keys.add(key)
        hit = (compiled, key)
        self._step_memo[sig] = hit
        return hit

    # -- the SLO admission gate ---------------------------------------------
    def _observe_decode_walls(self) -> None:
        """Fold any new decode-step records into the work predictor."""
        recs = self.stream.records
        for r in recs[self._records_seen:]:
            if r.key in self._decode_keys:
                self._decode_walls.append(r.wall_s)
        self._records_seen = len(recs)

    def predicted_token_latency_s(self) -> float:
        """Costmodel-predicted p99 token latency were one more request
        admitted now: each decode tick pays the dispatch floor once per
        submission that can sit in flight ahead of it (the window bound),
        plus the per-token work — the p99 of recently observed decode-step
        walls, or the floor itself before anything was observed."""
        if self._decode_walls:
            walls = sorted(self._decode_walls)
            work = walls[min(len(walls) - 1, int(0.99 * len(walls)))]
        else:
            work = self.stream.floor_s
        # the gate runs at drained barriers (live in-flight depth 0), so the
        # p99 queue-delay term uses the window bound the next pipelined
        # window will fill to, not the momentary depth
        return self.stream.floor_s * self.stream.max_in_flight + work

    def _admission_clear(self) -> bool:
        if self.slo_s is None:
            return True
        if not any(s.active for s in self.slots) \
                and self.stream.in_flight_depth == 0:
            return True          # idle engine: deferring forever would
                                 # starve without ever improving the SLO
        return self.predicted_token_latency_s() <= self.slo_s

    # -- the pipelined serve loop -------------------------------------------
    def _window_horizon(self, step: int, queue: list[Request]) -> int:
        """Decode steps encodable ahead without a host decision: up to the
        first lane completion, never past the step at which a queued
        arrival could claim a currently-free lane, and never deeper than
        the stream's in-flight window — submitting past the window would
        throttle every further step on a drain-thread wakeup, while
        syncing at the window boundary drains once per window."""
        remain = []
        for s in self.slots:
            if not s.active or s.prefilling:
                continue
            # steps still teacher-forced before sampling starts at this lane
            forced_left = max(0, s.req.prompt.size - 1 - s.next_pos)
            to_sample = s.req.max_new_tokens - len(s.generated)
            remain.append(forced_left + to_sample)
        k = min(remain + [self.stream.max_in_flight])
        if queue and any(not s.active for s in self.slots):
            k = min(k, max(1, queue[0].arrival - step))
        if any(s.prefilling for s in self.slots):
            k = 1          # a pending lane's next chunk bounds the window:
                           # decode one step, then give the chunk a turn
        return k

    def _pipelined_window(self, step: int, queue: list[Request],
                          results: list[RequestResult]) -> int:
        """Encode + submit `k` chained decode steps, then sync once and fold
        the materialized tokens back into the host state machines."""
        k = self._window_horizon(step, queue)
        n = self.n_slots
        tok0 = np.zeros((n, 1), np.int32)
        rids = np.zeros((n,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active and not s.prefilling:
                tok0[i, 0] = s.next_tok
                rids[i] = s.req.rid
        tok_dev = self._batch_put(tok0)   # becomes a chained async value
        ridsj = self._batch_put(rids)
        plan: list[tuple[Any, list[int]]] = []
        for _ in range(k):
            pos = np.zeros((n,), np.int32)
            forced = np.zeros((n,), np.int32)
            mask = np.zeros((n,), bool)
            sampled_lanes: list[int] = []
            n_active = 0
            for i, s in enumerate(self.slots):
                if not s.active or s.prefilling:
                    continue
                n_active += 1
                pos[i] = s.next_pos
                nxt = s.next_pos + 1
                if nxt < s.req.prompt.size:   # catch-up: teacher-force
                    forced[i] = int(s.req.prompt[nxt])
                else:
                    mask[i] = True
                    sampled_lanes.append(i)
                s.next_pos = nxt
            posj = self._batch_put(pos)
            forcedj = self._batch_put(forced)
            maskj = self._batch_put(mask)
            compiled, dkey = self._fused_step_program(
                self.caches, tok_dev, posj, forcedj, maskj, ridsj)
            self.stream.encode_operation(
                compiled, (self.params, self.caches, tok_dev, posj, forcedj,
                           maskj, ridsj), dkey, batch=n_active)
            # submit without blocking: caches/token chain forward as live
            # async values; the background drain confirms completions
            self.caches, tok_dev, samp = self.stream.submit()[0]
            plan.append((samp, sampled_lanes))
        self.stream.sync()
        self._observe_decode_walls()
        nxt_host = np.asarray(tok_dev)[:, 0]
        for t, (samp, sampled_lanes) in enumerate(plan):
            samp_np = np.asarray(samp) if sampled_lanes else None
            for i in sampled_lanes:
                s = self.slots[i]
                s.generated.append(int(samp_np[i]))
                if len(s.generated) >= s.req.max_new_tokens:
                    self._advance_finished(s, results, step + t)
        for i, s in enumerate(self.slots):
            if s.active and not s.prefilling:
                s.next_tok = int(nxt_host[i])
        return step + k

    def run(self, requests: list[Request]) -> list[RequestResult]:
        for r in requests:
            self._check(r)
        queue = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self._ensure_caches()
        results: list[RequestResult] = []
        self._queue, self._results = queue, results
        step = 0
        while queue or any(s.active for s in self.slots):
            if self.step_hook is not None:
                self.step_hook(self, step)
            # admissions happen at a drained barrier (prefill + lane writes
            # are stream dispatches themselves); the gate reads the ledger
            for i, slot in enumerate(self.slots):
                if not queue or queue[0].arrival > step:
                    break
                if slot.active:
                    continue
                if not self._admission_clear():
                    self.deferred_admissions += 1
                    break
                self._admit(i, queue.pop(0), step)
            # pending lanes advance ONE chunk at this drained barrier, so
            # the SLO gate and the in-flight decode window both see each
            # chunk as an ordinary dispatch — never a monolithic stall
            for i, slot in enumerate(self.slots):
                if slot.prefilling:
                    self._advance_chunk(i, step)
            # a fully-prefilled request can finish without a decode step
            for s in list(self.slots):
                if s.active and s.generating \
                        and len(s.generated) >= s.req.max_new_tokens:
                    self._advance_finished(s, results, step)
            if not any(s.active for s in self.slots):
                if queue:
                    step += 1     # idle tick: wait for the next arrival
                    continue
                break
            if not any(s.active and not s.prefilling for s in self.slots):
                step += 1         # only mid-chunk lanes: nothing to decode
                continue
            step = self._pipelined_window(step, queue, results)
        results.sort(key=lambda r: r.rid)
        return results

    # -- reporting ----------------------------------------------------------
    def stats(self, n_requests: int) -> dict:
        out = super().stats(n_requests)
        recs = self.stream.records
        out.update({
            "deferred_admissions": self.deferred_admissions,
            "max_in_flight": self.stream.max_in_flight,
            "mean_inflight_depth": float(np.mean(
                [r.inflight_depth for r in recs])) if recs else 0.0,
            "predicted_token_latency_s": self.predicted_token_latency_s(),
        })
        return out


SCHEDULES = {
    "sequential": SequentialSchedule,
    "continuous": ContinuousSchedule,
    "slo": SLOSchedule,
    # "spec" (SpeculativeSchedule) registers itself from launch.speculative,
    # imported at the bottom of this module
}

# ---------------------------------------------------------------------------
# Typed serve configuration
# ---------------------------------------------------------------------------
# `ServeConfig` is the construction API: one dataclass per schedule-specific
# knob group, attached as a section (`slo=`, `spec=`, `prefix=`, `chunk=`).
# A section present on a schedule it does not apply to is a loud ValueError
# at `validate()` — the old `make_scheduler(**kw)` silently stripped such
# knobs, which let a misspelled or misplaced flag vanish without a trace.


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """`slo` schedule knobs: admission-gate target + in-flight window."""
    slo_ms: float | None = None
    max_in_flight: int = SLOSchedule.MAX_IN_FLIGHT


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """`spec` schedule knobs: drafter selection + window depth."""
    draft_depth: int = 4
    draft: str = "shrink"
    draft_ckpt: str | None = None
    draft_branches: int = 1
    drafter: Any = None           # a prebuilt Drafter overrides `draft`
    max_in_flight: int = 2


@dataclasses.dataclass(frozen=True)
class PrefixConfig:
    """Paged KV prefix pool (continuous/slo). Presence of the section
    enables the pool; `pool` hands in an already-populated PagedKVPool
    (the elastic supervisor's rescale path)."""
    blocks: int = 64
    block_size: int = 8
    pool: PagedKVPool | None = None


@dataclasses.dataclass(frozen=True)
class ChunkConfig:
    """Long-context knobs (continuous/slo): chunked prefill and/or
    ring-attention routing. `prefill_chunk` admits a long prompt as fixed-
    size chunk programs with decode windows between them; `ring_min` routes
    monolithic prefills of at least that many tokens through
    `parallel.ring_attention` (needs an active multi-device mesh — consumed
    at model build via `ParallelContext.ring_prefill_min`, not here)."""
    prefill_chunk: int | None = None
    ring_min: int | None = None

    def __post_init__(self) -> None:
        if self.prefill_chunk is None and self.ring_min is None:
            raise ValueError(
                "ChunkConfig needs prefill_chunk and/or ring_min; an empty "
                "section would silently do nothing")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.ring_min is not None and self.ring_min < 1:
            raise ValueError(f"ring_min must be >= 1, got {self.ring_min}")


#: which schedules each section applies to — the loud-rejection table
_SECTION_SCHEDULES = {
    "slo": ("slo",),
    "spec": ("spec",),
    "prefix": ("continuous", "slo"),
    "chunk": ("continuous", "slo"),
}


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Typed serving configuration: base knobs every schedule shares, plus
    per-schedule sections. `validate()` rejects a section attached to a
    schedule it cannot apply to; `build_scheduler(config, ...)` is the one
    construction path `launch/serve.py` uses."""
    schedule: str
    max_len: int
    n_slots: int = 1
    sampling: str = "greedy"
    seed: int = 0
    buckets: tuple[int, ...] | None = None
    stream: ExecutionStream | None = None
    program_cache: ProgramCache | None = None
    target: hal.Target | None = None
    ctx: ParallelContext | None = None
    slo: SLOConfig | None = None
    spec: SpecConfig | None = None
    prefix: PrefixConfig | None = None
    chunk: ChunkConfig | None = None

    def validate(self) -> "ServeConfig":
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule {self.schedule!r} not in {sorted(SCHEDULES)}")
        for name, applies in _SECTION_SCHEDULES.items():
            if getattr(self, name) is not None \
                    and self.schedule not in applies:
                raise ValueError(
                    f"ServeConfig.{name} does not apply to the "
                    f"{self.schedule!r} schedule (only {applies}); drop the "
                    f"section instead of expecting it to be ignored")
        if self.chunk is not None and self.prefix is not None \
                and self.chunk.prefill_chunk is not None \
                and self.chunk.prefill_chunk % self.prefix.block_size != 0:
            raise ValueError(
                f"prefix.block_size ({self.prefix.block_size}) must divide "
                f"chunk.prefill_chunk ({self.chunk.prefill_chunk}): chunk "
                f"targets are chunk multiples, and a chain only anchors "
                f"when whole blocks tile the prefilled prefix exactly")
        return self

    def scheduler_kwargs(self) -> dict:
        """Flatten to the scheduler constructors' keyword surface."""
        kw: dict[str, Any] = dict(
            sampling=self.sampling, seed=self.seed, buckets=self.buckets,
            stream=self.stream, program_cache=self.program_cache,
            target=self.target)
        if self.ctx is not None:
            kw["ctx"] = self.ctx
        if self.slo is not None:
            kw.update(slo_ms=self.slo.slo_ms,
                      max_in_flight=self.slo.max_in_flight)
        if self.spec is not None:
            sp = self.spec
            kw.update(draft_depth=sp.draft_depth, draft=sp.draft,
                      draft_ckpt=sp.draft_ckpt,
                      draft_branches=sp.draft_branches,
                      max_in_flight=sp.max_in_flight)
            if sp.drafter is not None:
                kw["drafter"] = sp.drafter
        if self.prefix is not None:
            pf = self.prefix
            kw.update(prefix_cache=True, prefix_blocks=pf.blocks,
                      prefix_block_size=pf.block_size)
            if pf.pool is not None:
                kw["prefix_pool"] = pf.pool
        if self.chunk is not None and self.chunk.prefill_chunk is not None:
            kw["prefill_chunk"] = self.chunk.prefill_chunk
        return kw


def build_scheduler(config: ServeConfig, model, params, cfg) -> _SchedulerBase:
    """Construct the scheduler a validated `ServeConfig` describes."""
    config.validate()
    kw = config.scheduler_kwargs()
    if config.schedule == "sequential":
        return SequentialSchedule(model, params, cfg,
                                  max_len=config.max_len, **kw)
    return SCHEDULES[config.schedule](model, params, cfg,
                                      n_slots=config.n_slots,
                                      max_len=config.max_len, **kw)


# -- legacy keyword path ----------------------------------------------------
#: every keyword the legacy `make_scheduler(**kw)` surface ever accepted,
#: by the section (or base) it folds into
_BASE_KW = ("sampling", "seed", "buckets", "stream", "program_cache",
            "target", "ctx")
_SLO_KW = ("slo_ms",)
_SPEC_KW = ("draft_depth", "draft", "drafter", "draft_ckpt",
            "draft_branches")
_PREFIX_KW = ("prefix_cache", "prefix_blocks", "prefix_block_size",
              "prefix_pool")
_CHUNK_KW = ("prefill_chunk",)


def make_scheduler(schedule: str, model, params, cfg, *, n_slots: int = 1,
                   max_len: int, **kw) -> _SchedulerBase:
    """Deprecated keyword shim over `ServeConfig` + `build_scheduler`.

    The old surface silently stripped schedule-inapplicable knobs (a typo'd
    or misplaced flag vanished without a trace). This shim keeps every
    historical call working but is loud: unknown keywords raise TypeError,
    knobs that do not apply to `schedule` warn before being dropped, and
    every call emits a DeprecationWarning pointing at ServeConfig."""
    warnings.warn(
        "make_scheduler(**kw) is deprecated: build a ServeConfig and call "
        "build_scheduler(config, model, params, cfg) instead",
        DeprecationWarning, stacklevel=2)
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule {schedule!r} not in {sorted(SCHEDULES)}")
    known = set(_BASE_KW) | set(_SLO_KW) | set(_SPEC_KW) | set(_PREFIX_KW) \
        | set(_CHUNK_KW) | {"max_in_flight"}
    unknown = sorted(set(kw) - known)
    if unknown:
        raise TypeError(
            f"make_scheduler got unknown keyword(s) {unknown}; known "
            f"keywords: {sorted(known)}")

    def strip(keys: tuple[str, ...], why: str) -> None:
        hit = [k for k in keys if kw.get(k) not in (None, False)]
        for k in keys:
            kw.pop(k, None)
        if hit:
            warnings.warn(
                f"make_scheduler: {hit} do(es) not apply to the "
                f"{schedule!r} schedule ({why}); dropped — ServeConfig "
                f"rejects this outright", UserWarning, stacklevel=3)

    if schedule != "slo":
        strip(_SLO_KW, "SLO admission gate is slo-only")
    if schedule != "spec":
        strip(_SPEC_KW, "drafter knobs are spec-only")
    if schedule not in ("continuous", "slo"):  # pool rides slot admission
        strip(_PREFIX_KW, "the prefix pool rides slot admission")
        strip(_CHUNK_KW, "chunked prefill rides slot admission")
    if schedule == "spec":
        strip(_CHUNK_KW, "spec admission stages target+drafter jointly")
    if schedule not in ("slo", "spec"):   # in-flight window is async-only
        strip(("max_in_flight",), "the in-flight window is async-only")

    sections: dict[str, Any] = {}
    if schedule == "slo":
        slo_kw = {}
        if "slo_ms" in kw:
            slo_kw["slo_ms"] = kw.pop("slo_ms")
        if "max_in_flight" in kw:
            slo_kw["max_in_flight"] = kw.pop("max_in_flight")
        if slo_kw:
            sections["slo"] = SLOConfig(**slo_kw)
    if schedule == "spec":
        spec_kw = {k: kw.pop(k) for k in
                   _SPEC_KW + ("max_in_flight",) if k in kw}
        if spec_kw:
            sections["spec"] = SpecConfig(**spec_kw)
    if kw.pop("prefix_cache", False) or kw.get("prefix_pool") is not None:
        sections["prefix"] = PrefixConfig(
            blocks=kw.pop("prefix_blocks", 64),
            block_size=kw.pop("prefix_block_size", 8),
            pool=kw.pop("prefix_pool", None))
    else:  # pool disabled: blocks/block_size had no effect before either
        for k in ("prefix_blocks", "prefix_block_size", "prefix_pool"):
            kw.pop(k, None)
    if kw.get("prefill_chunk") is not None:
        sections["chunk"] = ChunkConfig(prefill_chunk=kw.pop("prefill_chunk"))
    kw.pop("prefill_chunk", None)

    config = ServeConfig(schedule=schedule, max_len=max_len, n_slots=n_slots,
                         **kw, **sections)
    return build_scheduler(config, model, params, cfg)


# registers SCHEDULES["spec"]; the bottom import keeps the cycle harmless
# (this module is fully defined by the time speculative imports it back)
from repro.launch import speculative as _speculative  # noqa: E402,F401
