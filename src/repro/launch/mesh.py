"""Production meshes.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Under the dry-run's 512 forced host devices, the single-pod mesh takes
    the first 256 (one pod's worth)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before importing jax (see launch/dryrun.py)")
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def make_host_mesh(model_parallel: int | None = None) -> Mesh:
    """Whatever this host has (tests / examples): (data, model)."""
    n = len(jax.devices())
    if model_parallel is None:
        model_parallel = 2 if n % 2 == 0 and n > 1 else 1
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
