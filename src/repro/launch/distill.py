"""Drafter distillation: teach the shrink draft model the target's logits.

    PYTHONPATH=src python -m repro.launch.distill --arch tinyllama-1.1b \
        --smoke --teacher-steps 150 --steps 300 --ckpt-dir /tmp/distill

Speculative decoding only beats the dispatch floor when the drafter's
proposals actually match the target's picks (§9 economics: two floors per
window buy `accept + 1` tokens, so `E[accept] > 1` is the break-even). A
random-init `draft_of(cfg)` student shares no distribution with the target
— its acceptance is ~0 and every window is two floors for one token. This
driver fixes the root cause with a KL distillation loop wired through the
seed training stack, nothing bespoke:

  * **teacher** — the target model itself, trained (or loaded) with
    `launch/train.py`'s `make_train_step` on the synthetic motif corpus
    (`data/pipeline.py`): the motifs give next-token prediction real
    structure, so teacher and student have something to agree *about*.
  * **student** — `draft_of(cfg)`: one layer, same widths/vocab, built
    through `build_model` like every serving model.
  * **loss** — `kl_weight * T^2 * KL(teacher || student)` at temperature T
    plus `(1 - kl_weight)` hard-label cross entropy (the classic Hinton
    mix), stepped by the SAME `make_train_step` machinery via its
    `loss_fn=` hook — optimizer, clipping, schedule and donation discipline
    identical to pretraining. Teacher logits are precomputed per batch by
    one jitted teacher forward and ride the batch dict, so the student's
    step stays a pure `(params, opt_state, batch)` function.
  * **checkpoints** — `checkpoint/CheckpointManager` with a metadata
    sidecar (arch, vocab, d_model, weight form, final agreement):
    `Drafter.shrink(ckpt=...)` validates it loudly before serving, and a
    packed `--student-weight-form` saves `DispatchedWeight` form tags that
    round-trip intact.

The result feeds `--draft shrink --draft-ckpt` on the serve CLI and the
gated shrink-drafter row of `bench_spec_decode` — speculation winning
without self-drafting.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.speculative import draft_of
from repro.launch.train import make_train_step
from repro.models.layers import logits as logits_fn
from repro.models.model import _xent, build_model
from repro.optim import adamw

#: the student's data stream is the same motif distribution as the
#: teacher's (same DataConfig seed => same planted motifs) but a disjoint
#: slice of the step space, so distillation batches never replay teacher
#: training batches
STUDENT_STEP_OFFSET = 100_000

#: recipe defaults, validated end-to-end: ~0.95+ greedy rollout agreement
#: on held-out motif prompts for the smoke configs in ~20 s of CPU
DEFAULTS = dict(teacher_steps=150, steps=300, batch=8, seq=64, lr=3e-3,
                kl_weight=0.75, temperature=1.0)


def _full_logits(model, cfg, params, tokens):
    """fp32 (B, S, V-padded) logits of a full-context forward — the shared
    shape of the teacher's soft targets and the student's predictions."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h, _, _ = model.forward(params, tokens, positions, mode="train")
    with model._dispatch_scope():
        return logits_fn(cfg, params["embed"], h).astype(jnp.float32)


def make_teacher_logits_fn(teacher, cfg):
    """One jitted teacher forward: batch tokens -> fp32 logits. Runs once
    per distillation batch; its output rides the batch dict into the
    student's train step as `batch["teacher_logits"]`."""
    return jax.jit(lambda tparams, tokens:
                   _full_logits(teacher, cfg, tparams, tokens))


def make_distill_loss(student, vocab: int, *, kl_weight: float = 0.75,
                      temperature: float = 1.0):
    """`loss_fn(params, batch)` for `make_train_step`: temperature-scaled
    KL to the teacher + hard-label CE, with the teacher's top-1 agreement
    reported alongside (the quantity speculative acceptance tracks)."""
    if not 0.0 <= kl_weight <= 1.0:
        raise ValueError(f"kl_weight must be in [0, 1], got {kl_weight}")
    dcfg, T = student.cfg, float(temperature)

    def loss_fn(params, batch):
        tokens, teacher_lg = batch["tokens"], batch["teacher_logits"]
        lg = _full_logits(student, dcfg, params, tokens)
        vmask = jnp.arange(lg.shape[-1]) < vocab        # padded slots out
        lg = jnp.where(vmask, lg, -1e30)
        tl = jnp.where(vmask, teacher_lg.astype(jnp.float32), -1e30)
        logp_s = jax.nn.log_softmax(lg / T, axis=-1)
        logp_t = jax.nn.log_softmax(tl / T, axis=-1)
        p_t = jnp.exp(logp_t)
        kl = (T * T) * jnp.sum(p_t * (logp_t - logp_s), axis=-1).mean()
        ce, z = _xent(lg, batch["targets"], vocab)
        loss = kl_weight * kl + (1.0 - kl_weight) * ce + 1e-4 * z
        agree = (jnp.argmax(lg, axis=-1) == jnp.argmax(tl, axis=-1)) \
            .astype(jnp.float32).mean()
        return loss, {"loss": loss, "kl": kl, "ce": ce, "agree": agree}

    return loss_fn


def _fit(step_fn, params, opt_state, batches, *, log_every: int, tag: str):
    """The shared hot loop: jitted step, donated state, loss history."""
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    history: list[float] = []
    for t, batch in enumerate(batches):
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        if (t + 1) % log_every == 0:
            loss = float(metrics["loss"])
            history.append(loss)
            extras = "".join(f" {k} {float(v):.3f}"
                             for k, v in metrics.items()
                             if k in ("kl", "ce", "agree"))
            print(f"[{tag}] step {t + 1:5d} loss {loss:8.4f}{extras}",
                  flush=True)
    return params, history


def train_teacher(cfg, *, steps: int, batch: int, seq: int, lr: float,
                  seed: int = 0, log_every: int = 50):
    """Train the target on the motif corpus: (teacher, params, history).

    The reproduction has no pretrained weights, so the teacher IS this run
    — what matters for speculation is that teacher and student share a
    learned distribution, which random init never gives."""
    teacher = build_model(cfg)
    params = teacher.init(jax.random.PRNGKey(seed))
    opt_cfg = adamw.AdamWConfig(peak_lr=lr, warmup_steps=max(steps // 15, 5),
                                total_steps=steps)
    opt_state = adamw.init_state(opt_cfg, params)
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                 global_batch=batch, seed=seed))
    batches = ({k: jnp.asarray(v) for k, v in src.batch(t).items()}
               for t in range(steps))
    params, history = _fit(make_train_step(teacher, opt_cfg), params,
                           opt_state, batches, log_every=log_every,
                           tag="teacher")
    return teacher, params, history


def distill_student(cfg, teacher, tparams, *, steps: int, batch: int,
                    seq: int, lr: float, kl_weight: float,
                    temperature: float, seed: int = 0,
                    log_every: int = 50):
    """Distill `draft_of(cfg)` against the teacher: (student, params,
    history). Same step machinery as pretraining, loss swapped through the
    `loss_fn=` hook; constant-after-warmup schedule (a distillation budget
    is not a convergence horizon)."""
    dcfg = draft_of(cfg)
    student = build_model(dcfg)
    params = student.init(jax.random.PRNGKey(seed + 1))
    opt_cfg = adamw.AdamWConfig(peak_lr=lr, warmup_steps=max(steps // 30, 5),
                                total_steps=steps, schedule_kind="constant")
    opt_state = adamw.init_state(opt_cfg, params)
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                 global_batch=batch, seed=seed))
    teacher_fn = make_teacher_logits_fn(teacher, cfg)
    loss_fn = make_distill_loss(student, cfg.vocab, kl_weight=kl_weight,
                                temperature=temperature)

    def batches():
        for t in range(steps):
            b = {k: jnp.asarray(v)
                 for k, v in src.batch(STUDENT_STEP_OFFSET + t).items()}
            b["teacher_logits"] = teacher_fn(tparams, b["tokens"])
            yield b

    params, history = _fit(
        make_train_step(student, opt_cfg, loss_fn=loss_fn), params,
        opt_state, batches(), log_every=log_every, tag="distill")
    return student, params, history


def rollout_agreement(cfg, teacher, tparams, student, sparams, *,
                      n_prompts: int = 16, prompt_len: int = 24,
                      steps: int = 12, seed: int = 7) -> float:
    """Held-out greedy rollout agreement: roll the TEACHER forward greedily
    from fresh motif prompts and score the student's stepwise top-1 match —
    the off-policy estimate of shrink-drafter acceptance."""
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=prompt_len,
                                 global_batch=n_prompts, seed=seed))
    ctx = jnp.asarray(src.prompt_batch(0, n_prompts, prompt_len))
    t_row = jax.jit(lambda p, toks:
                    _full_logits(teacher, cfg, p, toks)[:, -1, :cfg.vocab])
    s_row = jax.jit(lambda p, toks:
                    _full_logits(student, student.cfg, p,
                                 toks)[:, -1, :cfg.vocab])
    hits = total = 0
    for _ in range(steps):
        t_pick = np.asarray(jnp.argmax(t_row(tparams, ctx), axis=-1))
        s_pick = np.asarray(jnp.argmax(s_row(sparams, ctx), axis=-1))
        hits += int((t_pick == s_pick).sum())
        total += t_pick.size
        ctx = jnp.concatenate(
            [ctx, jnp.asarray(t_pick[:, None], jnp.int32)], axis=1)
    return hits / max(total, 1)


def _metadata(cfg, role: str, *, weight_form: str = "fp16",
              **extra) -> dict:
    return {"role": role, "arch": cfg.name, "vocab": int(cfg.vocab),
            "d_model": int(cfg.d_model), "n_layers": int(cfg.n_layers),
            "weight_form": weight_form, **extra}


def load_teacher(cfg, ckpt_dir: str):
    """(teacher, params) from a distill checkpoint directory's teacher/
    subtree, metadata-validated against `cfg` before any array loads."""
    teacher = build_model(cfg)
    mgr = CheckpointManager(ckpt_dir)
    meta = mgr.metadata() or {}
    for key, want in (("vocab", cfg.vocab), ("d_model", cfg.d_model)):
        got = meta.get(key)
        if got is not None and int(got) != int(want):
            raise ValueError(
                f"teacher checkpoint {ckpt_dir!r} was trained with "
                f"{key}={got}, but the requested config {cfg.name!r} has "
                f"{key}={want}")
    template = jax.eval_shape(teacher.init, jax.random.PRNGKey(0))
    params, _ = mgr.restore(template)
    return teacher, jax.tree.map(jnp.asarray, params)


def distill_pipeline(cfg, *, teacher_steps: int, steps: int, batch: int,
                     seq: int, lr: float, kl_weight: float,
                     temperature: float, seed: int = 0,
                     teacher_ckpt: str | None = None,
                     eval_steps: int = 12, log_every: int = 50) -> dict:
    """The whole recipe as a library call (the bench runs it inline when no
    `--distill-dir` is given): train-or-load teacher, distill student,
    measure held-out rollout agreement."""
    if teacher_ckpt:
        teacher, tparams = load_teacher(cfg, teacher_ckpt)
        teacher_history: list[float] = []
    else:
        teacher, tparams, teacher_history = train_teacher(
            cfg, steps=teacher_steps, batch=batch, seq=seq, lr=lr,
            seed=seed, log_every=log_every)
    student, sparams, history = distill_student(
        cfg, teacher, tparams, steps=steps, batch=batch, seq=seq, lr=lr,
        kl_weight=kl_weight, temperature=temperature, seed=seed,
        log_every=log_every)
    agree = rollout_agreement(cfg, teacher, tparams, student, sparams,
                              steps=eval_steps, seed=seed + 7)
    return {"cfg": cfg, "teacher": teacher, "teacher_params": tparams,
            "teacher_history": teacher_history, "student": student,
            "student_cfg": student.cfg, "student_params": sparams,
            "history": history, "agreement": agree}


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES + ["ane-paper"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--teacher-steps", type=int,
                    default=DEFAULTS["teacher_steps"])
    ap.add_argument("--teacher-ckpt", default="",
                    help="load the teacher from this checkpoint directory "
                         "instead of training one")
    ap.add_argument("--steps", type=int, default=DEFAULTS["steps"],
                    help="distillation steps for the student")
    ap.add_argument("--batch", type=int, default=DEFAULTS["batch"])
    ap.add_argument("--seq", type=int, default=DEFAULTS["seq"])
    ap.add_argument("--lr", type=float, default=DEFAULTS["lr"])
    ap.add_argument("--kl-weight", type=float,
                    default=DEFAULTS["kl_weight"],
                    help="soft-target weight; 1 - kl_weight goes to the "
                         "hard-label CE")
    ap.add_argument("--temperature", type=float,
                    default=DEFAULTS["temperature"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="",
                    help="write teacher/ and student/ checkpoints (with "
                         "metadata sidecars) under this directory")
    ap.add_argument("--student-weight-form", default="fp16",
                    choices=("fp16", "int4_palette", "sparse"),
                    help="pack the student checkpoint into this streamed "
                         "form; `Drafter.shrink(ckpt=...)` restores the "
                         "DispatchedWeight tags intact")
    ap.add_argument("--eval-steps", type=int, default=12,
                    help="held-out teacher-rollout length for the "
                         "agreement report")
    ap.add_argument("--log-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    out = distill_pipeline(
        cfg, teacher_steps=args.teacher_steps, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr,
        kl_weight=args.kl_weight, temperature=args.temperature,
        seed=args.seed, teacher_ckpt=args.teacher_ckpt or None,
        eval_steps=args.eval_steps, log_every=args.log_every)

    if args.ckpt_dir:
        import os

        from repro.optim.compression import compress_model_params
        tmgr = CheckpointManager(os.path.join(args.ckpt_dir, "teacher"))
        tmgr.save(args.teacher_steps, out["teacher_params"],
                  metadata=_metadata(cfg, "teacher"))
        sparams = out["student_params"]
        if args.student_weight_form != "fp16":
            sparams = compress_model_params(sparams,
                                            args.student_weight_form)
        smgr = CheckpointManager(os.path.join(args.ckpt_dir, "student"))
        smgr.save(args.steps, sparams,
                  metadata=_metadata(
                      out["student_cfg"], "draft-student",
                      weight_form=args.student_weight_form,
                      target_arch=cfg.name,
                      agreement_top1=float(out["agreement"])))
        print(f"-> {args.ckpt_dir}/teacher, {args.ckpt_dir}/student "
              f"({args.student_weight_form})")

    first = out["history"][0] if out["history"] else float("nan")
    last = out["history"][-1] if out["history"] else float("nan")
    print(f"distilled {out['student_cfg'].name}: loss {first:.3f} -> "
          f"{last:.3f}, held-out teacher-rollout agreement "
          f"{out['agreement']:.3f}")
    return {"loss_history": out["history"],
            "teacher_history": out["teacher_history"],
            "agreement": out["agreement"],
            "arch": cfg.name, "student_arch": out["student_cfg"].name}


if __name__ == "__main__":
    run()
