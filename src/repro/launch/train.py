"""Training driver: compile-once / dispatch-many, fault-tolerant, sharded.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Structure (paper ch. 2 applied to a training fleet):
  * compile phase: one jit'd train_step, content-hash cached; params and
    optimizer state are donated (resident across dispatches — the only host
    crossings are data in and checkpoints out);
  * dispatch phase: the hot loop binds a fresh batch and posts the step;
  * fault tolerance: async checkpoints every N steps, watchdog + supervisor
    restarts from the latest committed step, deterministic data resume.
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import make_pipeline
from repro.models.model import build_model
from repro.optim import adamw
from repro.optim.compression import (compress_grads, decompress_grads,
                                     init_residual)
from repro.parallel import sharding as shard_lib
from repro.parallel.ctx import ParallelContext
from repro.runtime.fault_tolerance import RestartPolicy, Watchdog, run_with_restarts


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    grad_compression: str = "none", *, loss_fn=None):
    """The jitted step: loss -> grads -> (optional int8 error-feedback
    compression round-trip) -> AdamW. Donated state never re-crosses the
    host.

    `loss_fn(params, batch) -> (loss, metrics)` overrides `model.loss` —
    the hook the distillation driver uses to train a student against
    teacher logits through this exact step machinery (same compression,
    same optimizer, same donation discipline)."""
    loss_fn = model.loss if loss_fn is None else loss_fn

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if grad_compression == "int8":
            comp, residual = compress_grads(grads, opt_state.get("residual"))
            grads = decompress_grads(comp, grads)
            opt_state = dict(opt_state, residual=residual)
        new_params, new_opt, om = adamw.apply_updates(
            opt_cfg, params, grads, {k: v for k, v in opt_state.items()
                                     if k != "residual"})
        if "residual" in opt_state:
            new_opt["residual"] = opt_state["residual"]
        return new_params, new_opt, {**metrics, **om}

    return train_step


def shard_args(model, params, opt_state, batch_like, ctx: ParallelContext):
    """(param_specs, opt_specs, batch_specs) pytrees for jit shardings."""
    pspecs = shard_lib.param_specs(params, ctx)
    ospecs = shard_lib.opt_state_specs(opt_state, pspecs, ctx,
                                       zero1=ctx.zero1)
    if "residual" in opt_state:
        ospecs = dict(ospecs, residual=shard_lib.param_specs(
            opt_state["residual"], ctx))
    bspecs = shard_lib.batch_specs(batch_like, ctx)
    return pspecs, ospecs, bspecs


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES + ["ane-paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="host", choices=["host", "none"])
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.mesh == "host" and len(jax.devices()) > 1:
        from repro.launch.mesh import make_host_mesh
        ctx = ParallelContext(mesh=make_host_mesh())
    else:
        ctx = ParallelContext(mesh=None)
    model = build_model(cfg, ctx)
    opt_cfg = adamw.AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                                total_steps=args.steps)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    pipe_src = make_pipeline(cfg, args.seq, args.batch, seed=args.seed)

    step_fn = make_train_step(model, opt_cfg, args.grad_compression)
    history: list[float] = []

    def training_run(start_step: int) -> int:
        params = model.init(jax.random.PRNGKey(args.seed))
        opt_state = adamw.init_state(opt_cfg, params)
        if args.grad_compression == "int8":
            opt_state["residual"] = init_residual(params)
        step = 0
        if start_step == -1 and mgr is not None and mgr.latest_step() is not None:
            (params, opt_state), step = mgr.restore((params, opt_state))
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)

        batch0 = pipe_src._source.batch(step)
        if ctx.active:
            pspecs, ospecs, bspecs = shard_args(model, params, opt_state,
                                                batch0, ctx)
            jit_step = jax.jit(
                step_fn, donate_argnums=(0, 1),
                in_shardings=(pspecs, ospecs, bspecs),
                out_shardings=(pspecs, ospecs, None))
        else:
            jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        watchdog = Watchdog(deadline_s=600.0)
        t_start = time.perf_counter()
        while step < args.steps:
            batch = {k: jnp.asarray(v) for k, v in
                     pipe_src._source.batch(step).items()}
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            watchdog.poke()
            step += 1
            if step % args.log_every == 0 or step == args.steps:
                loss = float(metrics["loss"])
                history.append(loss)
                dt = (time.perf_counter() - t_start) / step
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.1f} ms/step", flush=True)
            if mgr is not None and step % args.ckpt_every == 0:
                mgr.save_async(step, (params, opt_state))
        if mgr is not None:
            mgr.save(args.steps, (params, opt_state))
        return step

    final = run_with_restarts(training_run, policy=RestartPolicy(max_restarts=2))
    pipe_src.close()
    return {"final_step": final, "loss_history": history,
            "final_loss": history[-1] if history else float("nan")}


if __name__ == "__main__":
    out = run()
    print(f"done: step {out['final_step']} final loss {out['final_loss']:.4f}")
