"""Serving driver: batched prefill + decode with resident caches.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 64 --gen 32

The paper's serving shape (ch. 2/14): compile once, keep the KV cache
resident on-device across steps (donated buffers), send only the small
per-step token, read logits back. Batched requests amortize the dispatch
floor (§9.4: batching to 512 drops per-sample cost ~127x)."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.parallel.ctx import ParallelContext


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES + ["ane-paper"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    model = build_model(cfg, ParallelContext(mesh=None))
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_len, cfg.d_model)), model.dtype)

    max_len = s + args.gen
    # compile once (content-hash cached), dispatch many
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    pf_caches, logits = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # move prefill caches into decode-sized buffers
    caches = model.init_cache(b, max_len)
    caches = _merge_prefill(model, caches, pf_caches, s)

    tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1
                     ).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        caches, logits = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1
                         ).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks_per_s = b * (args.gen - 1) / max(t_decode, 1e-9)
    gen = np.concatenate(out_tokens, axis=1)
    print(f"prefill {b}x{s}: {t_prefill*1e3:.1f} ms | "
          f"decode {args.gen-1} steps: {t_decode*1e3:.1f} ms "
          f"({toks_per_s:.1f} tok/s)")
    return {"tokens": gen, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": toks_per_s}


def _merge_prefill(model, caches, pf_caches, prompt_len: int):
    """Copy prefill cache contents into the (larger) decode buffers."""
    def merge(dst, src):
        if dst is None or src is None:
            return dst
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        if dst.ndim == src.ndim:
            # same rank, longer time axis somewhere: dynamic update at 0
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return dst
    return jax.tree.map(merge, caches, pf_caches)


if __name__ == "__main__":
    run()
