"""Serving driver: dispatcher-routed batched prefill + decode, compile-once.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 64 --gen 32 --weight-form int4_palette

The paper's serving shape (ch. 2/5/14), end to end:

  * **op-by-device routing** — the model is built with a
    `KernelDispatcher` for the configured HAL target, so every projection,
    MLP, MoE expert, attention and logits matmul resolves against the
    kernel registry: `anemm` for dense weights, `palette`/`sparse` for
    packed ones (`--weight-form`), with oracle fallback wherever the target
    gates the op/form/dtype (`--target ane-m1` exercises it live).
  * **compile once, dispatch many** — prefill and decode compile through
    the content-hash `ProgramCache`; a second identical request hits the
    cache (the anehash warm start, §5.6).
  * **resident state** — the KV cache is a donated argument of the decode
    program: the held buffer never re-crosses the host between steps.

Batched requests amortize the dispatch floor (§9.4: batching to 512 drops
per-sample cost ~127x)."""

from __future__ import annotations

import argparse
import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import KernelDispatcher, ProgramCache
from repro.models.model import build_model
from repro.optim.compression import compress_model_params
from repro.parallel.ctx import ParallelContext

WEIGHT_FORMS = ("fp16", "int4_palette", "sparse")


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES + ["ane-paper"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--weight-form", default="fp16", choices=WEIGHT_FORMS,
                    help="pack matmul weights into this streamed form")
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS),
                    help="HAL target whose capability surface gates routing")
    ap.add_argument("--no-dispatch", action="store_true",
                    help="bypass the dispatcher (seed dense path; "
                         "incompatible with a packed --weight-form)")
    ap.add_argument("--requests", type=int, default=1,
                    help="identical request rounds; round 2+ must hit the "
                         "program cache")
    args = ap.parse_args(argv)

    if args.no_dispatch and args.weight_form != "fp16":
        ap.error("packed weight forms require the dispatcher")

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    dispatcher = None if args.no_dispatch else \
        KernelDispatcher(hal.get_target(args.target))
    model = build_model(cfg, ParallelContext(mesh=None), dispatcher=dispatcher)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.weight_form != "fp16":
        params = compress_model_params(params, args.weight_form)

    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_len, cfg.d_model)), model.dtype)

    max_len = s + args.gen
    program_cache = ProgramCache()
    out: dict = {}
    for _ in range(max(args.requests, 1)):
        out = _serve_one(model, params, batch, program_cache, cfg, args,
                         max_len)
    out["cache_hits"] = program_cache.stats.hits
    out["cache_misses"] = program_cache.stats.misses
    if dispatcher is not None:
        out["routes"] = dict(Counter(
            (r.kernel, r.backend) for r in dispatcher.routes))
    return out


def _serve_one(model, params, batch, program_cache: ProgramCache, cfg, args,
               max_len: int) -> dict:
    """One request round: compile-or-hit prefill + decode, then the decode
    loop with the cache buffers donated (resident) across dispatches."""
    b, s = batch["tokens"].shape

    prefill, _ = program_cache.compile(model.prefill, params, batch)
    t0 = time.perf_counter()
    pf_caches, logits = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # move prefill caches into decode-sized buffers
    caches = model.init_cache(b, max_len)
    caches = _merge_prefill(model, caches, pf_caches, s)

    tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1
                     ).astype(jnp.int32)[:, None]
    pos0 = jnp.full((b,), s, jnp.int32)
    decode, _ = program_cache.compile(
        model.decode_step, params, caches, tok, pos0,
        jit_kwargs={"donate_argnums": (1,)})

    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        caches, logits = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1
                         ).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks_per_s = b * (args.gen - 1) / max(t_decode, 1e-9)
    gen = np.concatenate(out_tokens, axis=1)
    print(f"prefill {b}x{s}: {t_prefill*1e3:.1f} ms | "
          f"decode {args.gen-1} steps: {t_decode*1e3:.1f} ms "
          f"({toks_per_s:.1f} tok/s) | program cache "
          f"h{program_cache.stats.hits}/m{program_cache.stats.misses}")
    return {"tokens": gen, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": toks_per_s}


def _merge_prefill(model, caches, pf_caches, prompt_len: int):
    """Copy prefill cache contents into the (larger) decode buffers."""
    def merge(dst, src):
        if dst is None or src is None:
            return dst
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        if dst.ndim == src.ndim:
            # same rank, longer time axis somewhere: dynamic update at 0
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return dst
    return jax.tree.map(merge, caches, pf_caches)


if __name__ == "__main__":
    run()
