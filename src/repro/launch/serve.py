"""Serving driver: a thin CLI over the continuous-batching scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 64 --gen 32 --weight-form int4_palette \
        --schedule continuous --sampling greedy

The paper's serving shape (ch. 2/5/14), end to end:

  * **op-by-device routing** — the model is built with a
    `KernelDispatcher` for the configured HAL target, so every projection,
    MLP, MoE expert, attention and logits matmul resolves against the
    kernel registry: `anemm` for dense weights, `palette`/`sparse` for
    packed ones (`--weight-form`), with oracle fallback wherever the target
    gates the op/form/dtype (`--target ane-m1` exercises it live).
  * **compile once, dispatch many** — prefill and decode compile through
    the content-hash `ProgramCache`; prompt-length bucketing bounds the
    prefill shape set, so a stream of heterogeneous requests warm-starts
    from at most `#buckets` prefill programs + 1 decode program (the
    anehash warm start, §5.6).
  * **resident state** — the shared multi-lane KV cache is a donated
    argument of the decode program: the held buffer never re-crosses the
    host between steps, and admission writes new requests into free lanes
    in place.
  * **dispatch-floor amortization** — every model dispatch goes through an
    `ExecutionStream` whose records charge the costmodel floor estimate
    per call; `--schedule continuous` shares each decode dispatch across
    all active lanes (§9.4: batching to 512 drops per-sample cost ~127x),
    while `--schedule sequential` is the un-amortized one-request-at-a-time
    parity reference.
  * **overlapped streams** — `--schedule slo` pipelines decode on an
    `AsyncExecutionStream` (encode step N+1 while step N executes, on-device
    sampling, bounded `--max-in-flight` window) and gates admission on the
    costmodel-predicted token latency against `--slo-ms` (the paper's
    unfinished overlapping-streams path, §2.4).
  * **chunked prefill** — `--prefill-chunk C` admits a long prompt as a
    sequence of fixed-size chunk programs (one ProgramCache entry per chunk
    size) written incrementally into the lane's cache, with decode windows
    between chunks: the SLO admission gate schedules each chunk like any
    other dispatch, so in-flight decodes never stall behind one monolithic
    prefill — and greedy token streams stay bit-identical to unchunked.
    `--ring-prefill-min N` (mesh only) routes monolithic prefills of >= N
    tokens through ring attention over the "model" axis — the
    context-parallel path for prompts beyond one device's cache slab.
  * **speculative decoding** — `--schedule spec` serves draft->verify
    windows on the async stream: a drafter (`--draft shrink` depth-pruned
    second model, optionally loaded from a `launch.distill` checkpoint via
    `--draft-ckpt` / `--draft self` the target itself) proposes
    `--draft-depth` tokens — or `--draft-branches` sibling chains of them
    (tree verification) — in one dispatch, and one fused verify dispatch
    resamples them on device through the `specdec` kernel — two dispatch
    floors buy up to depth+1 tokens (§9 economics), token-exact against
    the sequential reference.

  * **multi-host mesh serving** — `--mesh-shape 4x2` runs the same
    scheduler under a device mesh: lanes (the decode batch dim) shard over
    the "data" axis, packed MoE expert banks shard over the "model" axis
    (the EP `shard_map` path), and the token streams stay bit-identical to
    the single-device run. `--evacuate-on-failure` (with `--fail-host N`
    to inject a loss) wraps the loop in the `ServeSupervisor`: heartbeats
    every tick, and on host loss the mesh shrinks to the survivors and the
    lost lanes re-admit token-exact.

All scheduling logic lives in `repro.launch.scheduler`; this module only
parses arguments, builds the model/requests, and reports.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import Counter

import jax
import numpy as np

from repro import configs
from repro.core import hal
from repro.core.dispatch import (AsyncExecutionStream, ExecutionStream,
                                 KernelDispatcher, ProgramCache)
from repro.launch.scheduler import (SAMPLING_MODES, SCHEDULES, ChunkConfig,
                                    PrefixConfig, Request, ServeConfig,
                                    SLOConfig, SpecConfig, build_scheduler,
                                    merge_prefill_caches)
from repro.launch.speculative import DRAFT_KINDS
from repro.models.model import build_model
from repro.optim.compression import compress_model_params
from repro.parallel.ctx import ParallelContext
from repro.runtime.supervisor import FailureInjection, ServeSupervisor

WEIGHT_FORMS = ("fp16", "int4_palette", "sparse")

_MESH_NAMES = {2: ("data", "model"), 3: ("pod", "data", "model")}


def parse_mesh(spec: str) -> ParallelContext:
    """'4x2' -> a ("data","model") mesh context; '' -> the null context.

    Two dims shard lanes over "data" and MoE expert banks over "model";
    three dims add a leading "pod" axis that also carries lanes (the
    cache/batch rules shard dim 0 over ("pod","data") jointly)."""
    if not spec:
        return ParallelContext(mesh=None)
    dims = tuple(int(x) for x in spec.lower().split("x"))
    names = _MESH_NAMES.get(len(dims))
    if names is None or any(d < 1 for d in dims):
        raise ValueError(f"--mesh-shape {spec!r}: want 2 or 3 positive "
                         "'x'-separated dims, e.g. 4x2 or 2x2x2")
    need = int(np.prod(dims))
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"--mesh-shape {spec} wants {need} devices, {have} visible "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "fakes them on CPU)")
    return ParallelContext(mesh=jax.make_mesh(dims, names))


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCH_NAMES + ["ane-paper"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode lanes (continuous) / requests per round")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated per-request prompt lengths "
                         "(heterogeneous round; overrides --prompt-len)")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="continuous",
                    choices=sorted(SCHEDULES),
                    help="continuous = slot-masked batched decode with "
                         "mid-flight admission; slo = overlapped decode "
                         "(async stream) with SLO-aware admission; "
                         "spec = speculative draft->verify windows on the "
                         "async stream (--draft-depth proposals per window, "
                         "fused on-device verify/accept); sequential = one "
                         "request at a time (parity reference)")
    ap.add_argument("--draft-depth", type=int, default=4,
                    help="spec schedule only: drafter proposals per window "
                         "(each window pays two dispatch floors for up to "
                         "draft-depth + 1 emitted tokens)")
    ap.add_argument("--draft", default="shrink", choices=DRAFT_KINDS,
                    help="spec schedule only: 'shrink' builds a depth-pruned "
                         "draft model from the target config (the real "
                         "two-model path; random-init unless --draft-ckpt "
                         "serves distilled weights), 'self' drafts with the "
                         "target itself (the agreement ceiling)")
    ap.add_argument("--draft-ckpt", default="",
                    help="spec schedule only: a `launch.distill` checkpoint "
                         "directory (the student/ subdir) with distilled "
                         "shrink-drafter weights; vocab/width mismatches "
                         "are rejected loudly at load")
    ap.add_argument("--draft-branches", type=int, default=1,
                    help="spec schedule only: sibling draft chains per lane "
                         "(tree verification; branch at the window root on "
                         "the drafter's top-N, one verify dispatch scores "
                         "the whole tree)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="slo schedule only: admit a queued request only "
                         "while the costmodel-predicted token latency stays "
                         "under this many milliseconds (default: no limit)")
    ap.add_argument("--max-in-flight", type=int, default=8,
                    help="slo schedule only: bounded in-flight submission "
                         "window of the async stream")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous/slo schedules: route admissions through "
                         "the block-paged KV pool — a request whose prompt "
                         "prefix is resident (shared system prompt, repeated "
                         "round) admits with one gather dispatch instead of "
                         "re-prefilling the matched blocks")
    ap.add_argument("--prefix-blocks", type=int, default=64,
                    help="prefix cache only: arena capacity in blocks")
    ap.add_argument("--prefix-block-size", type=int, default=8,
                    help="prefix cache only: tokens per block (should divide "
                         "the prefill buckets, or chains never anchor)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous/slo schedules: admit a long prompt as "
                         "fixed-size chunk programs (one ProgramCache entry "
                         "per chunk size) written incrementally into the "
                         "lane, with decode windows between chunks — the "
                         "SLO gate schedules each chunk like any dispatch "
                         "instead of stalling behind a monolithic prefill")
    ap.add_argument("--ring-prefill-min", type=int, default=None,
                    help="with --mesh-shape only: route monolithic prefills "
                         "of at least this many tokens through ring "
                         "attention (context-parallel over the 'model' "
                         "axis); default off — keeps mesh streams "
                         "bit-identical to single-device")
    ap.add_argument("--ckpt", default="",
                    help="load target params from this CheckpointManager "
                         "directory (e.g. a `launch.distill` run's teacher/ "
                         "subdir, so a --draft-ckpt student speculates for "
                         "the teacher it was distilled against) instead of "
                         "random init")
    ap.add_argument("--sampling", default="greedy", choices=SAMPLING_MODES,
                    help="greedy argmax or seeded categorical sampling")
    ap.add_argument("--weight-form", default="fp16", choices=WEIGHT_FORMS,
                    help="pack matmul weights into this streamed form")
    ap.add_argument("--target", default="tpu-v5e",
                    choices=sorted(hal.TARGETS),
                    help="HAL target whose capability surface gates routing "
                         "(also sets the costmodel dispatch floor)")
    ap.add_argument("--no-dispatch", action="store_true",
                    help="bypass the dispatcher (seed dense path; "
                         "incompatible with a packed --weight-form)")
    ap.add_argument("--requests", type=int, default=1,
                    help="identical request rounds; round 2+ must hit the "
                         "program cache")
    ap.add_argument("--mesh-shape", default="",
                    help="serve on a device mesh, e.g. '4x2' = lanes over a "
                         "4-way 'data' axis x MoE expert banks over a 2-way "
                         "'model' axis (3 dims: pod x data x model); token "
                         "streams stay bit-identical to the null mesh")
    ap.add_argument("--evacuate-on-failure", action="store_true",
                    help="continuous/slo: wrap the scheduler in the "
                         "ServeSupervisor — heartbeat every tick, watchdog "
                         "on hangs, and on host loss shrink the mesh to the "
                         "survivors and re-admit the lost host's lanes "
                         "token-exact")
    ap.add_argument("--fail-host", type=int, default=-1,
                    help="inject a failure of this host (batch-axis rank) "
                         "mid-stream to exercise evacuation; -1 = none "
                         "(implies --evacuate-on-failure)")
    ap.add_argument("--fail-at-step", type=int, default=3,
                    help="scheduler tick the injected failure fires at")
    ap.add_argument("--fail-kind", default="vanish",
                    choices=("vanish", "hang"),
                    help="vanish = host stops heartbeating; hang = one tick "
                         "stalls past the watchdog deadline")
    args = ap.parse_args(argv)

    if args.no_dispatch and args.weight_form != "fp16":
        ap.error("packed weight forms require the dispatcher")
    use_supervisor = args.evacuate_on_failure or args.fail_host >= 0
    if use_supervisor and args.schedule not in ("continuous", "slo"):
        ap.error(f"--evacuate-on-failure serves --schedule continuous or "
                 f"slo, not {args.schedule}")
    try:
        ctx = parse_mesh(args.mesh_shape)
    except ValueError as e:
        ap.error(str(e))
    if args.ring_prefill_min is not None:
        if not ctx.active or ctx.axis_size("model") <= 1:
            ap.error("--ring-prefill-min needs --mesh-shape with a >1 "
                     "'model' axis: the ring rotates KV over that axis")
        # consumed at model build: attention's prefill branch reads it off
        # the context, so the route is baked into the compiled program
        ctx = dataclasses.replace(ctx,
                                  ring_prefill_min=args.ring_prefill_min)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    target = hal.get_target(args.target)
    dispatcher = None if args.no_dispatch else KernelDispatcher(target)
    model = build_model(cfg, ctx, dispatcher=dispatcher)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.ckpt:
        from repro.checkpoint.checkpoint import CheckpointManager
        template = jax.eval_shape(model.init, jax.random.PRNGKey(args.seed))
        params, step = CheckpointManager(args.ckpt).restore(template)
        params = jax.tree.map(jax.numpy.asarray, params)
        print(f"loaded target params from {args.ckpt} (step {step})")
    if args.weight_form != "fp16":
        params = compress_model_params(params, args.weight_form)

    # one round's requests, identical across rounds (warm-start discipline)
    if args.prompt_lens:
        lens = [int(x) for x in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len] * args.batch
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
               for L in lens]
    frames = None
    if cfg.family == "encdec":
        frames = [np.asarray(rng.normal(size=cfg.frame_shape),
                             np.float32) for _ in lens]
    max_len = max(lens) + args.gen

    program_cache = ProgramCache()
    if args.schedule in ("slo", "spec"):
        stream = AsyncExecutionStream(program_cache, target=target,
                                      max_in_flight=args.max_in_flight)
    else:
        stream = ExecutionStream(program_cache, target=target)

    # typed serve configuration: each schedule-specific knob group is a
    # section, and ServeConfig.validate() rejects a section the chosen
    # schedule cannot apply — a misplaced flag fails here, loudly, instead
    # of vanishing into a silently-stripped kwarg
    slo_cfg = SLOConfig(slo_ms=args.slo_ms,
                        max_in_flight=args.max_in_flight) \
        if args.schedule == "slo" else None
    spec_cfg = SpecConfig(draft_depth=args.draft_depth, draft=args.draft,
                          draft_ckpt=args.draft_ckpt or None,
                          draft_branches=args.draft_branches,
                          max_in_flight=args.max_in_flight) \
        if args.schedule == "spec" else None
    prefix_cfg = PrefixConfig(blocks=args.prefix_blocks,
                              block_size=args.prefix_block_size) \
        if args.prefix_cache else None
    chunk_cfg = ChunkConfig(prefill_chunk=args.prefill_chunk,
                            ring_min=args.ring_prefill_min) \
        if (args.prefill_chunk is not None
            or args.ring_prefill_min is not None) else None

    def make_sched(sctx, pool):
        # the supervisor rebuilds the scheduler on the shrunken mesh after
        # an evacuation; the stream (floor ledger) and program cache carry
        # across, the paged pool rides in via prefix.pool. The model's
        # internal sharding constraints are baked against its build mesh,
        # so a rescaled context needs a rebuilt model closure (params are
        # mesh-independent and re-place through the scheduler).
        m = model if sctx is ctx else build_model(cfg, sctx,
                                                  dispatcher=dispatcher)
        pfx = prefix_cfg
        if pool is not None:
            pfx = dataclasses.replace(prefix_cfg or PrefixConfig(),
                                      pool=pool)
        config = ServeConfig(schedule=args.schedule, max_len=max_len,
                             n_slots=args.batch, sampling=args.sampling,
                             seed=args.seed, stream=stream, ctx=sctx,
                             slo=slo_cfg, spec=spec_cfg, prefix=pfx,
                             chunk=chunk_cfg)
        try:
            return build_scheduler(config, m, params, cfg)
        except ValueError as e:
            ap.error(str(e))

    supervisor = None
    if use_supervisor:
        injection = None
        if args.fail_host >= 0:
            injection = FailureInjection(host=args.fail_host,
                                         at_step=args.fail_at_step,
                                         kind=args.fail_kind)
        supervisor = ServeSupervisor(make_sched, ctx, injection=injection)
        engine = supervisor
    else:
        engine = make_sched(ctx, None)

    results = []
    t0 = time.perf_counter()
    for r in range(max(args.requests, 1)):
        reqs = [Request(rid=r * len(lens) + i, prompt=prompts[i],
                        max_new_tokens=args.gen,
                        frames=None if frames is None else frames[i])
                for i in range(len(lens))]
        results = supervisor.serve(reqs) if supervisor is not None \
            else engine.run(reqs)
    wall = time.perf_counter() - t0

    n_requests = len(lens) * max(args.requests, 1)
    total_tokens = args.gen * n_requests
    stats = engine.stats(n_requests)
    # serving throughput excludes AOT compilation (the ProgramCache tracks
    # its own compile seconds); a cold first round is compile-dominated
    serve_wall = max(wall - program_cache.stats.compile_seconds, 1e-9)
    out = {
        "tokens": np.stack([r.tokens for r in results]),
        "schedule": args.schedule,
        "sampling": args.sampling,
        "wall_s": wall,
        "compile_s": program_cache.stats.compile_seconds,
        "tok_per_s": total_tokens / serve_wall,
        "cache_hits": program_cache.stats.hits,
        "cache_misses": program_cache.stats.misses,
        "results": results,
        **stats,
    }
    if dispatcher is not None:
        out["routes"] = dict(Counter(
            (r.kernel, r.backend) for r in dispatcher.routes))
    prefix_note = ""
    if args.prefix_cache:
        pc = stats["prefix_cache"]
        prefix_note = (f" | prefix cache: {pc['hits']} hits / "
                       f"{pc['misses']} misses, {pc['hit_tokens']} prefill "
                       f"tokens skipped, {pc['evictions']} evictions")
    chunk_note = ""
    if args.prefill_chunk is not None:
        cp = stats["chunked_prefill"]
        chunk_note = (f" | chunked prefill C={cp['prefill_chunk']}: "
                      f"{cp['n_chunks']} chunks / {cp['chunk_tokens']} "
                      f"prompt tokens")
    mesh_note = ""
    if ctx.active:
        mesh_note = (f" | mesh {args.mesh_shape}: {stats['n_hosts']} hosts, "
                     f"fleet floor {stats['fleet_floor_s']*1e3:.2f} ms")
    if supervisor is not None:
        mesh_note += (f" | supervisor: {stats['restarts']} restarts, "
                      f"{len(stats['rescales'])} rescales, evacuated lanes "
                      f"{stats['evacuated_rids']}")
    slo_note = ""
    if args.schedule == "slo":
        slo_note = (f" | in-flight<= {stats['max_in_flight']}, "
                    f"{stats['deferred_admissions']} deferred admissions, "
                    f"pred p99 token "
                    f"{stats['predicted_token_latency_s']*1e3:.2f} ms")
    elif args.schedule == "spec":
        trained = "distilled" if stats.get("drafter_trained") else "random"
        slo_note = (f" | {args.draft} ({trained}) drafter depth "
                    f"{args.draft_depth} x{stats['draft_branches']} "
                    f"branches: {stats['n_windows']} windows, acceptance "
                    f"{stats['acceptance_rate']:.2f}, "
                    f"{stats['tokens_per_window_dispatch']:.2f} "
                    f"tok/window-dispatch")
    print(f"{args.schedule} x {args.sampling}: {n_requests} requests "
          f"(lens {lens}) gen {args.gen}: {wall*1e3:.1f} ms "
          f"({serve_wall*1e3:.1f} ms ex-compile, {out['tok_per_s']:.1f} "
          f"tok/s) | {stats['n_dispatches']} "
          f"dispatches, floor/request "
          f"{stats['per_request_dispatch_overhead_s']*1e6:.1f} us | "
          f"program cache h{program_cache.stats.hits}/"
          f"m{program_cache.stats.misses}{mesh_note}{prefix_note}"
          f"{chunk_note}{slo_note}")
    return out


def _merge_prefill(model, caches, pf_caches, prompt_len: int):
    """Copy prefill cache contents into the (larger) decode buffers.

    Kept for callers of the historical serve-loop helper; the merge itself
    is `scheduler.merge_prefill_caches` — by named time axis, raising with
    the tree path on any rank/axis mismatch instead of silently returning
    the empty decode buffer."""
    del model, prompt_len                  # merge is shape-driven per leaf
    return merge_prefill_caches(caches, pf_caches)


if __name__ == "__main__":
    run()
