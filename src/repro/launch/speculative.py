"""Speculative decoding on the overlapped stream (paper §9 economics).

The paper's dispatch-floor measurements (§9.3/§9.4) put a fixed t0 on every
command the engine executes; decode pays it once per token, so the only way
to go faster per token is more tokens per dispatch. Speculative decoding is
that lever on the serving stack:

  * **Drafter** — a second, cheaper model sharing the target's tokenizer and
    vocab: `draft_of(cfg)` depth-prunes any registry config into a draft
    config (same widths, so prompts/frames are shared verbatim and every
    internal divisibility constraint holds for every family), built through
    `models.build_model` so its matmuls route through the kernel dispatcher
    like every other model. `Drafter.self_draft` reuses the target itself —
    the agreement ceiling (with random-init reproduction weights, the only
    drafter whose proposals align with the target's).
  * **draft window** — one dispatch runs K drafter decode steps fused
    (`lax.scan`), proposing tokens with the *same* seeded rule the verifier
    resamples with (greedy argmax / per-(rid, pos) fold_in categorical), so
    a drafter that equals the target is accepted in full.
  * **fused verify/accept** — one dispatch runs K+1 target decode steps
    teacher-forced on the proposals, perturbs the fp32 logit rows with the
    per-(rid, pos) gumbel of `jax.random.categorical` when sampling, and
    routes the `specdec` kernel (accept-prefix + bonus resample, on device).
    Emitted tokens are always the target sampler's picks, so greedy streams
    are token-exact against `SequentialSchedule` and categorical streams are
    schedule-invariant whatever the drafter proposed.
  * **KV rollback on rejection** — the window writes K speculative positions
    into the resident (donated) caches; rejected ones must not survive.
    Positional leaves (`k`/`v`/`pos`/`c_kv`/`k_rope`, slot = pos % size, so
    sliding-window layers wrap) save the about-to-be-clobbered slots before
    the scan and restore every slot past the accept point after it.
    Recurrent leaves (SSM state/conv tails, RG-LRU h) are snapshotted per
    scan step and the per-lane snapshot at the accept point is kept. The
    drafter's own caches are best-effort (proposals need no exactness): a
    rejection may dent its next proposals, never the emitted stream.
  * **tree / multi-draft windows** (`--draft-branches N`) — the draft
    dispatch proposes N sibling chains per lane (branching at the window
    root on the drafter's top-N, branch 0 = the chain proposal) and ONE
    verify dispatch scores the whole tree: the target's caches tile to
    B*N rows inside the dispatch, the `specdec_tree` kernel picks the
    branch with the longest accepted prefix per lane, and the rollback
    keeps exactly the winner's accepted state. Same two floors per window,
    N first-token guesses instead of one — more expected accepts per floor
    when the drafter's top-1 is unsure but its top-N covers the target.
  * **floor accounting** — both the draft and the verify dispatch are
    encoded on `self.stream`: two floor-charged `DispatchRecord`s per window
    for up to K+1 emitted tokens. That is the honest §9 ledger the
    `bench_spec_decode` gate reads; an off-stream dispatch would fake the
    win.

Windows are pipelined on `AsyncExecutionStream`: the draft dispatch is
submitted without blocking, the verify dispatch chains the live draft-token
tensor, and the host syncs once per window (accept lengths are data, so the
host must read them before planning the next window).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import AsyncExecutionStream
from repro.kernels import compat
from repro.kernels.specdec import ops as specdec_ops
from repro.launch.scheduler import (SCHEDULES, TIME_MERGE_LEAVES,
                                    ContinuousSchedule, _admit_into_slot_impl,
                                    _leaf_name, _reset_slot_impl, bucket_for)
from repro.models.model import build_model


@partial(jax.jit, donate_argnums=(0, 1))
def _reset_both_slots(t_caches, d_caches, slot):
    """Decode-only admission, both models in ONE dispatch: the drafter's
    lane hygiene must not double the per-admission floor charge."""
    return (_reset_slot_impl(t_caches, slot),
            _reset_slot_impl(d_caches, slot))


@partial(jax.jit, donate_argnums=(0, 1))
def _admit_both_slots(t_caches, d_caches, pf_t, pf_d, slot):
    """Write target AND drafter prefill state into lane `slot` in ONE
    dispatch (resident buffers donated), mirroring `_admit_into_slot`."""
    return (_admit_into_slot_impl(t_caches, pf_t, slot),
            _admit_into_slot_impl(d_caches, pf_d, slot))

# ---------------------------------------------------------------------------
# Draft models
# ---------------------------------------------------------------------------


def draft_of(cfg) -> Any:
    """The shrink rule: depth-prune any registry config into a draft config.

    Widths (d_model, heads, d_ff, SSM/LRU dims) are kept so the draft shares
    the target's tokenizer, vocab, prompts and encdec frames verbatim and
    every family's divisibility constraints hold unchanged; depth drops to
    one layer (one block-pattern period for hybrids, one encoder layer for
    encdec). MoE layers prune to their dense path (experts_per_token worth
    of compute is drafting overhead, not drafting signal); MTP heads drop.
    """
    n_layers = len(cfg.block_pattern) if cfg.block_pattern else 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-draft",
        n_layers=n_layers,
        # every draft layer dense: layer_is_moe(i) is i >= n_dense_layers
        n_dense_layers=n_layers if cfg.n_experts else cfg.n_dense_layers,
        n_encoder_layers=min(cfg.n_encoder_layers, 1),
        mtp_depth=0,
    )


def _validate_draft_params(model, dcfg, params) -> None:
    """Reject drafter params that do not match `draft_of`'s config, loud:
    a silently-wrong drafter would serve (proposals need no exactness) with
    acceptance ~0 — precisely the regression the distillation fixes."""
    ref = dict(model.named_leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    got = dict(model.named_leaves(params))
    if set(ref) != set(got):
        missing = sorted(set(ref) - set(got))[:4]
        extra = sorted(set(got) - set(ref))[:4]
        raise ValueError(
            f"drafter params do not match the {dcfg.name!r} param tree: "
            f"missing {missing}, unexpected {extra} — was this checkpoint "
            f"distilled for a different arch or weight form?")
    for path, ref_leaf in ref.items():
        if tuple(got[path].shape) != tuple(ref_leaf.shape):
            hint = (" (the drafter must share the target's vocab and "
                    "widths — re-distill against this target)"
                    if path.startswith("embed") else "")
            raise ValueError(
                f"drafter param {path!r} has shape "
                f"{tuple(got[path].shape)}, draft config {dcfg.name!r} "
                f"wants {tuple(ref_leaf.shape)}{hint}")


def _load_draft_checkpoint(model, dcfg, cfg, path: str):
    """Restore distilled drafter params from a `CheckpointManager`
    directory, validating the metadata sidecar (vocab/width/arch) BEFORE
    any array loads and the param tree after. A checkpoint saved with a
    packed weight form restores into a `DispatchedWeight`-tagged template,
    so the form tags round-trip intact."""
    from repro.checkpoint.checkpoint import CheckpointManager
    mgr = CheckpointManager(path)
    if mgr.latest_step() is None:
        raise FileNotFoundError(f"no committed drafter checkpoint in {path!r}")
    meta = mgr.metadata() or {}
    for key, want in (("vocab", cfg.vocab), ("d_model", cfg.d_model)):
        got = meta.get(key)
        if got is not None and int(got) != int(want):
            raise ValueError(
                f"drafter checkpoint {path!r} was distilled with "
                f"{key}={got}, but the target {cfg.name!r} serves "
                f"{key}={want}; speculative decoding shares the tokenizer "
                f"and widths — re-distill against this target")
    form = meta.get("weight_form", "fp16")
    if form != "fp16":
        from repro.optim.compression import compress_model_params
        template = compress_model_params(
            model.init(jax.random.PRNGKey(0)), form)
    else:
        template = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    try:
        params, _ = mgr.restore(template)
    except (KeyError, ValueError) as e:
        raise ValueError(
            f"drafter checkpoint {path!r} does not restore into the "
            f"{dcfg.name!r} param tree: {e}") from None
    params = jax.tree.map(jnp.asarray, params)
    if form == "fp16":
        _validate_draft_params(model, dcfg, params)
    return params


@dataclasses.dataclass
class Drafter:
    """A draft model + params, served alongside the target.

    Built through `build_model`, so its projections/MLPs/attention resolve
    through the kernel dispatcher (packed weight forms included) exactly
    like the target — the first second-model subsystem on the stack.
    """

    model: Any
    params: Any
    cfg: Any
    kind: str = "shrink"
    #: True when the params came from a distillation run (`params=`/`ckpt=`)
    #: rather than random init — surfaced in stats so a bench/CI gate can
    #: tell a real drafter from the acceptance-0 placebo
    trained: bool = False

    @classmethod
    def shrink(cls, cfg, *, dispatcher=None, seed: int = 0, params=None,
               ckpt: str | None = None) -> "Drafter":
        """The depth-pruned two-model drafter. With neither `params` nor
        `ckpt` the student is random-init (acceptance ~0: a placebo useful
        only for rollback-path tests); `params=` serves distilled weights
        directly, `ckpt=` restores them from a `launch.distill` checkpoint
        directory — both validated loudly against `draft_of(cfg)`."""
        if params is not None and ckpt is not None:
            raise ValueError("pass params= or ckpt=, not both")
        dcfg = draft_of(cfg)
        model = build_model(dcfg, dispatcher=dispatcher)
        trained = params is not None or ckpt is not None
        if ckpt is not None:
            params = _load_draft_checkpoint(model, dcfg, cfg, ckpt)
        elif params is not None:
            _validate_draft_params(model, dcfg, params)
        else:
            params = model.init(jax.random.PRNGKey(seed + 1))
        return cls(model, params, dcfg, kind="shrink", trained=trained)

    @classmethod
    def self_draft(cls, model, params, cfg) -> "Drafter":
        """Draft with the target itself: proposals equal the target's picks
        by construction (accept-all) — the amortization ceiling, and the
        only aligned drafter when weights are random-init."""
        return cls(model, params, cfg, kind="self", trained=True)


DRAFT_KINDS = ("shrink", "self")


# ---------------------------------------------------------------------------
# The schedule
# ---------------------------------------------------------------------------


class SpeculativeSchedule(ContinuousSchedule):
    """Draft -> verify windows pipelined on `AsyncExecutionStream`.

    Admission, bucketed prefill and teacher-forced prompt catch-up follow
    `ContinuousSchedule` (the drafter is prefilled/caught-up in lockstep so
    its context matches the target's); once every active lane is sampling,
    decode proceeds in windows of `--draft-depth` proposals:

        draft dispatch   : K+1 fused drafter steps -> proposals (B, K)
        verify dispatch  : K+1 fused target steps, teacher-forced on the
                           proposals; seeded scores -> `specdec` kernel ->
                           per-lane (samples, accept_len); rejected cache
                           writes rolled back on device; donated caches.

    Each window emits `accept_len + 1` tokens per lane for exactly two
    floor-charged `DispatchRecord`s — the §9 economics the bench gates on.
    With `draft_branches > 1` the same two dispatches carry a root-branched
    tree of proposals per lane (`_draft_tree_program` /
    `_verify_tree_program`) and the emitted stream is still the target
    sampler's picks, token-exact against the sequential reference.
    """

    name = "spec"

    #: in-flight window when this schedule builds its own stream: draft and
    #: verify of one window overlap with host encode; 2 is the natural depth
    MAX_IN_FLIGHT = 2

    def __init__(self, model, params, cfg, *, n_slots: int, max_len: int,
                 draft_depth: int = 4, draft: str = "shrink",
                 drafter: Drafter | None = None, draft_branches: int = 1,
                 draft_ckpt: str | None = None,
                 max_in_flight: int = MAX_IN_FLIGHT,
                 stream=None, program_cache=None, target=None, **kw) -> None:
        if kw.get("prefix_cache"):
            raise ValueError(
                "SpeculativeSchedule does not route admissions through the "
                "paged KV pool: joint target+drafter admission would need "
                "both caches resident per block and the pool only pages the "
                "target's. Serve prefix-cached traffic with "
                "--schedule continuous or slo.")
        if kw.get("prefill_chunk") is not None:
            raise ValueError(
                "SpeculativeSchedule does not chunk prefill: admission "
                "stages the target AND drafter caches jointly, and the "
                "chunked staging path only carries the target's. Serve "
                "chunked-prefill traffic with --schedule continuous or slo.")
        kw.pop("prefill_chunk", None)
        if stream is None:
            stream = AsyncExecutionStream(program_cache, target=target,
                                          max_in_flight=max_in_flight)
        if not isinstance(stream, AsyncExecutionStream):
            raise ValueError(
                "SpeculativeSchedule pipelines draft->verify windows through "
                f"AsyncExecutionStream; got {type(stream).__name__}")
        super().__init__(model, params, cfg, n_slots=n_slots, max_len=max_len,
                         stream=stream, program_cache=program_cache,
                         target=target, **kw)
        if draft_depth < 1:
            raise ValueError(f"draft_depth must be >= 1, got {draft_depth}")
        if draft_branches < 1:
            raise ValueError(
                f"draft_branches must be >= 1, got {draft_branches}")
        if drafter is None:
            if draft not in DRAFT_KINDS:
                raise ValueError(f"draft {draft!r} not in {DRAFT_KINDS}")
            if draft == "self":
                if draft_ckpt:
                    raise ValueError(
                        "draft_ckpt loads a distilled shrink drafter; the "
                        "self drafter IS the target — drop --draft-ckpt or "
                        "use --draft shrink")
                drafter = Drafter.self_draft(model, params, cfg)
            else:
                drafter = Drafter.shrink(cfg, dispatcher=model.dispatcher,
                                         ckpt=draft_ckpt or None)
        if drafter.cfg.vocab != cfg.vocab:
            raise ValueError(
                f"drafter vocab {drafter.cfg.vocab} != target vocab "
                f"{cfg.vocab}; speculative decoding shares the tokenizer")
        self.drafter = drafter
        self.draft_depth = draft_depth
        self.draft_branches = draft_branches
        self.draft_caches = None
        self._min_ring = None     # resolved from the live caches, memoized
        self.n_windows = 0
        self.proposed = 0
        self.accepted = 0
        self.emitted = 0
        # model-forward counters for the §9 work term of bench_spec_decode
        self.draft_steps = 0      # drafter decode steps inside draft windows
        self.verify_steps = 0     # target decode steps inside verify passes
        self.catchup_steps = 0    # joint teacher-forced ticks (1 step each)
        self._draft_keys: set[str] = set()
        self._verify_keys: set[str] = set()
        self._draft_memo: dict = {}
        self._verify_memo: dict = {}
        self._joint_memo: dict = {}
        # one stable function object, so every admission resolves through
        # the ProgramCache (identity-keyed warm start, hits counted) instead
        # of a private shape memo the cache statistics would never see
        t_model, d_model = self.model, self.drafter.model

        def joint_prefill(params, dparams, batch):
            pf_t, logits = t_model.prefill(params, batch)
            pf_d, _ = d_model.prefill(dparams, batch)
            return pf_t, logits, pf_d

        self._joint_prefill_fn = joint_prefill

    # -- fused programs ------------------------------------------------------
    def _draft_program(self, tok, p0, rids, k: int):
        """K+1 fused drafter steps: consume the chain starting at `tok`,
        propose with the target sampler's exact seeded rule (greedy argmax /
        fold_in categorical), keep the token chain on device. The extra
        step consumes the last proposal so that on an accept-all window the
        drafter's consumed stream stays contiguous with the next window's
        first token (a skipped position is harmless to a KV drafter but
        desyncs a recurrent one); its proposal is discarded."""
        sig = (k, tok.shape, p0.shape)
        hit = self._draft_memo.get(sig)
        if hit is not None:
            return hit
        model, vocab = self.drafter.model, self.cfg.vocab
        mode, root = self.sampler.mode, self.sampler._root

        def fused(params, caches, tok0, p0, rids):
            def body(carry, i):
                caches, tok = carry
                caches, lg = model.decode_step(params, caches, tok, p0 + i)
                row = lg[:, -1, :vocab].astype(jnp.float32)
                if mode == "greedy":
                    prop = jnp.argmax(row, axis=-1).astype(jnp.int32)
                else:
                    def draw(rid, p, r):
                        key = jax.random.fold_in(
                            jax.random.fold_in(root, rid), p)
                        return jax.random.categorical(key, r)
                    prop = jax.vmap(draw)(rids, p0 + i + 1, row) \
                        .astype(jnp.int32)
                return (caches, prop[:, None]), prop
            (caches, _), props = jax.lax.scan(body, (caches, tok0),
                                              jnp.arange(k + 1))
            return caches, jnp.transpose(props[:k])      # (B, K)

        compiled, key = self.cache.compile(
            fused, self.drafter.params, self.draft_caches, tok, p0, rids,
            jit_kwargs={"donate_argnums": (1,)})
        self._draft_keys.add(key)
        hit = (compiled, key)
        self._draft_memo[sig] = hit
        return hit

    def _verify_program(self, tok, p0, drafts, rids, k: int):
        """K+1 fused target steps teacher-forced on the proposals, the
        `specdec` verify/accept kernel, and on-device rollback of every
        rejected cache write — one dispatch, one floor."""
        sig = (k, tok.shape, p0.shape)
        hit = self._verify_memo.get(sig)
        if hit is not None:
            return hit
        model, vocab = self.model, self.cfg.vocab
        mode, root = self.sampler.mode, self.sampler._root
        disp = self.model.dispatcher

        def fused(params, caches, tok0, p0, drafts, rids):
            pairs, treedef = compat.tree_flatten_with_path(caches)
            names = [_leaf_name(p) for p, _ in pairs]
            pos_idx = [i for i, n in enumerate(names)
                       if n in TIME_MERGE_LEAVES]
            rec_idx = [i for i, n in enumerate(names)
                       if n not in TIME_MERGE_LEAVES]

            def slots_of(leaf):
                # positional leaves are (stack, B, S, ...): the window will
                # write slots (p0+1 .. p0+k) % S (ring for windowed layers)
                size = leaf.shape[2]
                return (p0[:, None] + 1 + jnp.arange(k)[None]) % size

            def gather(leaf, slots):
                idx = slots.reshape((1,) + slots.shape
                                    + (1,) * (leaf.ndim - 3))
                return jnp.take_along_axis(leaf, idx, axis=2)

            saved = [gather(pairs[i][1], slots_of(pairs[i][1]))
                     for i in pos_idx] if k else []

            def body(carry, i):
                caches, tok = carry
                caches, lg = model.decode_step(params, caches, tok, p0 + i)
                row = lg[:, -1, :vocab].astype(jnp.float32)
                if k:
                    nxt = jax.lax.dynamic_slice_in_dim(
                        drafts, jnp.minimum(i, k - 1), 1, axis=1)
                else:
                    nxt = tok                      # K = 0: value never used
                snaps = [jax.tree.flatten(caches)[0][j] for j in rec_idx]
                return (caches, nxt), (row, snaps)

            (caches, _), (rows, snaps) = jax.lax.scan(
                body, (caches, tok0), jnp.arange(k + 1))
            scores = jnp.transpose(rows, (1, 0, 2))      # (B, K+1, V)
            positions = p0[:, None] + 1 + jnp.arange(k + 1)[None]
            scores = specdec_ops.seeded_scores(scores, root, rids,
                                               positions, mode)
            samples, accept = specdec_ops.verify_accept(scores, drafts,
                                                        dispatcher=disp)
            # rollback: keep exactly the state of the accepted prefix
            leaves = list(jax.tree.flatten(caches)[0])
            for j, i in enumerate(rec_idx):
                snap = snaps[j]                          # (K+1, stack, B, ..)
                idx = accept.reshape((1, 1, -1)
                                     + (1,) * (snap.ndim - 3))
                leaves[i] = jnp.take_along_axis(snap, idx, axis=0)[0]
            if k:
                rejected = (jnp.arange(1, k + 1)[None] > accept[:, None])
                for j, i in enumerate(pos_idx):
                    leaf = leaves[i]
                    slots = slots_of(leaf)
                    cur = gather(leaf, slots)
                    m = rejected.reshape((1,) + rejected.shape
                                         + (1,) * (leaf.ndim - 3))
                    vals = jnp.where(m, saved[j], cur)
                    barr = jnp.arange(leaf.shape[1])[:, None]
                    leaves[i] = leaf.at[:, barr, slots].set(vals)
            return treedef.unflatten(leaves), samples, accept

        compiled, key = self.cache.compile(
            fused, self.params, self.caches, tok, p0, drafts, rids,
            jit_kwargs={"donate_argnums": (1,)})
        self._verify_keys.add(key)
        hit = (compiled, key)
        self._verify_memo[sig] = hit
        return hit

    def _draft_tree_program(self, tok, p0, rids, k: int):
        """The multi-draft window: one dispatch proposes a TREE of `nbr`
        sibling chains per lane instead of one. Branching happens at the
        root — the drafter's top-`nbr` picks for the window's first
        position (branch 0 is exactly the chain proposal, greedy or seeded)
        — and each branch extends with the target sampler's rule, so the
        tree is `nbr` independent chains sharing position 0's context. The drafter's
        caches tile from B to B*nbr lanes inside the dispatch (lane b's
        branches at rows b*nbr..b*nbr+nbr-1); the verify dispatch keeps the
        winning branch's rows. The trailing contiguity step mirrors
        `_draft_program`'s."""
        nbr = self.draft_branches
        sig = (k, nbr, tok.shape, p0.shape)
        hit = self._draft_memo.get(sig)
        if hit is not None:
            return hit
        model, vocab = self.drafter.model, self.cfg.vocab
        mode, root = self.sampler.mode, self.sampler._root

        def fused(params, caches, tok0, p0, rids):
            # step 0 on the B un-tiled lanes: consume the window's first
            # token, rank the drafter's next-token candidates
            caches, lg = model.decode_step(params, caches, tok0, p0)
            row = lg[:, -1, :vocab].astype(jnp.float32)
            if mode != "greedy":
                def perturb(rid, p, r):
                    key = jax.random.fold_in(
                        jax.random.fold_in(root, rid), p)
                    return r + jax.random.gumbel(key, r.shape, r.dtype)
                # gumbel-perturbed rows: branch 0 (the top-1) is exactly
                # the seeded categorical draw the chain drafter proposes
                row = jax.vmap(perturb)(rids, p0 + 1, row)
            roots = jax.lax.top_k(row, nbr)[1].astype(jnp.int32)  # (B, nbr)
            tiled = jax.tree.map(lambda l: jnp.repeat(l, nbr, axis=1),
                                 caches)
            tokt = roots.reshape(-1)[:, None]                # (B*nbr, 1)
            p0t = jnp.repeat(p0, nbr)
            ridt = jnp.repeat(rids, nbr)

            def body(carry, i):
                caches, tokb = carry
                caches, lg = model.decode_step(params, caches, tokb,
                                               p0t + 1 + i)
                rowb = lg[:, -1, :vocab].astype(jnp.float32)
                if mode == "greedy":
                    prop = jnp.argmax(rowb, axis=-1).astype(jnp.int32)
                else:
                    def draw(rid, p, r):
                        key = jax.random.fold_in(
                            jax.random.fold_in(root, rid), p)
                        return jax.random.categorical(key, r)
                    prop = jax.vmap(draw)(ridt, p0t + i + 2, rowb) \
                        .astype(jnp.int32)
                return (caches, prop[:, None]), prop

            # k steps: k-1 branch extensions + the contiguity step that
            # consumes the last proposal (its own output is discarded)
            (tiled, _), props = jax.lax.scan(body, (tiled, tokt),
                                             jnp.arange(k))
            ext = jnp.transpose(props[: k - 1])          # (B*nbr, k-1)
            drafts = jnp.concatenate([tokt, ext], axis=1) \
                .reshape(tok0.shape[0], nbr, k)
            return tiled, drafts

        # no donation: the drafter caches come in at B rows and leave tiled
        # at B*nbr, so the input buffers are never reusable anyway
        compiled, key = self.cache.compile(
            fused, self.drafter.params, self.draft_caches, tok, p0, rids)
        self._draft_keys.add(key)
        hit = (compiled, key)
        self._draft_memo[sig] = hit
        return hit

    def _verify_tree_program(self, dcaches_tiled, tok, p0, drafts, rids,
                             k: int):
        """One dispatch scores the WHOLE tree: the target's caches tile to
        B*nbr rows, K+1 fused steps run every branch teacher-forced in the
        tiled batch, the `specdec_tree` kernel picks the winning branch per
        lane (max accepted prefix, first index on ties), and the rollback
        keeps exactly the winning branch's accepted prefix — winning rows
        selected from the tiled caches, then the same positional-save /
        recurrent-snapshot restore as the chain verify. Accepted tokens are
        the target sampler's picks, so equal-accept sibling branches carry
        identical accepted prefixes and the emitted stream stays token-
        exact against the sequential reference whichever branch wins."""
        nbr = self.draft_branches
        sig = (k, nbr, tok.shape, p0.shape)
        hit = self._verify_memo.get(sig)
        if hit is not None:
            return hit
        model, vocab = self.model, self.cfg.vocab
        mode, root = self.sampler.mode, self.sampler._root
        disp = self.model.dispatcher

        def fused(params, caches, dcaches, tok0, p0, drafts, rids):
            pairs, treedef = compat.tree_flatten_with_path(caches)
            names = [_leaf_name(p) for p, _ in pairs]
            pos_idx = [i for i, n in enumerate(names)
                       if n in TIME_MERGE_LEAVES]
            rec_idx = [i for i, n in enumerate(names)
                       if n not in TIME_MERGE_LEAVES]

            def slots_of(leaf):
                size = leaf.shape[2]
                return (p0[:, None] + 1 + jnp.arange(k)[None]) % size

            def gather(leaf, slots):
                idx = slots.reshape((1,) + slots.shape
                                    + (1,) * (leaf.ndim - 3))
                return jnp.take_along_axis(leaf, idx, axis=2)

            # positional save happens BEFORE tiling (per lane): every
            # branch clobbers the same (p0+1 .. p0+k) % S slots, and the
            # restore target is the un-tiled winning row
            saved = [gather(pairs[i][1], slots_of(pairs[i][1]))
                     for i in pos_idx]
            b = tok0.shape[0]
            tiled = jax.tree.map(lambda l: jnp.repeat(l, nbr, axis=1),
                                 caches)
            tokt = jnp.repeat(tok0, nbr, axis=0)
            p0t = jnp.repeat(p0, nbr)
            ridt = jnp.repeat(rids, nbr)
            dflat = drafts.reshape((b * nbr, k))

            def body(carry, i):
                caches, tokb = carry
                caches, lg = model.decode_step(params, caches, tokb,
                                               p0t + i)
                row = lg[:, -1, :vocab].astype(jnp.float32)
                nxt = jax.lax.dynamic_slice_in_dim(
                    dflat, jnp.minimum(i, k - 1), 1, axis=1)
                snaps = [jax.tree.flatten(caches)[0][j] for j in rec_idx]
                return (caches, nxt), (row, snaps)

            (tiled, _), (rows, snaps) = jax.lax.scan(
                body, (tiled, tokt), jnp.arange(k + 1))
            scores = jnp.transpose(rows, (1, 0, 2))      # (B*nbr, K+1, V)
            positions = p0t[:, None] + 1 + jnp.arange(k + 1)[None]
            scores = specdec_ops.seeded_scores(scores, root, ridt,
                                               positions, mode)
            samples, accept, branch = specdec_ops.verify_accept_tree(
                scores.reshape((b, nbr, k + 1, scores.shape[-1])),
                drafts, dispatcher=disp)
            # keep each lane's winning branch row from the tiled caches
            g = jnp.arange(b) * nbr + branch
            leaves = [jnp.take(l, g, axis=1)
                      for l in jax.tree.flatten(tiled)[0]]
            for j, i in enumerate(rec_idx):
                snap = jnp.take(snaps[j], g, axis=2)     # (K+1, stack, B, .)
                idx = accept.reshape((1, 1, -1)
                                     + (1,) * (snap.ndim - 3))
                leaves[i] = jnp.take_along_axis(snap, idx, axis=0)[0]
            rejected = (jnp.arange(1, k + 1)[None] > accept[:, None])
            for j, i in enumerate(pos_idx):
                leaf = leaves[i]
                slots = slots_of(leaf)
                cur = gather(leaf, slots)
                m = rejected.reshape((1,) + rejected.shape
                                     + (1,) * (leaf.ndim - 3))
                vals = jnp.where(m, saved[j], cur)
                barr = jnp.arange(leaf.shape[1])[:, None]
                leaves[i] = leaf.at[:, barr, slots].set(vals)
            # drafter caches: keep the winning branch's rows, best-effort
            # (no rollback — a dented proposal context costs acceptance on
            # the next window, never a token)
            dsel = jax.tree.map(lambda l: jnp.take(l, g, axis=1), dcaches)
            return treedef.unflatten(leaves), dsel, samples, accept

        # donate the target caches only: the tiled drafter caches shrink
        # back to B rows on the way out, so their buffers can't be reused
        compiled, key = self.cache.compile(
            fused, self.params, self.caches, dcaches_tiled, tok, p0,
            drafts, rids, jit_kwargs={"donate_argnums": (1,)})
        self._verify_keys.add(key)
        hit = (compiled, key)
        self._verify_memo[sig] = hit
        return hit

    def _joint_program(self, tok, pos):
        """Prompt catch-up: one dispatch steps target AND drafter on the
        same teacher-forced token, keeping the drafter's context synced."""
        sig = (tok.shape, pos.shape)
        hit = self._joint_memo.get(sig)
        if hit is not None:
            return hit
        t_model, d_model = self.model, self.drafter.model

        def fused(params, dparams, caches, dcaches, tok, pos):
            caches, lg = t_model.decode_step(params, caches, tok, pos)
            dcaches, _ = d_model.decode_step(dparams, dcaches, tok, pos)
            return caches, dcaches, lg

        compiled, key = self.cache.compile(
            fused, self.params, self.drafter.params, self.caches,
            self.draft_caches, tok, pos,
            jit_kwargs={"donate_argnums": (2, 3)})
        hit = (compiled, key)
        self._joint_memo[sig] = hit
        return hit

    def _joint_prefill_program(self, batch: dict):
        """Target + drafter prefill fused into ONE program: admission pays
        the same per-request floor count as the single-model schedules (the
        drafter rides the dispatch, it does not add one). Compile-or-hit
        per bucket shape through the content-hash ProgramCache."""
        return self.cache.compile(self._joint_prefill_fn, self.params,
                                  self.drafter.params, batch)

    # -- admission (drafter in lockstep, fused dispatches) -------------------
    def _admit(self, slot_idx: int, req, step: int) -> None:
        """`ContinuousSchedule._admit` semantics with the drafter admitted in
        the SAME dispatches: one joint prefill + one joint lane write (or one
        joint reset), so speculation's admission floor cost matches the
        baseline schedules dispatch for dispatch."""
        slot = self.slots[slot_idx]
        L = req.prompt.size
        bucket = bucket_for(L, self.buckets)
        sidx = jnp.asarray(slot_idx, jnp.int32)
        if bucket == 0:
            self.stream.encode_operation(
                _reset_both_slots, (self.caches, self.draft_caches, sidx),
                "spec_reset_slot", batch=1)
            self.caches, self.draft_caches = self.stream.execute_sync()[0]
            slot.next_pos, slot.next_tok = 0, int(req.prompt[0])
        else:
            batch = self._prefill_batch(req.prompt[None, :bucket], req.frames)
            prefill, pkey = self._joint_prefill_program(batch)
            self.stream.encode_operation(
                prefill, (self.params, self.drafter.params, batch), pkey,
                batch=1)
            pf_t, logits, pf_d = self.stream.execute_sync()[0]
            self.stream.encode_operation(
                _admit_both_slots,
                (self.caches, self.draft_caches, pf_t, pf_d, sidx),
                "spec_admit_slot", batch=1)
            self.caches, self.draft_caches = self.stream.execute_sync()[0]
            slot.next_pos = bucket
            if bucket < L:        # catch up through decode, teacher-forced
                slot.next_tok = int(req.prompt[bucket])
            else:                 # prompt fully prefilled: sample token L
                tok = self.sampler(np.asarray(logits)[0, -1], req.rid, L)
                slot.generated.append(tok)
                slot.next_tok = tok
        slot.req = req
        slot.bucket = bucket
        slot.admitted_step = step

    # -- the serve loop ------------------------------------------------------
    def run(self, requests: list) -> list:
        for r in requests:
            self._check(r)
        queue = sorted(requests, key=lambda r: (r.arrival, r.rid))
        if self.caches is None:
            self.caches = self.model.init_cache(self.n_slots, self.max_len)
        if self.draft_caches is None:
            self.draft_caches = self.drafter.model.init_cache(
                self.n_slots, self.max_len)
        results: list = []
        step = 0
        while queue or any(s.active for s in self.slots):
            for i, slot in enumerate(self.slots):
                if not queue or queue[0].arrival > step:
                    break
                if not slot.active:
                    self._admit(i, queue.pop(0), step)
            # a fully-prefilled request can finish without a decode step
            for s in list(self.slots):
                if s.active and s.generating \
                        and len(s.generated) >= s.req.max_new_tokens:
                    self._advance_finished(s, results, step)
            active = [s for s in self.slots if s.active]
            if not active:
                if queue:
                    step += 1     # idle tick: wait for the next arrival
                    continue
                break
            if any(s.next_pos + 1 < s.req.prompt.size for s in active):
                step = self._catchup_step(results, step)
            else:
                step = self._spec_window(queue, results, step)
        results.sort(key=lambda r: r.rid)
        return results

    def _catchup_step(self, results: list, step: int) -> int:
        """One joint teacher-forced tick while any lane is still inside its
        prompt — continuous-schedule semantics, drafter synced for free."""
        n = self.n_slots
        tok = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        n_active = 0
        for i, s in enumerate(self.slots):
            if s.active:
                tok[i, 0] = s.next_tok
                pos[i] = s.next_pos
                n_active += 1
        tokj, posj = jnp.asarray(tok), jnp.asarray(pos)
        prog, key = self._joint_program(tokj, posj)
        self.stream.encode_operation(
            prog, (self.params, self.drafter.params, self.caches,
                   self.draft_caches, tokj, posj), key, batch=n_active)
        self.caches, self.draft_caches, logits = self.stream.execute_sync()[0]
        self.catchup_steps += 1
        lg = np.asarray(logits[:, -1, : self.cfg.vocab], np.float32)
        for i, s in enumerate(self.slots):
            if s.active:
                self._advance(s, lg[i], results, step)
        return step + 1

    def _min_positional_size(self) -> int:
        """Smallest slot-axis extent over the target's positional cache
        leaves (sliding-window layers keep a ring of `attn_window` slots).
        A window deeper than ring-1 would wrap onto its own step-0 slot and
        the rollback save/restore would resurrect pre-window state over a
        committed write — so `_window_depth` clamps against this."""
        if self._min_ring is None:
            sizes = [leaf.shape[2] for path, leaf in
                     compat.tree_flatten_with_path(self.caches)[0]
                     if _leaf_name(path) in TIME_MERGE_LEAVES]
            self._min_ring = min(sizes) if sizes else self.max_len
        return self._min_ring

    def _window_depth(self, active: list, queue: list, step: int) -> int:
        """Draft depth this window: never past a lane's cache end, never
        deep enough to wrap a sliding-window ring onto the slot being
        committed, never more proposals than the hungriest lane can still
        emit, and never blowing past a queued arrival that could claim a
        free lane."""
        k = self.draft_depth
        k = min(k, self._min_positional_size() - 1)
        k = min(k, min(self.max_len - 1 - s.next_pos for s in active))
        k = min(k, max(s.req.max_new_tokens - len(s.generated)
                       for s in active) - 1)
        if queue and any(not s.active for s in self.slots):
            k = min(k, max(1, queue[0].arrival - step) - 1)
        return max(k, 0)

    def _spec_window(self, queue: list, results: list, step: int) -> int:
        active = [s for s in self.slots if s.active]
        k = self._window_depth(active, queue, step)
        n = self.n_slots
        tok = np.zeros((n, 1), np.int32)
        p0 = np.zeros((n,), np.int32)
        rids = np.zeros((n,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                tok[i, 0] = s.next_tok
                p0[i] = s.next_pos
                rids[i] = s.req.rid
        tokj = jnp.asarray(tok)
        p0j = jnp.asarray(p0)
        ridsj = jnp.asarray(rids)
        if k > 0 and self.draft_branches > 1:
            # tree window: one draft dispatch proposes nbr sibling chains
            # per lane, one verify dispatch scores the whole tree
            prog, dkey = self._draft_tree_program(tokj, p0j, ridsj, k)
            self.stream.encode_operation(
                prog, (self.drafter.params, self.draft_caches, tokj, p0j,
                       ridsj), dkey, batch=len(active))
            dtiled, drafts = self.stream.submit()[0]
            self.draft_steps += k + 1
            prog, vkey = self._verify_tree_program(dtiled, tokj, p0j,
                                                   drafts, ridsj, k)
            self.stream.encode_operation(
                prog, (self.params, self.caches, dtiled, tokj, p0j, drafts,
                       ridsj), vkey, batch=len(active))
            self.caches, self.draft_caches, samples, accept = \
                self.stream.submit()[0]
        else:
            if k > 0:
                prog, dkey = self._draft_program(tokj, p0j, ridsj, k)
                self.stream.encode_operation(
                    prog, (self.drafter.params, self.draft_caches, tokj,
                           p0j, ridsj), dkey, batch=len(active))
                # submit without blocking: the proposal tensor chains
                # straight into the verify dispatch as a live async value
                self.draft_caches, drafts = self.stream.submit()[0]
                self.draft_steps += k + 1
            else:
                drafts = jnp.zeros((n, 0), jnp.int32)
            prog, vkey = self._verify_program(tokj, p0j, drafts, ridsj, k)
            self.stream.encode_operation(
                prog, (self.params, self.caches, tokj, p0j, drafts, ridsj),
                vkey, batch=len(active))
            self.caches, samples, accept = self.stream.submit()[0]
        self.stream.sync()      # accept lengths are data: one sync per window
        samples = np.asarray(samples)
        accept = np.asarray(accept)
        self.n_windows += 1
        self.verify_steps += k + 1
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            a = int(accept[i])
            self.proposed += k
            self.accepted += a
            room = s.req.max_new_tokens - len(s.generated)
            take = min(a + 1, room)
            s.generated.extend(int(t) for t in samples[i, :take])
            self.emitted += take
            s.next_pos = int(p0[i]) + a + 1
            s.next_tok = int(samples[i, a])
            if len(s.generated) >= s.req.max_new_tokens:
                self._advance_finished(s, results, step + take)
        return step + k + 1

    # -- reporting -----------------------------------------------------------
    @property
    def acceptance_rate(self) -> float:
        """Accepted / proposed over the run; 0.0 when no window ever
        proposed a draft (a zero-window run offers no evidence the drafter
        works — reporting 1.0 here let a broken drafter masquerade as a
        perfect one through short, fully-prefilled benchmarks)."""
        return self.accepted / self.proposed if self.proposed else 0.0

    def stats(self, n_requests: int) -> dict:
        out = super().stats(n_requests)
        recs = self.stream.records
        draft_recs = sum(1 for r in recs if r.key in self._draft_keys)
        verify_recs = sum(1 for r in recs if r.key in self._verify_keys)
        out.update({
            "draft_depth": self.draft_depth,
            "draft_branches": self.draft_branches,
            "drafter": self.drafter.kind,
            "drafter_trained": self.drafter.trained,
            "n_windows": self.n_windows,
            "draft_dispatches": draft_recs,
            "verify_dispatches": verify_recs,
            "proposed": self.proposed,
            "accepted": self.accepted,
            "acceptance_rate": self.acceptance_rate,
            "emitted_tokens": self.emitted,
            "tokens_per_window_dispatch":
                self.emitted / max(draft_recs + verify_recs, 1),
            "draft_steps": self.draft_steps,
            "verify_steps": self.verify_steps,
            "catchup_steps": self.catchup_steps,
        })
        return out


SCHEDULES["spec"] = SpeculativeSchedule
