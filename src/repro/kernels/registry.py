"""Kernel registry: every Pallas kernel family, with its oracle attached.

The paper's central artifact is an operation-by-device matrix: which kernels
run where, validated by compile-and-run rather than attestation (§4), with a
roofline cost entry per cell (§9). This registry is that matrix's row space:
each kernel family registers

  * the Pallas entry point and the pure-jnp/numpy **ref oracle** it must match,
  * the supported dtypes and a set of named **shape classes** (including
    padding/alignment edge cases — ragged dims, tiny dims, non-multiples of
    the MXU tile),
  * a **cost entry** producing `core.costmodel.OpCost` for the segmenter and
    roofline,
  * the **capability op** that gates it per target (`hal.Target.op_floor`),
    and the weight form it streams, when any.

`core.dispatch.KernelDispatcher` routes through this table with
capability-gated fallback to the oracle, and `tests/test_conformance.py`
sweeps every registered kernel x dtype x shape class against its oracle — a
kernel added here is conformance-tested and dispatchable for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import OpCost
from repro.core.hal import WeightForm

# ---------------------------------------------------------------------------
# Spec types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One named shape class of a kernel's sweep.

    `dims` is kernel-specific (interpreted by the spec's `make_inputs`);
    `edge=True` marks padding/alignment stress cases — ragged extents, dims
    below one MXU tile, sizes straddling a block boundary."""

    name: str
    dims: tuple[int, ...]
    edge: bool = False


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One kernel family's row in the operation-by-device matrix."""

    name: str
    capability_op: str                  # gate key into hal.Target.op_floor
    dtypes: tuple[Any, ...]             # activation dtypes the kernel accepts
    cases: tuple[ShapeCase, ...]
    make_inputs: Callable[[ShapeCase, Any, np.random.Generator], dict]
    run_kernel: Callable[[dict], Any]
    run_oracle: Callable[[dict], Any]
    tol: Callable[[Any], tuple[float, float]]   # dtype -> (rtol, atol)
    cost: Callable[[ShapeCase, Any], OpCost]
    # Optional: weight form this kernel streams (palette/sparse) — dispatch
    # additionally gates on target.streams(form).
    weight_form: WeightForm | None = None
    # Optional: (scalar_kernel_fn, scalar_ref_fn, diff_args) builder for the
    # VJP leg of the conformance sweep. None = kernel is forward-only (or its
    # gradient is defined elsewhere, e.g. recompute-backward wrappers).
    make_vjp: Callable[[dict], tuple[Callable, Callable, tuple]] | None = None

    @property
    def edge_cases(self) -> tuple[ShapeCase, ...]:
        return tuple(c for c in self.cases if c.edge)


_REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} registered twice")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def all_specs() -> list[KernelSpec]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def iter_conformance_cases() -> Iterator[tuple[KernelSpec, ShapeCase, Any]]:
    """The generated sweep: every registered kernel x dtype x shape class."""
    for spec in all_specs():
        for dtype in spec.dtypes:
            for case in spec.cases:
                yield spec, case, dtype


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _normal(rng: np.random.Generator, shape, dtype) -> jnp.ndarray:
    return jnp.asarray(rng.normal(size=shape), dtype)


def _mm_tol(dtype) -> tuple[float, float]:
    # fp32 tolerance covers blocked-K accumulation-order differences; narrow
    # dtypes add one rounding at the store.
    return (1e-3, 1e-3) if dtype == jnp.float32 else (2.5e-2, 2.5e-2)


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# anemm — blocked matmul with the ANE-mode epilogue
# ---------------------------------------------------------------------------


def _anemm_inputs(case: ShapeCase, dtype, rng) -> dict:
    m, k, n = case.dims
    return {"a": _normal(rng, (m, k), dtype), "b": _normal(rng, (k, n), dtype)}


def _anemm_vjp(inputs: dict):
    from repro.kernels.anemm import ops as anemm_ops

    a = inputs["a"].astype(jnp.float32)
    b = inputs["b"].astype(jnp.float32)
    return (lambda a, b: anemm_ops.matmul(a, b).sum(),
            lambda a, b: (a @ b).sum(), (a, b))


def _register_anemm() -> None:
    from repro.kernels.anemm.anemm import anemm
    from repro.kernels.anemm.ref import anemm_ref

    register(KernelSpec(
        name="anemm",
        capability_op="matmul",
        dtypes=(jnp.float32, jnp.bfloat16, jnp.float16),
        cases=(
            ShapeCase("aligned", (128, 512, 128)),
            ShapeCase("tall", (256, 256, 64)),
            ShapeCase("ragged", (200, 300, 100), edge=True),
            ShapeCase("tiny", (8, 32, 8), edge=True),
            ShapeCase("vector", (1, 384, 16), edge=True),
            ShapeCase("off_block", (129, 257, 130), edge=True),
        ),
        make_inputs=_anemm_inputs,
        run_kernel=lambda i: anemm(i["a"], i["b"]),
        run_oracle=lambda i: anemm_ref(i["a"], i["b"]),
        tol=_mm_tol,
        cost=lambda c, dt: OpCost(
            f"anemm/{c.name}", 2.0 * c.dims[0] * c.dims[1] * c.dims[2],
            float(_itemsize(dt)) * (c.dims[0] * c.dims[1]
                                    + c.dims[1] * c.dims[2]
                                    + c.dims[0] * c.dims[2])),
        make_vjp=_anemm_vjp,
    ))


# ---------------------------------------------------------------------------
# palette — int4 palette-LUT weights, dequantized at the MXU input
# ---------------------------------------------------------------------------


def _palette_inputs(case: ShapeCase, dtype, rng) -> dict:
    from repro.kernels.palette.palette_matmul import pack_kn

    m, k, n = case.dims
    packed, lut = pack_kn(rng.normal(size=(k, n)).astype(np.float32), iters=4)
    return {"a": _normal(rng, (m, k), dtype),
            "packed": jnp.asarray(packed), "lut": jnp.asarray(lut)}


def _register_palette() -> None:
    from repro.kernels.palette.palette_matmul import palette_matmul
    from repro.kernels.palette.ref import palette_matmul_ref

    register(KernelSpec(
        name="palette",
        capability_op="matmul",
        weight_form=WeightForm.INT4_PALETTE,
        dtypes=(jnp.float32, jnp.bfloat16),
        cases=(
            ShapeCase("aligned", (64, 256, 192)),
            ShapeCase("wide", (128, 512, 256)),
            ShapeCase("ragged", (32, 130, 72), edge=True),
            ShapeCase("tiny", (4, 32, 16), edge=True),
        ),
        make_inputs=_palette_inputs,
        run_kernel=lambda i: palette_matmul(i["a"], i["packed"], i["lut"]),
        run_oracle=lambda i: palette_matmul_ref(i["a"], i["packed"], i["lut"]),
        tol=_mm_tol,
        cost=lambda c, dt: OpCost(
            f"palette/{c.name}", 2.0 * c.dims[0] * c.dims[1] * c.dims[2],
            float(_itemsize(dt)) * c.dims[0] * (c.dims[1] + c.dims[2])
            + 0.5 * c.dims[1] * c.dims[2] + 64.0),   # packed nibbles + codebook
    ))


# ---------------------------------------------------------------------------
# sparse — 1:2 pair-structured sparse weights, streamed compressed
# ---------------------------------------------------------------------------


def _sparse_inputs(case: ShapeCase, dtype, rng) -> dict:
    from repro.kernels.sparse.sparse_matmul import pack_pair_sparse

    m, k, n = case.dims
    vals, sel = pack_pair_sparse(rng.normal(size=(k, n)).astype(np.float32))
    return {"a": _normal(rng, (m, k), dtype),
            "values": jnp.asarray(vals), "selector": jnp.asarray(sel)}


def _register_sparse() -> None:
    from repro.kernels.sparse.sparse_matmul import sparse_matmul
    from repro.kernels.sparse.ref import sparse_matmul_ref

    register(KernelSpec(
        name="sparse",
        capability_op="matmul",
        weight_form=WeightForm.SPARSE,
        dtypes=(jnp.float32, jnp.bfloat16),
        cases=(
            # K must be a multiple of 16 (selector bits pack 8 pairs/byte)
            ShapeCase("aligned", (64, 256, 192)),
            ShapeCase("wide", (96, 512, 128)),
            ShapeCase("ragged", (48, 144, 72), edge=True),
            ShapeCase("tiny", (8, 32, 16), edge=True),
        ),
        make_inputs=_sparse_inputs,
        run_kernel=lambda i: sparse_matmul(i["a"], i["values"], i["selector"]),
        run_oracle=lambda i: sparse_matmul_ref(i["a"], i["values"],
                                               i["selector"]),
        tol=_mm_tol,
        cost=lambda c, dt: OpCost(
            f"sparse/{c.name}", 2.0 * c.dims[0] * c.dims[1] * c.dims[2],
            float(_itemsize(dt)) * c.dims[0] * (c.dims[1] + c.dims[2])
            + c.dims[1] * c.dims[2] * (1.0 + 1.0 / 16.0)),  # values + selector
    ))


# ---------------------------------------------------------------------------
# flash — fused attention, online softmax
# ---------------------------------------------------------------------------


def _flash_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, h, kvh, sq, skv, d = case.dims
    return {"q": _normal(rng, (b, h, sq, d), dtype),
            "k": _normal(rng, (b, kvh, skv, d), dtype),
            "v": _normal(rng, (b, kvh, skv, d), dtype)}


def _flash_tol(dtype) -> tuple[float, float]:
    return (2e-3, 2e-3) if dtype == jnp.float32 else (3e-2, 3e-2)


def _flash_vjp(inputs: dict):
    from repro.kernels.flash import ops as flash_ops
    from repro.kernels.flash.ref import flash_attention_ref

    q = inputs["q"].astype(jnp.float32)
    k = inputs["k"].astype(jnp.float32)
    v = inputs["v"].astype(jnp.float32)
    return (lambda q, k, v: flash_ops.attention(q, k, v).sum(),
            lambda q, k, v: flash_attention_ref(q, k, v).sum(), (q, k, v))


def _register_flash() -> None:
    from repro.kernels.flash.flash_attention import flash_attention
    from repro.kernels.flash.ref import flash_attention_ref

    register(KernelSpec(
        name="flash",
        capability_op="attention_fused",
        dtypes=(jnp.float32, jnp.bfloat16, jnp.float16),
        cases=(
            # dims = (B, H, KVH, Sq, Skv, d)
            ShapeCase("gqa", (2, 4, 2, 128, 128, 64)),
            ShapeCase("mha", (1, 4, 4, 128, 128, 32)),
            ShapeCase("ragged", (1, 2, 2, 100, 100, 32), edge=True),
            ShapeCase("odd_len", (1, 2, 1, 77, 77, 16), edge=True),
        ),
        make_inputs=_flash_inputs,
        run_kernel=lambda i: flash_attention(i["q"], i["k"], i["v"],
                                             causal=True, bq=64, bk=64),
        run_oracle=lambda i: flash_attention_ref(i["q"], i["k"], i["v"],
                                                 causal=True),
        tol=_flash_tol,
        cost=lambda c, dt: OpCost(
            f"flash/{c.name}",
            4.0 * c.dims[0] * c.dims[1] * c.dims[3] * c.dims[4] * c.dims[5],
            float(_itemsize(dt)) * c.dims[0] * c.dims[5]
            * (c.dims[1] * c.dims[3] * 2 + c.dims[2] * c.dims[4] * 2)),
        make_vjp=_flash_vjp,
    ))


# ---------------------------------------------------------------------------
# decode_attention — one-token GQA decode against a long cache
# ---------------------------------------------------------------------------


def _decode_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, h, kvh, s, d, length = case.dims
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    return {"q": _normal(rng, (b, h, d), dtype),
            "k_cache": _normal(rng, (b, s, kvh, d), dtype),
            "v_cache": _normal(rng, (b, s, kvh, d), dtype),
            "positions": jnp.where(pos < length, pos, -1),
            "current": jnp.full((b,), length - 1, jnp.int32)}


def _register_decode() -> None:
    from repro.kernels.flash.decode_attention import (decode_attention,
                                                      decode_attention_ref)

    register(KernelSpec(
        name="decode_attention",
        # The cache-slot select is a gather at heart: H13/M1 has no native
        # gather (hal.T4.1), so the dispatcher's matrix falls this kernel
        # back to the oracle there — the paper's op-by-device cell, live.
        capability_op="gather",
        dtypes=(jnp.float32, jnp.bfloat16),
        cases=(
            # dims = (B, H, KVH, S, d, written_length)
            ShapeCase("gqa", (2, 8, 2, 256, 64, 200)),
            ShapeCase("mha", (1, 4, 4, 128, 32, 100)),
            ShapeCase("ragged", (3, 4, 2, 96, 64, 50), edge=True),
            ShapeCase("short_cache", (2, 4, 1, 24, 16, 9), edge=True),
        ),
        make_inputs=_decode_inputs,
        run_kernel=lambda i: decode_attention(
            i["q"], i["k_cache"], i["v_cache"], i["positions"], i["current"],
            bk=64),
        run_oracle=lambda i: decode_attention_ref(
            i["q"], i["k_cache"], i["v_cache"], i["positions"], i["current"]),
        tol=_flash_tol,
        cost=lambda c, dt: OpCost(
            f"decode_attention/{c.name}",
            4.0 * c.dims[0] * c.dims[1] * c.dims[3] * c.dims[4],
            float(_itemsize(dt)) * 2.0
            * c.dims[0] * c.dims[3] * c.dims[2] * c.dims[4]),
    ))


# ---------------------------------------------------------------------------
# paged_decode_attention — decode against a block arena through a page table
# ---------------------------------------------------------------------------


def _paged_decode_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, h, kvh, n, bs, nb, d, length = case.dims
    # per-lane written lengths: lane 0 carries the full prefix, later lanes
    # progressively shorter (ragged page tables, partial last pages); the
    # empty_lane case zeroes the last lane — a K=0 page table of all -1
    lens = [max(0, length - i * (length // max(b, 1))) for i in range(b)]
    if case.name == "empty_lane":
        lens[-1] = 0
    pos_arena = np.full((n, bs), -1, np.int32)
    tables = np.full((b, nb), -1, np.int32)
    perm = rng.permutation(n)          # scattered arena rows: a real gather
    nxt = 0
    for i in range(b):
        for j in range(-(-lens[i] // bs)):
            row = int(perm[nxt])
            nxt += 1
            tables[i, j] = row
            pp = j * bs + np.arange(bs)
            pos_arena[row] = np.where(pp < lens[i], pp, -1)
    return {"q": _normal(rng, (b, h, d), dtype),
            "k_arena": _normal(rng, (n, bs, kvh, d), dtype),
            "v_arena": _normal(rng, (n, bs, kvh, d), dtype),
            "pos_arena": jnp.asarray(pos_arena),
            "block_tables": jnp.asarray(tables),
            "current": jnp.asarray(np.maximum(np.asarray(lens) - 1, 0),
                                   jnp.int32)}


def _register_paged_decode() -> None:
    from repro.kernels.flash.decode_attention import (
        paged_decode_attention, paged_decode_attention_ref)

    register(KernelSpec(
        name="paged_decode_attention",
        # same op-by-device cell as decode_attention: the page-table block
        # resolve is a gather, so targets without native gather (H13/M1)
        # fall back to the materializing oracle
        capability_op="gather",
        dtypes=(jnp.float32, jnp.bfloat16),
        cases=(
            # dims = (B, H, KVH, N arena blocks, bs, nb pages/lane, d, length)
            ShapeCase("gqa", (2, 8, 2, 16, 8, 6, 64, 41)),
            ShapeCase("mha", (1, 4, 4, 8, 16, 4, 32, 64)),
            ShapeCase("ragged_pages", (3, 4, 2, 24, 8, 5, 64, 27), edge=True),
            ShapeCase("empty_lane", (2, 4, 1, 12, 8, 4, 16, 9), edge=True),
        ),
        make_inputs=_paged_decode_inputs,
        run_kernel=lambda i: paged_decode_attention(
            i["q"], i["k_arena"], i["v_arena"], i["pos_arena"],
            i["block_tables"], i["current"]),
        run_oracle=lambda i: paged_decode_attention_ref(
            i["q"], i["k_arena"], i["v_arena"], i["pos_arena"],
            i["block_tables"], i["current"]),
        tol=_flash_tol,
        cost=lambda c, dt: OpCost(
            f"paged_decode_attention/{c.name}",
            4.0 * c.dims[0] * c.dims[1] * c.dims[5] * c.dims[4] * c.dims[6],
            float(_itemsize(dt)) * 2.0 * c.dims[0] * c.dims[5] * c.dims[4]
            * c.dims[2] * c.dims[6] + 4.0 * c.dims[0] * c.dims[5]),
    ))


# ---------------------------------------------------------------------------
# act_lut — 33-knot piecewise-linear activation evaluation
# ---------------------------------------------------------------------------


def _act_lut_inputs(case: ShapeCase, dtype, rng) -> dict:
    from repro.core.numerics import build_lut

    (n,) = case.dims
    table = build_lut("sigmoid")
    lo, hi = table.xs[0], table.xs[-1]
    x = rng.uniform(lo - 2.0, hi + 2.0, size=(n,)).astype(np.float32)
    return {"x": jnp.asarray(x, dtype), "table": table, "name": "sigmoid"}


def _register_act_lut() -> None:
    from repro.kernels.act_lut.ops import lut_activation
    from repro.kernels.act_lut.ref import act_lut_ref

    register(KernelSpec(
        name="act_lut",
        capability_op="sigmoid",
        dtypes=(jnp.float32, jnp.bfloat16),
        cases=(
            ShapeCase("block", (1024,)),
            ShapeCase("long", (4096,)),
            ShapeCase("ragged", (1311,), edge=True),
            ShapeCase("tiny", (7,), edge=True),
        ),
        make_inputs=_act_lut_inputs,
        run_kernel=lambda i: lut_activation(i["name"])(i["x"]),
        run_oracle=lambda i: jnp.asarray(
            act_lut_ref(np.asarray(i["x"], np.float64), i["table"]),
            jnp.float32),
        # the PWL table itself is fp16-grid accurate; bf16 x adds input rounding
        tol=lambda dt: (0.0, 2e-3) if dt == jnp.float32 else (0.0, 2e-2),
        cost=lambda c, dt: OpCost(
            f"act_lut/{c.name}", 40.0 * c.dims[0],   # 32 compares + PWL eval
            2.0 * float(_itemsize(dt)) * c.dims[0]),
    ))


# ---------------------------------------------------------------------------
# specdec — fused speculative-decoding verify/accept
# ---------------------------------------------------------------------------


def _specdec_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, t, v = case.dims
    scores = _normal(rng, (b, t, v), dtype).astype(jnp.float32)
    # draft proposals with varied agreement: per lane, copy the target's
    # pick for a random-length prefix, then diverge — every accept length
    # from reject-at-once to accept-all shows up in the sweep
    picks = np.asarray(jnp.argmax(scores, axis=-1))
    draft = rng.integers(0, v, size=(b, max(t - 1, 0))).astype(np.int32)
    for i in range(b):
        keep = int(rng.integers(0, t))              # 0..t-1 matching tokens
        draft[i, :keep] = picks[i, :keep]
        if keep < t - 1:                            # force the first mismatch
            draft[i, keep] = (picks[i, keep] + 1) % v
    return {"scores": scores, "draft": jnp.asarray(draft)}


def _specdec_packed(fn, i):
    samples, accept = fn(i["scores"], i["draft"])
    return jnp.concatenate([samples, accept[:, None]], axis=1)


def _register_specdec() -> None:
    from repro.kernels.specdec.ref import verify_accept_ref
    from repro.kernels.specdec.specdec import verify_accept_kernel

    register(KernelSpec(
        name="specdec",
        # the resample is an argmax at heart (hw-gated by the ANE's
        # 0x4f2_argmax_hw feature byte); targets without it fall the
        # verify/accept back to the jnp oracle inside the serving program
        capability_op="argmax",
        dtypes=(jnp.float32,),          # sampler math is fp32 by contract
        cases=(
            # dims = (B, K+1 window positions, vocab)
            ShapeCase("window", (4, 5, 512)),
            ShapeCase("deep", (2, 9, 384)),
            ShapeCase("ragged_vocab", (3, 4, 301), edge=True),
            ShapeCase("bonus_only", (2, 1, 128), edge=True),   # K = 0
            ShapeCase("tiny", (1, 2, 8), edge=True),
        ),
        make_inputs=_specdec_inputs,
        run_kernel=lambda i: _specdec_packed(verify_accept_kernel, i),
        run_oracle=lambda i: _specdec_packed(verify_accept_ref, i),
        tol=lambda dt: (0.0, 0.0),      # integer outputs: exact or wrong
        cost=lambda c, dt: OpCost(
            f"specdec/{c.name}",
            2.0 * c.dims[0] * c.dims[1] * c.dims[2],   # max + first-index min
            4.0 * c.dims[0] * c.dims[1] * (c.dims[2] + 2.0)),
    ))


def _specdec_tree_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, nbr, t, v = case.dims
    # np.array (not asarray): _normal may hand back a read-only device view
    # and the tie_branches case writes into rows below
    scores = np.array(_normal(rng, (b, nbr, t, v), dtype), np.float32)
    # per (lane, branch): copy the target's picks for a random-length prefix
    # then force the first mismatch — accept lengths span reject-at-once to
    # accept-all, and lanes where several branches tie on the max accept
    # length exercise the first-index branch tie-break
    picks = np.argmax(scores, axis=-1)
    draft = rng.integers(0, v, size=(b, nbr, max(t - 1, 0))).astype(np.int32)
    for i in range(b):
        for j in range(nbr):
            keep = int(rng.integers(0, t))
            draft[i, j, :keep] = picks[i, j, :keep]
            if keep < t - 1:
                draft[i, j, keep] = (picks[i, j, keep] + 1) % v
        if case.name == "tie_branches" and nbr > 1 and t > 1:
            # two sibling branches with identical accept lengths: the
            # kernel must pick the first, like the oracle's jnp.argmax
            draft[i, 1] = draft[i, 0]
            scores[i, 1] = scores[i, 0]
    return {"scores": jnp.asarray(scores), "draft": jnp.asarray(draft)}


def _specdec_tree_packed(fn, i):
    samples, accept, branch = fn(i["scores"], i["draft"])
    return jnp.concatenate(
        [samples, accept[:, None], branch[:, None]], axis=1)


def _register_specdec_tree() -> None:
    from repro.kernels.specdec.ref import verify_accept_tree_ref
    from repro.kernels.specdec.specdec import verify_accept_tree_kernel

    register(KernelSpec(
        name="specdec_tree",
        # same hardware gate as the chain row: the per-branch resample is
        # an argmax; the branch reduction is a max + first-index min on top
        capability_op="argmax",
        dtypes=(jnp.float32,),          # sampler math is fp32 by contract
        cases=(
            # dims = (B, branches, K+1 window positions, vocab)
            ShapeCase("fanout2", (4, 2, 5, 512)),
            ShapeCase("fanout3", (2, 3, 4, 384)),
            ShapeCase("single_branch", (3, 1, 4, 256), edge=True),  # == chain
            ShapeCase("tie_branches", (3, 2, 5, 256), edge=True),
            ShapeCase("ragged_vocab", (2, 2, 4, 301), edge=True),
            ShapeCase("bonus_only", (2, 2, 1, 128), edge=True),     # K = 0
        ),
        make_inputs=_specdec_tree_inputs,
        run_kernel=lambda i: _specdec_tree_packed(verify_accept_tree_kernel, i),
        run_oracle=lambda i: _specdec_tree_packed(verify_accept_tree_ref, i),
        tol=lambda dt: (0.0, 0.0),      # integer outputs: exact or wrong
        cost=lambda c, dt: OpCost(
            f"specdec_tree/{c.name}",
            2.0 * c.dims[0] * c.dims[1] * c.dims[2] * c.dims[3],
            4.0 * c.dims[0] * c.dims[1] * c.dims[2] * (c.dims[3] + 2.0)),
    ))


# ---------------------------------------------------------------------------
# conv2d / avg_pool / max_pool — the conv-engine family (NHWC)
# ---------------------------------------------------------------------------


def _conv_tol(dtype) -> tuple[float, float]:
    # fp32 covers tap-loop vs lax accumulation-order differences; narrow
    # dtypes add a store rounding and, for fused-LUT cases, a possible PWL
    # segment flip at a knot boundary (bounded by the fp16 table grid).
    return (2e-3, 2e-3) if dtype == jnp.float32 else (3e-2, 3e-2)


def _conv2d_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, h, w, cin, cout, kh, kw, sh, sw, same = case.dims
    out = {"x": _normal(rng, (b, h, w, cin), dtype),
           "w": jnp.asarray(rng.normal(size=(kh, kw, cin, cout)) * 0.2, dtype),
           "bias": _normal(rng, (cout,), dtype),
           "stride": (sh, sw), "padding": "SAME" if same else "VALID"}
    if case.name.startswith("fused_"):
        out["epilogue"] = case.name.split("_", 1)[1]
    return out


def _conv2d_vjp(inputs: dict):
    from repro.kernels.conv import ops as conv_ops
    from repro.kernels.conv.ref import conv2d_ref

    x = inputs["x"].astype(jnp.float32)
    w = inputs["w"].astype(jnp.float32)
    st, pad = inputs["stride"], inputs["padding"]
    return (lambda x, w: conv_ops.conv2d(x, w, stride=st, padding=pad).sum(),
            lambda x, w: conv2d_ref(x, w, stride=st, padding=pad).sum(),
            (x, w))


def _register_conv2d() -> None:
    from repro.kernels.conv import ops as conv_ops
    from repro.kernels.conv.ref import conv2d_ref

    register(KernelSpec(
        name="conv2d",
        capability_op="conv2d",
        dtypes=(jnp.float32, jnp.bfloat16, jnp.float16),
        cases=(
            # dims = (B, H, W, Cin, Cout, KH, KW, SH, SW, same?)
            ShapeCase("same_s1", (2, 16, 16, 8, 128, 3, 3, 1, 1, 1)),
            ShapeCase("strided", (1, 20, 16, 8, 128, 3, 3, 2, 2, 1)),
            ShapeCase("fused_gelu", (1, 12, 12, 8, 128, 3, 3, 1, 1, 1)),
            ShapeCase("valid_s1", (2, 10, 10, 16, 64, 3, 3, 1, 1, 0)),
            ShapeCase("ragged_tail", (1, 17, 13, 5, 33, 3, 3, 2, 2, 1),
                      edge=True),
            ShapeCase("pointwise", (2, 8, 8, 24, 48, 1, 1, 1, 1, 1),
                      edge=True),
            ShapeCase("stride_gt_k", (1, 12, 12, 8, 16, 2, 2, 3, 3, 0),
                      edge=True),
        ),
        make_inputs=_conv2d_inputs,
        run_kernel=lambda i: conv_ops.conv2d(
            i["x"], i["w"], i["bias"], stride=i["stride"],
            padding=i["padding"], epilogue=i.get("epilogue")),
        run_oracle=lambda i: conv2d_ref(
            i["x"], i["w"], i["bias"], stride=i["stride"],
            padding=i["padding"], epilogue=i.get("epilogue")),
        tol=_conv_tol,
        cost=lambda c, dt: OpCost(
            f"conv2d/{c.name}",
            2.0 * c.dims[0] * -(-c.dims[1] // c.dims[7])
            * -(-c.dims[2] // c.dims[8])
            * c.dims[5] * c.dims[6] * c.dims[3] * c.dims[4],
            float(_itemsize(dt)) * (c.dims[0] * c.dims[1] * c.dims[2]
                                    * c.dims[3]
                                    + c.dims[5] * c.dims[6] * c.dims[3]
                                    * c.dims[4]
                                    + c.dims[0] * -(-c.dims[1] // c.dims[7])
                                    * -(-c.dims[2] // c.dims[8])
                                    * c.dims[4])),
        make_vjp=_conv2d_vjp,
    ))


def _pool_inputs(case: ShapeCase, dtype, rng) -> dict:
    b, h, w, c, wh, ww, sh, sw, same = case.dims
    return {"x": _normal(rng, (b, h, w, c), dtype),
            "window": (wh, ww), "stride": (sh, sw),
            "padding": "SAME" if same else "VALID"}


_POOL_CASES = (
    # dims = (B, H, W, C, WH, WW, SH, SW, same?)
    ShapeCase("win2_s2", (2, 16, 16, 32, 2, 2, 2, 2, 0)),
    ShapeCase("win3_s2_same", (1, 15, 15, 16, 3, 3, 2, 2, 1)),
    ShapeCase("overlap", (2, 12, 12, 8, 3, 3, 1, 1, 0)),
    ShapeCase("ragged_tail", (1, 17, 13, 5, 3, 3, 2, 2, 1), edge=True),
    ShapeCase("global", (2, 8, 8, 16, 8, 8, 8, 8, 0), edge=True),
)


def _pool_cost(kind: str):
    def cost(c, dt):
        ohw = (-(-c.dims[1] // c.dims[6])) * (-(-c.dims[2] // c.dims[7]))
        return OpCost(
            f"{kind}/{c.name}",
            float(c.dims[0]) * ohw * c.dims[4] * c.dims[5] * c.dims[3],
            float(_itemsize(dt)) * c.dims[0]
            * (c.dims[1] * c.dims[2] + ohw) * c.dims[3])
    return cost


def _register_avg_pool() -> None:
    from repro.kernels.conv import ops as conv_ops
    from repro.kernels.conv.ref import avg_pool_ref

    register(KernelSpec(
        name="avg_pool",
        capability_op="avg_pool",
        dtypes=(jnp.float32, jnp.bfloat16, jnp.float16),
        cases=_POOL_CASES,
        make_inputs=_pool_inputs,
        run_kernel=lambda i: conv_ops.avg_pool(
            i["x"], window=i["window"], stride=i["stride"],
            padding=i["padding"]),
        run_oracle=lambda i: avg_pool_ref(
            i["x"], window=i["window"], stride=i["stride"],
            padding=i["padding"]),
        # one fp32 sum each side; only the tap order differs
        tol=lambda dt: (1e-5, 1e-5) if dt == jnp.float32 else (1e-2, 1e-2),
        cost=_pool_cost("avg_pool"),
    ))


def _register_max_pool() -> None:
    from repro.kernels.conv import ops as conv_ops
    from repro.kernels.conv.ref import max_pool_ref

    register(KernelSpec(
        name="max_pool",
        capability_op="max_pool",
        dtypes=(jnp.float32, jnp.bfloat16, jnp.float16),
        cases=_POOL_CASES,
        make_inputs=_pool_inputs,
        run_kernel=lambda i: conv_ops.max_pool(
            i["x"], window=i["window"], stride=i["stride"],
            padding=i["padding"]),
        run_oracle=lambda i: max_pool_ref(
            i["x"], window=i["window"], stride=i["stride"],
            padding=i["padding"]),
        tol=lambda dt: (0.0, 0.0),      # max is order-free: exact or wrong
        cost=_pool_cost("max_pool"),
    ))


# ---------------------------------------------------------------------------
# Registration (import-time, idempotent via the duplicate guard)
# ---------------------------------------------------------------------------


for _reg in (_register_anemm, _register_palette, _register_sparse,
             _register_flash, _register_decode, _register_paged_decode,
             _register_act_lut, _register_specdec, _register_specdec_tree,
             _register_conv2d, _register_avg_pool, _register_max_pool):
    _reg()
