"""flash: tiled attention with online softmax and an fp32 VMEM accumulator.

The fusion result of paper §9.6 transcribed to the MXU: fusing the score,
softmax, and value matmuls into one kernel keeps the (bq x bk) score tile as
the only live intermediate — the whole attention graph runs above the ridge
point instead of three bandwidth-bound dispatches. The running (max, denom)
pair is the same two-rounding-point structure as the wide accumulator:
scores in fp32, output rounded once at the store.

Grid: (B*H, Sq/bq, Skv/bk) with the KV axis innermost; m/l/acc live in VMEM
scratch across the KV steps. GQA maps q-head -> kv-head in the BlockSpec
index maps (no KV duplication in HBM). Causal + sliding-window masking via
block-position iota; fully-masked blocks short-circuit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.common import cdiv, interpret_mode, pad_to

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, bq: int, bk: int, causal: bool, window: int | None,
            skv: int, scale: float, out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    allow = k_pos < skv                                 # kv padding
    if causal:
        allow &= k_pos <= q_pos
    if window is not None:
        allow &= (q_pos - k_pos) < window

    def compute():
        q = q_ref[0].astype(jnp.float32)                # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(allow, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    if causal or window is not None:
        # skip blocks that are entirely masked
        first_q = qi * bq
        last_q = first_q + bq - 1
        first_k = ki * bk
        last_k = first_k + bk - 1
        runnable = True
        if causal:
            runnable = jnp.asarray(first_k <= last_q)
        if window is not None:
            runnable &= jnp.asarray(last_k > first_q - window)

        @pl.when(runnable)
        def _run():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "scale"))
def flash_attention(
    q: jnp.ndarray,                 # (B, H, Sq, d)
    k: jnp.ndarray,                 # (B, KVH, Skv, d)
    v: jnp.ndarray,                 # (B, KVH, Skv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int = 512,
    bk: int = 512,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, max(sq, 16))
    bk = min(bk, max(skv, 16))
    qp = pad_to(q, 2, bq)
    kp = pad_to(k, 2, bk)
    vp = pad_to(v, 2, bk)
    nq, nk = cdiv(qp.shape[2], bq), cdiv(kp.shape[2], bk)
    # flatten (B, H) into the leading grid axis; kv head = head // g
    qf = qp.reshape(b * h, qp.shape[2], d)
    kf = kp.reshape(b * kvh, kp.shape[2], d)
    vf = vp.reshape(b * kvh, vp.shape[2], d)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, causal=causal,
                          window=window, skv=skv, scale=scale,
                          out_dtype=q.dtype),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qi, ki, g=g, kvh=kvh:
                         ((bh // (g * kvh)) * kvh + (bh % (g * kvh)) // g,
                          ki, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qi, ki, g=g, kvh=kvh:
                         ((bh // (g * kvh)) * kvh + (bh % (g * kvh)) // g,
                          ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, qp.shape[2], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret_mode(),
        **compat.pallas_call_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, h, qp.shape[2], d)[:, :, :sq]
