"""Public wrapper: flash forward kernel + recompute backward.

Backward recomputes attention through the memory-safe chunked reference
(standard flash practice: store no S x S intermediates; trade ~1 extra
forward of FLOPs). The vjp of the chunked reference is itself chunked, so
peak memory stays O(block) in both directions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash.flash_attention import flash_attention
from repro.models.attention import chunked_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(q, k, v, causal: bool = True, window: int | None = None):
    """q: (B, H, Sq, d); k/v: (B, KVH, Skv, d)."""
    return flash_attention(q, k, v, causal=causal, window=window)


def _ref_bhsd(q, k, v, causal, window):
    # chunked_attention wants (B, S, H, d)
    out = chunked_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal,
                            window=window)
    return out.transpose(0, 2, 1, 3)


def _fwd(q, k, v, causal, window):
    return attention(q, k, v, causal, window), (q, k, v)


def _bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref_bhsd(q, k, v, causal, window),
                     q, k, v)
    return vjp(g)


attention.defvjp(_fwd, _bwd)
