"""flash decode: one-token GQA attention against a long KV cache.

The serving hot path (paper ch.14): a single query row per sequence scanned
against a 32k-500k entry cache. Decode attention is pure weight/cache
streaming — arithmetic intensity ~1 — so the kernel's job is to keep HBM
reads perfectly sequential and the softmax state in VMEM:

grid (B, KVH, S/bk), KV innermost; scratch carries the online-softmax
(m, l, acc) for the g grouped query heads of one kv head. Invalid cache
slots (beyond the written length, or outside a rolling window) mask via the
positions array, which streams alongside the cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.common import cdiv, interpret_mode, pad_to

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
            m_ref, l_ref, acc_ref, *, nk: int, scale: float, window,
            out_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (g, d)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[0]                                  # (bk,) written positions
    cur = cur_ref[0, 0]
    valid = (pos >= 0) & (pos <= cur)
    if window is not None:
        valid &= (cur - pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)         # (g, bk)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, :, 0].astype(jnp.float32)            # (bk, d)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "scale"))
def decode_attention(
    q: jnp.ndarray,            # (B, H, d) one query row per sequence
    k_cache: jnp.ndarray,      # (B, S, KV, d)
    v_cache: jnp.ndarray,      # (B, S, KV, d)
    positions: jnp.ndarray,    # (B, S) written absolute position per slot (-1 empty)
    current: jnp.ndarray,      # (B,) current decode position
    *,
    window: int | None = None,
    bk: int = 512,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    bk = min(bk, max(s, 8))
    kp = pad_to(k_cache, 1, bk)
    vp = pad_to(v_cache, 1, bk)
    pp = pad_to(positions, 1, bk)
    if pp.shape[1] != positions.shape[1]:
        # padded slots must read as empty
        pad_width = pp.shape[1] - positions.shape[1]
        pp = jnp.concatenate([positions,
                              jnp.full((b, pad_width), -1, positions.dtype)],
                             axis=1)
    nk = cdiv(kp.shape[1], bk)
    # (B, KVH, g, d) query layout: kv-head-major groups
    qg = q.reshape(b, kvh, g, d)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, scale=scale, window=window,
                          out_dtype=q.dtype),
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk), lambda bi, hi, ki: (bi, ki)),
            pl.BlockSpec((1, 1), lambda bi, hi, ki: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret_mode(),
        **compat.pallas_call_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qg, kp, vp, pp, current.reshape(b, 1).astype(jnp.int32))
    return out.reshape(b, h, d)


def decode_attention_ref(q, k_cache, v_cache, positions, current,
                         *, window=None, scale=None):
    """jnp oracle (mirrors models/attention._decode_attention)."""
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kvh, g, d)
    sc = jnp.einsum("bkgd,bckd->bkgc", qg.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) * scale
    valid = (positions >= 0) & (positions <= current[:, None])
    if window is not None:
        valid &= (current[:, None] - positions) < window
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged decode: the KV cache lives in a shared block arena and each lane
# reads it through a page table (launch/kv_pool.py builds both). One grid
# step processes one page; the BlockSpec index maps resolve the arena block
# from the scalar-prefetched table, so the gather happens in the DMA engine,
# not as a materialized copy.
# ---------------------------------------------------------------------------


def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, nb: int, scale: float, window,
                  out_dtype):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (g, d)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[0]                                  # (bs,) written positions
    cur = cur_ref[0, 0]
    mapped = bt_ref[bi, ki] >= 0                      # -1 = unmapped page
    valid = mapped & (pos >= 0) & (pos <= cur)
    if window is not None:
        valid &= (cur - pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)         # (g, bs)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, :, 0].astype(jnp.float32)            # (bs, d)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nb - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(out_dtype)


def gather_pages(arena: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialize a per-lane view from a block arena: ``arena`` is
    ``(N, bs, ...)``, ``block_tables`` is ``(B, nb)`` int32 with -1 for
    unmapped pages (clamped to row 0; callers mask via positions/table).
    Returns ``(B, nb*bs, ...)`` — the monolithic-slab layout."""
    n, bs = arena.shape[:2]
    b, nb = block_tables.shape
    g = jnp.take(arena, jnp.maximum(block_tables, 0).reshape(-1), axis=0)
    return g.reshape((b, nb * bs) + arena.shape[2:])


@functools.partial(jax.jit, static_argnames=("window", "scale"))
def paged_decode_attention(
    q: jnp.ndarray,            # (B, H, d) one query row per sequence
    k_arena: jnp.ndarray,      # (N, bs, KV, d) shared block arena
    v_arena: jnp.ndarray,      # (N, bs, KV, d)
    pos_arena: jnp.ndarray,    # (N, bs) written absolute position per slot
    block_tables: jnp.ndarray,  # (B, nb) arena block per lane page; -1 empty
    current: jnp.ndarray,      # (B,) current decode position
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, d = q.shape
    n, bs, kvh, _ = k_arena.shape
    nb = block_tables.shape[1]
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kvh, g, d)
    bt = block_tables.astype(jnp.int32)
    cur = current.reshape(b, 1).astype(jnp.int32)

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=1,
        grid=(b, kvh, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki, t: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, hi, ki, t: (jnp.maximum(t[bi, ki], 0),
                                                0, hi, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, hi, ki, t: (jnp.maximum(t[bi, ki], 0),
                                                0, hi, 0)),
            pl.BlockSpec((1, bs),
                         lambda bi, hi, ki, t: (jnp.maximum(t[bi, ki], 0), 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ki, t: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, hi, ki, t: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    if grid_spec is None:       # no scalar prefetch in this Pallas: gather
        # the pages outside the kernel and run the monolithic-slab path with
        # one KV chunk per page — identical accumulation order, so the two
        # paths stay bit-identical in interpret mode
        k_cache = gather_pages(k_arena, bt)
        v_cache = gather_pages(v_arena, bt)
        pos = jnp.where(
            jnp.repeat(bt >= 0, bs, axis=1), gather_pages(pos_arena, bt), -1)
        return decode_attention(q, k_cache, v_cache, pos, current,
                                window=window, bk=bs, scale=scale)

    out = pl.pallas_call(
        functools.partial(_paged_kernel, nb=nb, scale=scale, window=window,
                          out_dtype=q.dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret_mode(),
        **compat.pallas_call_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(bt, qg, k_arena, v_arena, pos_arena, cur)
    return out.reshape(b, h, d)


def paged_decode_attention_ref(q, k_arena, v_arena, pos_arena, block_tables,
                               current, *, window=None, scale=None):
    """jnp oracle: materialize the page-table gather, then the monolithic
    oracle. Unmapped pages (-1) read as empty slots."""
    bs = k_arena.shape[1]
    bt = block_tables.astype(jnp.int32)
    k_cache = gather_pages(k_arena, bt)
    v_cache = gather_pages(v_arena, bt)
    pos = jnp.where(
        jnp.repeat(bt >= 0, bs, axis=1), gather_pages(pos_arena, bt), -1)
    return decode_attention_ref(q, k_cache, v_cache, pos, current,
                                window=window, scale=scale)
