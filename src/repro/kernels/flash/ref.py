"""Pure-jnp oracle for flash attention (materialized scores, fp32)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    kq = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    allow = jnp.ones((sq, skv), bool)
    if causal:
        allow &= kpos <= qpos
    if window is not None:
        allow &= (qpos - kpos) < window
    s = jnp.where(allow[None, None], s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq).astype(q.dtype)
