"""Public wrappers for the conv/pooling family.

`conv2d` is the inference entry point (bias + optional fused LUT epilogue)
with training-grade gradients: forward runs the Pallas kernel, backward
differentiates the jnp reference (the transpose of a conv is itself a conv
pair XLA already emits optimally — the same convention as anemm's XLA
backward). The fused-LUT backward inherits the PWL segment-slope derivative
through `lut_apply_ref`. Pooling is forward-only (serving path); its oracle
is differentiable for anyone who needs gradients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv.conv2d import conv2d as _conv2d_kernel
from repro.kernels.conv.pool import avg_pool, max_pool  # noqa: F401 — re-export
from repro.kernels.conv.ref import conv2d_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _conv(x, w, bias, stride, padding, ane_mode, epilogue):
    return _conv2d_kernel(x, w, bias, stride=stride, padding=padding,
                          ane_mode=ane_mode, epilogue=epilogue)


def _conv_fwd(x, w, bias, stride, padding, ane_mode, epilogue):
    return _conv(x, w, bias, stride, padding, ane_mode, epilogue), \
        (x, w, bias)


def _conv_bwd(stride, padding, ane_mode, epilogue, res, g):
    x, w, bias = res

    def ref(*diff_args):
        xx, ww = diff_args[0], diff_args[1]
        bb = diff_args[2] if bias is not None else None
        return conv2d_ref(xx, ww, bb, stride=stride, padding=padding,
                          ane_mode=ane_mode, epilogue=epilogue)

    args = (x, w) if bias is None else (x, w, bias)
    _, vjp = jax.vjp(ref, *args)
    grads = vjp(g)
    return grads if bias is not None else (*grads, None)


_conv.defvjp(_conv_fwd, _conv_bwd)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray | None = None,
           *, stride: tuple[int, int] = (1, 1), padding: str = "SAME",
           ane_mode: bool = False,
           epilogue: str | None = None) -> jnp.ndarray:
    """NHWC conv through the Pallas kernel, differentiable, with the bias /
    saturation / LUT-activation epilogue fused at the output port."""
    return _conv(x, w, bias, stride, padding, ane_mode, epilogue)
