"""Pure-jnp oracles for the conv/pooling family.

SAME/VALID resolve through the same `pad_explicit` formula the kernels use,
so oracle and kernel always agree on which cells a window covers. The fused
`epilogue=` reference is kernel-then-LUT: the conv result rounds to the out
dtype (the store of the separate-op pipeline) before `lut_apply_ref` widens
it back to fp32 — matching the rounding point the fused kernels replicate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hal
from repro.kernels.conv.conv2d import pad_explicit


def conv2d_ref(x, w, bias=None, *, stride=(1, 1), padding="SAME",
               ane_mode: bool = False, epilogue: str | None = None):
    """NHWC conv via `lax.conv_general_dilated`, fp32 accumulation."""
    kh, kw = w.shape[0], w.shape[1]
    sh, sw = stride
    pads = (pad_explicit(x.shape[1], kh, sh, padding),
            pad_explicit(x.shape[2], kw, sw, padding))
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (sh, sw), pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if ane_mode:
        acc = jnp.where(acc >= hal.ACCUM_OUT_CEILING, jnp.inf, acc)
        acc = jnp.where(acc <= -hal.ACCUM_OUT_CEILING, -jnp.inf, acc)
    out = acc.astype(x.dtype)
    if epilogue is not None:
        from repro.kernels.act_lut.ops import lut_apply_ref
        out = lut_apply_ref(out, epilogue)
    return out


def _pool_ref(x, *, window, stride, padding, kind):
    wh, ww = window
    sh, sw = stride
    pads = ((0, 0),
            pad_explicit(x.shape[1], wh, sh, padding),
            pad_explicit(x.shape[2], ww, sw, padding),
            (0, 0))
    xf = x.astype(jnp.float32)
    if kind == "avg":
        out = jax.lax.reduce_window(
            xf, 0.0, jax.lax.add, (1, wh, ww, 1), (1, sh, sw, 1),
            pads) * (1.0 / (wh * ww))
    else:
        out = jax.lax.reduce_window(
            xf, -jnp.inf, jax.lax.max, (1, wh, ww, 1), (1, sh, sw, 1), pads)
    return out.astype(x.dtype)


def avg_pool_ref(x, *, window, stride=None, padding="VALID"):
    return _pool_ref(x, window=window, stride=stride or window,
                     padding=padding, kind="avg")


def max_pool_ref(x, *, window, stride=None, padding="VALID"):
    return _pool_ref(x, window=window, stride=stride or window,
                     padding=padding, kind="max")
