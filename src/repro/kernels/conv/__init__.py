"""Conv/pooling kernel family (NHWC) with fused act_lut epilogues."""

from repro.kernels.conv.ops import avg_pool, conv2d, max_pool  # noqa: F401
