"""conv2d: direct NHWC convolution as KH*KW shifted MXU matmuls.

The engine the paper profiles is convolution-first (§3.1): the MAC array's
native datapath is a conv window sliding over a planar tensor, with the
per-channel scale/bias and the LUT activation unit sitting on the output
port so activations never round-trip through memory. This kernel is that
datapath on the MXU:

    each (kh, kw) tap is a strided spatial slice of the input tile
    contracted against the (Cin, Cout) weight plane — a plain matmul;
    the fp32 accumulator sums the KH*KW taps               (VMEM scratch)
    bias applies, ANE mode saturates the output port       (epilogue)
    the fused LUT activation evaluates in-register         (epilogue=)
    one store rounds to the narrow dtype                   (VMEM -> HBM)

Grid: one batch image per step ("parallel"); spatial extent stays whole in
VMEM (encoder stems and pooling pyramids are short-and-wide, well inside the
working-set budget). Channels pad to MXU-friendly multiples; `pad_explicit`
resolves SAME/VALID to explicit lo/hi pads shared with the jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hal
from repro.kernels import compat
from repro.kernels.act_lut.act_lut import lut_eval
from repro.kernels.common import interpret_mode, pad_to


def out_extent(size: int, k: int, stride: int, padding: str) -> int:
    """Output spatial extent for one dim (SAME: ceil(size/s); VALID floor)."""
    if padding == "SAME":
        return -(-size // stride)
    if padding == "VALID":
        if size < k:
            raise ValueError(f"VALID conv: extent {size} < window {k}")
        return (size - k) // stride + 1
    raise ValueError(f"padding must be SAME or VALID, got {padding!r}")


def pad_explicit(size: int, k: int, stride: int,
                 padding: str) -> tuple[int, int]:
    """(lo, hi) explicit pads for one spatial dim — one formula, used by the
    kernel wrapper and the oracles, so SAME always means the same cells."""
    o = out_extent(size, k, stride, padding)
    if padding == "VALID":
        return (0, 0)
    total = max((o - 1) * stride + k - size, 0)
    return (total // 2, total - total // 2)


def _kernel(x_ref, w_ref, bias_ref, lut_refs, o_ref, acc_ref, *,
            kh: int, kw: int, sh: int, sw: int, oh: int, ow: int,
            ane_mode: bool, out_dtype):
    x = x_ref[0]                                   # (Hp, Wp, Cin)
    cin = x.shape[-1]
    cout = acc_ref.shape[-1]
    acc_ref[...] = jnp.zeros_like(acc_ref)
    for i in range(kh):
        for j in range(kw):
            # tap (i, j): every output pixel reads x[i + sh*oy, j + sw*ox]
            patch = x[i:i + sh * (oh - 1) + 1:sh,
                      j:j + sw * (ow - 1) + 1:sw, :]
            acc_ref[...] += jax.lax.dot_general(
                patch.reshape(oh * ow, cin), w_ref[i * kw + j],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    acc = acc_ref[...]
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(jnp.float32)
    if ane_mode:
        # the MAC output-port ceiling: |x| >= 2^15 -> +-inf (paper §3.7)
        acc = jnp.where(acc >= hal.ACCUM_OUT_CEILING, jnp.inf, acc)
        acc = jnp.where(acc <= -hal.ACCUM_OUT_CEILING, -jnp.inf, acc)
    if lut_refs is not None:
        # fused LUT activation at the output port; round to the out dtype
        # first — the separate-op pipeline stores the conv and reloads it
        # through act_lut's fp32 widening, so this rounding is what makes
        # fused == kernel-then-LUT, bit for bit
        acc = acc.astype(out_dtype).astype(jnp.float32)
        acc = lut_eval(acc, *lut_refs, ane_mode=True)
    o_ref[...] = acc.reshape(1, oh, ow, cout).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "padding", "ane_mode",
                                    "epilogue"))
def conv2d(
    x: jnp.ndarray,                    # (B, H, W, Cin) NHWC
    w: jnp.ndarray,                    # (KH, KW, Cin, Cout) HWIO
    bias: jnp.ndarray | None = None,   # (Cout,)
    *,
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    ane_mode: bool = False,
    epilogue: str | None = None,       # LUT activation fused at the output
) -> jnp.ndarray:
    b, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (cin, cin2)
    sh, sw = stride
    out_dtype = x.dtype
    oh = out_extent(h, kh, sh, padding)
    ow = out_extent(wd, kw, sw, padding)
    ph = pad_explicit(h, kh, sh, padding)
    pw = pad_explicit(wd, kw, sw, padding)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    # the tap slices only ever reach sh*(oh-1)+kh rows; crop VALID leftovers
    xp = xp[:, :sh * (oh - 1) + kh, :sw * (ow - 1) + kw, :]
    # MXU-friendly channel padding: contraction to a sublane multiple,
    # output channels to a lane multiple (zeros are exact for the matmul)
    xp = pad_to(xp, 3, 8)
    wp = pad_to(pad_to(w.reshape(kh * kw, cin, cout), 1, 8), 2, 128)
    cin_p, cout_p = wp.shape[1], wp.shape[2]
    hp, wp_w = xp.shape[1], xp.shape[2]

    operands = [xp, wp]
    in_specs = [
        pl.BlockSpec((1, hp, wp_w, cin_p), lambda bb: (bb, 0, 0, 0)),
        pl.BlockSpec((kh * kw, cin_p, cout_p), lambda bb: (0, 0, 0)),
    ]
    if bias is not None:
        operands.append(pad_to(bias.reshape(1, -1), 1, cout_p))
        in_specs.append(pl.BlockSpec((1, cout_p), lambda bb: (0, 0)))
    if epilogue is not None:
        from repro.kernels.act_lut.ops import lut_table_operands
        operands.extend(lut_table_operands(epilogue))
        in_specs.extend(pl.BlockSpec((1, c), lambda bb: (0, 0))
                        for c in (33, 32, 32, 2))

    def kernel(*refs):
        x_ref, w_ref = refs[0], refs[1]
        idx = 2
        bias_ref = lut_refs = None
        if bias is not None:
            bias_ref = refs[idx]
            idx += 1
        if epilogue is not None:
            lut_refs = refs[idx:idx + 4]
            idx += 4
        o_ref, acc_ref = refs[-2], refs[-1]
        _kernel(x_ref, w_ref, bias_ref, lut_refs, o_ref, acc_ref,
                kh=kh, kw=kw, sh=sh, sw=sw, oh=oh, ow=ow,
                ane_mode=ane_mode, out_dtype=out_dtype)

    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, oh, ow, cout_p), lambda bb: (bb, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, cout_p), out_dtype),
        scratch_shapes=[pltpu.VMEM((oh * ow, cout_p), jnp.float32)],
        interpret=interpret_mode(),
        **compat.pallas_call_params(dimension_semantics=("parallel",)),
    )(*operands)
    return out[..., :cout]
