"""avg_pool / max_pool: NHWC window reductions over shifted strided slices.

Same datapath shape as the conv kernel (the engine's pooling unit shares the
conv address generator, §3.1): each (i, j) window tap is a strided spatial
slice of the VMEM-resident input tile; avg sums taps in fp32 and scales by
1/(wh*ww) at the output port, max folds taps with an elementwise maximum.
SAME padding contributes the reduction identity (0 for the avg sum — the
engine's count-include-pad semantics — and -inf for max), which is exactly
what `lax.reduce_window` does with the same explicit pads, so the oracle
match is bit-for-bit up to the single fp32 accumulation order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.common import interpret_mode, pad_to
from repro.kernels.conv.conv2d import out_extent, pad_explicit


def _pool_kernel(x_ref, o_ref, *, wh, ww, sh, sw, oh, ow, kind, out_dtype):
    x = x_ref[0].astype(jnp.float32)               # (Hp, Wp, C)
    acc = None
    for i in range(wh):
        for j in range(ww):
            tap = x[i:i + sh * (oh - 1) + 1:sh,
                    j:j + sw * (ow - 1) + 1:sw, :]
            if acc is None:
                acc = tap
            elif kind == "avg":
                acc = acc + tap
            else:
                acc = jnp.maximum(acc, tap)
    if kind == "avg":
        acc = acc * (1.0 / (wh * ww))
    o_ref[...] = acc[None].astype(out_dtype)


def _pool(x, *, window, stride, padding, kind):
    b, h, w, c = x.shape
    wh, ww = window
    sh, sw = stride
    oh = out_extent(h, wh, sh, padding)
    ow = out_extent(w, ww, sw, padding)
    ph = pad_explicit(h, wh, sh, padding)
    pw = pad_explicit(w, ww, sw, padding)
    fill = 0.0 if kind == "avg" else -jnp.inf      # the reduction identity
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), ph, pw, (0, 0)),
                 constant_values=fill)
    xp = xp[:, :sh * (oh - 1) + wh, :sw * (ow - 1) + ww, :]
    xp = pad_to(xp, 3, 128)
    hp, wp, cp = xp.shape[1], xp.shape[2], xp.shape[3]

    out = pl.pallas_call(
        functools.partial(_pool_kernel, wh=wh, ww=ww, sh=sh, sw=sw,
                          oh=oh, ow=ow, kind=kind, out_dtype=x.dtype),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, hp, wp, cp), lambda bb: (bb, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow, cp), lambda bb: (bb, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, cp), x.dtype),
        interpret=interpret_mode(),
        **compat.pallas_call_params(dimension_semantics=("parallel",)),
    )(xp)
    return out[..., :c]


@functools.partial(jax.jit, static_argnames=("window", "stride", "padding"))
def avg_pool(x: jnp.ndarray, *, window: tuple[int, int],
             stride: tuple[int, int] | None = None,
             padding: str = "VALID") -> jnp.ndarray:
    """NHWC average pooling (count-include-pad, like the engine)."""
    return _pool(x, window=window, stride=stride or window, padding=padding,
                 kind="avg")


@functools.partial(jax.jit, static_argnames=("window", "stride", "padding"))
def max_pool(x: jnp.ndarray, *, window: tuple[int, int],
             stride: tuple[int, int] | None = None,
             padding: str = "VALID") -> jnp.ndarray:
    """NHWC max pooling."""
    return _pool(x, window=window, stride=stride or window, padding=padding,
                 kind="max")
